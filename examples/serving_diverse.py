"""Serving + DMMC: batched greedy decoding from a small LM, then a
diversity-maximized, category-constrained selection over the generated
continuations (diverse top-m responses — the paper's web-search use case).

    PYTHONPATH=src python examples/serving_diverse.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import solve_dmmc
from repro.core.matroid import MatroidSpec
from repro.models import LM
from repro.serve.engine import Engine


def main():
    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = Engine(lm, params, max_len=48)

    B, P, steps, k = 24, 8, 16, 6
    prompts = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0, cfg.vocab)
    out = eng.generate(prompts, steps=steps)
    print(f"generated {B} continuations of {steps} tokens")

    # embed each continuation (mean hidden state of the trunk) and pick a
    # diverse subset balanced across 4 prompt "intents" (partition matroid)
    hidden, _, _ = lm.forward(
        params, jnp.concatenate([prompts, out], axis=1), remat=False
    )
    emb = np.asarray(jnp.mean(hidden.astype(jnp.float32), axis=1))
    intents = (np.arange(B) % 4).astype(np.int32)[:, None]
    caps = np.full(4, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=4, gamma=1)
    sol = solve_dmmc(emb, k, spec, cats=intents, caps=caps, tau=12,
                     setting="sequential", metric="cosine")
    print(f"diverse top-{k} responses: {sorted(sol.indices.tolist())} "
          f"(<=2 per intent), diversity={sol.diversity:.3f}")
    counts = np.bincount(intents[sol.indices, 0], minlength=4)
    assert counts.max() <= 2
    print(f"intent balance: {counts.tolist()}")


if __name__ == "__main__":
    main()
