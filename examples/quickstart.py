"""Quickstart: diversity maximization under a partition matroid, all three
settings (sequential Alg. 1 / streaming Alg. 2 / MapReduce shard_map).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import PartitionMatroid, solve_dmmc
from repro.core.matroid import MatroidSpec


def main():
    rng = np.random.default_rng(0)
    n, h, k = 5000, 6, 8

    # points on a low-dimensional manifold (the paper's doubling-dimension
    # regime), each with a category; at most 2 picks per category allowed
    base = rng.normal(size=(n, 3)) @ rng.normal(size=(3, 16))
    points = (base + 0.05 * rng.normal(size=(n, 16))).astype(np.float32)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)

    for setting in ("sequential", "streaming", "mapreduce"):
        kw = dict(setting=setting, tau=64)
        if setting == "mapreduce":
            # launch.mesh.make_mesh papers over the AxisType API drift
            from repro.launch.mesh import make_mesh

            kw["mesh"] = make_mesh((len(jax.devices()),), ("data",))
        sol = solve_dmmc(points, k, spec, cats=cats, caps=caps, **kw)
        m = PartitionMatroid(cats[:, 0], caps)
        assert m.is_independent(list(sol.indices))
        print(f"{setting:>11}: diversity={sol.diversity:9.2f}  "
              f"coreset={sol.coreset_size:4d}/{n}  "
              f"coreset_time={sol.timings['coreset_s']:.2f}s  "
              f"solver_time={sol.timings['solver_s']:.2f}s  "
              f"picked={sorted(sol.indices.tolist())}")


if __name__ == "__main__":
    main()
