"""End-to-end training driver: a (reduced) smollm-135m trained for a few
hundred steps with DMMC diversity-maximized batch selection vs random
batches — the paper's technique as a data-curation feature.

    PYTHONPATH=src python examples/diverse_training.py [--steps 200]
    PYTHONPATH=src python examples/diverse_training.py --full  # real 135M

Also demonstrates the fault-tolerance loop: checkpoints land in
--ckpt-dir and a rerun resumes (kill it mid-run to see).
"""
import argparse
import subprocess
import sys
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full 135M config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_diverse_ckpt")
    args = ap.parse_args()

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m", "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--log-every", "20",
        "--ckpt-every", "100",
    ]
    if not args.full:
        base.append("--reduced")
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))

    print("=== diverse (coreset-selected) batches ===")
    subprocess.run(base + ["--ckpt-dir", args.ckpt_dir], env=env, check=True)
    print("=== random batches (ablation) ===")
    subprocess.run(base + ["--no-diverse-data"], env=env, check=True)


if __name__ == "__main__":
    main()
