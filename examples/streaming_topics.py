"""Streaming DMMC over a simulated Wikipedia-like stream (transversal
matroid over topics): one pass, bounded memory, topic-diverse summary.

    PYTHONPATH=src python examples/streaming_topics.py
"""
import numpy as np

from repro.core import TransversalMatroid, solve_dmmc
from repro.core.matroid import MatroidSpec


def main():
    rng = np.random.default_rng(1)
    n, h, gamma, k = 30000, 20, 2, 10

    topic_centers = rng.normal(size=(h, 4))
    basis = rng.normal(size=(4, 25))
    topic = rng.integers(0, h, n)
    points = (topic_centers[topic] @ basis
              + 0.1 * rng.normal(size=(n, 25))).astype(np.float32)
    cats = np.full((n, gamma), -1, np.int32)
    cats[:, 0] = topic
    extra = rng.random(n) < 0.3
    cats[extra, 1] = rng.integers(0, h, extra.sum())
    spec = MatroidSpec("transversal", num_categories=h, gamma=gamma)

    sol = solve_dmmc(points, k, spec, cats=cats, tau=64,
                     setting="streaming", metric="cosine")
    m = TransversalMatroid(cats, h)
    assert m.is_independent(list(sol.indices))
    picked_topics = sorted({int(t) for i in sol.indices for t in cats[i]
                            if t >= 0})
    print(f"one pass over {n} docs, working set = {sol.coreset_size} docs")
    print(f"diversity = {sol.diversity:.2f}")
    print(f"selected docs {sol.indices.tolist()}")
    print(f"respecting a matching into topics; topics touched: "
          f"{picked_topics}")


if __name__ == "__main__":
    main()
