"""Online diversity serving end to end: a simulated recommendation stream
is ingested asynchronously (background submit worker publishing epoch
snapshots of the resumable Alg.-2 scan), TWO tenants — different metrics,
one physical stream — answer bursts of heterogeneous queries from their
own cached coreset distance matrices, and the single-tenant
``DiversityService`` façade shows the historical API unchanged — the
paper's web-search/recommendation workload (§1) with the coreset as the
*only* serving state.

At exit the run's observability artifacts are written next to the system
temp dir: a JSONL metrics snapshot (every serving counter/histogram this
run touched) and a Chrome ``trace_event`` file — open it at
chrome://tracing or https://ui.perfetto.dev to see the submit -> ingest ->
publish -> query -> solve span tree, one trace ID per request.

    PYTHONPATH=src python examples/diversity_service.py
"""
import os
import tempfile

import numpy as np

from repro import obs

from repro.core import solve_dmmc
from repro.core.matroid import MatroidSpec
from repro.serve.diversity import (
    DiversityQuery,
    DiversityService,
    QueryFrontend,
    StreamRuntime,
)


def make_catalog(rng, n, h):
    """A songs-like catalog: 16 genres, skewed sizes, genre caps."""
    genre = rng.choice(h, n, p=rng.dirichlet(np.ones(h)))
    basis = rng.normal(size=(5, 64))
    points = (rng.normal(size=(h, 5))[genre] * 2 @ basis
              + rng.normal(size=(n, 64))).astype(np.float32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return points, genre, caps, spec


def main():
    rng = np.random.default_rng(7)
    n, h, k, tau = 20000, 16, 8, 32
    points, genre, caps, spec = make_catalog(rng, n, h)

    # ---- the layered runtime: one stream, async ingest, two tenants ----
    rt = StreamRuntime(spec, k, tau=tau, caps=caps)  # euclidean stream
    fe = QueryFrontend(rt)
    # tenant 2: same stream, cosine geometry, its own cached matrix
    fe.register_tenant("cosine", metric="cosine")

    with rt:  # the catalog arrives in non-blocking batches
        for off in range(0, n, 1000):
            rt.submit(points[off:off + 1000], genre[off:off + 1000, None])
        epoch = fe.flush()  # freshness barrier: everything submitted is in
        snap = rt.latest()
        print(f"ingested {snap.n_offered} items asynchronously; epoch "
              f"{epoch} serves a {snap.size}-point coreset "
              f"(+{tau + 1}-center scan state)")

        # a burst of user queries per tenant: result sizes, genre filters,
        # caps — answered on each tenant's own cached matrix
        burst = [
            DiversityQuery(k=8),                                   # homepage
            DiversityQuery(k=4, allowed_cats=frozenset(range(4))), # rock tab
            DiversityQuery(k=6, caps=(1,) * h),                    # 1/genre
            DiversityQuery(k=8, variant="tree"),                   # playlist
            DiversityQuery(k=8, variant="tree",                    # same, fast
                           engine_hint="jit_greedy"),              # greedy
        ]
        for tenant in ("default", "cosine"):
            results = fe.query_batch(burst, tenant=tenant,
                                     min_epoch=epoch)
            print(f"tenant {tenant!r} (metric="
                  f"{fe.tenants.get(tenant).metric}):")
            for q, r in zip(burst, results):
                print(f"  k={q.k} variant={q.variant:<4} "
                      f"engine={r.engine:<15} epoch={r.epoch} "
                      f"div={r.diversity:9.3f} "
                      f"items={sorted(r.indices.tolist())}")
        st = fe.stats()
        print(f"stats: {st['cache']['builds']} pdist build(s) for "
              f"{len(st['tenants'])} tenants over one stream, "
              f"{st['cache']['hits']} cache hits, "
              f"{st['epochs_published']} epoch(s) published, "
              f"{st['snapshot_materializations']} materialization(s)")

    # ---- the single-tenant façade: the historical API, unchanged ----
    svc = DiversityService(spec, k, tau=tau, caps=caps)
    for off in range(0, n, 1000):
        svc.ingest(points[off:off + 1000], genre[off:off + 1000, None])
    res = svc.query_batch(burst)[0]

    # the cached answer matches the offline driver's answer (the fast
    # engines guarantee the same selection; the host engine also matches
    # the offline selection *order* bit for bit)
    sol = solve_dmmc(points, k, spec, cats=genre[:, None], caps=caps,
                     tau=tau, setting="streaming")
    assert sorted(res.indices.tolist()) == sorted(sol.indices.tolist())
    assert res.diversity == sol.diversity
    # ... and the async runtime's default tenant answered the same
    # query identically: same stream content, same coreset
    first = fe.query(burst[0], tenant="default")
    assert sorted(first.indices.tolist()) == sorted(sol.indices.tolist())
    print(f"parity with offline solve_dmmc confirmed "
          f"(div={sol.diversity:.3f}) for the façade AND the async runtime")

    # ---- observability: everything above was measured as it ran ----
    q_lat = obs.histogram("serve.query.latency_s", tenant="default")
    i_lat = None
    for m in obs.default_registry().series():
        if m.name == "serve.ingest.latency_s":
            i_lat = m
    print(f"observability: {q_lat.count} default-tenant query batches "
          f"(p95 {q_lat.quantile(0.95) * 1e3:.1f} ms), "
          f"{i_lat.count} ingest batches "
          f"(p95 {i_lat.quantile(0.95) * 1e3:.1f} ms), "
          f"{obs.counter('serve.epoch.published').value} epochs published")
    out = tempfile.gettempdir()
    metrics_path = os.path.join(out, "diversity_service.metrics.jsonl")
    trace_path = os.path.join(out, "diversity_service.trace.json")
    obs.write_metrics_jsonl(metrics_path)
    obs.dump_trace(trace_path)
    print(f"metrics snapshot -> {metrics_path}")
    print(f"request trace    -> {trace_path}  (chrome://tracing)")


if __name__ == "__main__":
    main()
