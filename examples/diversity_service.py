"""Online diversity service end to end: a simulated recommendation stream is
ingested in batches (resumable Alg.-2 scan), then bursts of heterogeneous
user queries are answered from the cached coreset distance matrix — the
paper's web-search/recommendation workload (§1) with the coreset as the
*only* serving state.

    PYTHONPATH=src python examples/diversity_service.py
"""
import numpy as np

from repro.core import solve_dmmc
from repro.core.matroid import MatroidSpec
from repro.serve.diversity import DiversityQuery, DiversityService


def main():
    rng = np.random.default_rng(7)
    n, h, k, tau = 20000, 16, 8, 32

    # a songs-like catalog: 16 genres, skewed sizes, genre caps
    genre = rng.choice(h, n, p=rng.dirichlet(np.ones(h)))
    basis = rng.normal(size=(5, 64))
    points = (rng.normal(size=(h, 5))[genre] * 2 @ basis
              + rng.normal(size=(n, 64))).astype(np.float32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)

    svc = DiversityService(spec, k, tau=tau, caps=caps, metric="cosine")
    for off in range(0, n, 1000):  # the catalog arrives in batches
        rep = svc.ingest(points[off:off + 1000], genre[off:off + 1000, None])
    print(f"ingested {rep.total} items; serving state = "
          f"{rep.coreset_size}-point coreset (+{tau + 1}-center scan state)")

    # a burst of user queries: different result sizes, genre filters, caps
    burst = [
        DiversityQuery(k=8),                                   # homepage
        DiversityQuery(k=4, allowed_cats=frozenset(range(4))), # rock tab
        DiversityQuery(k=6, caps=(1,) * h),                    # one per genre
        DiversityQuery(k=8, variant="tree"),                   # playlist arc
        DiversityQuery(k=8, variant="tree",                    # same, but the
                       engine_hint="jit_greedy"),              # fast greedy
    ]
    results = svc.query_batch(burst)
    for q, r in zip(burst, results):
        print(f"  k={q.k} variant={q.variant:<4} engine={r.engine:<4} "
              f"cached={r.from_cache} div={r.diversity:9.3f} "
              f"items={sorted(r.indices.tolist())}")
    s = svc.cache.stats
    print(f"cache: {s.builds} pdist build(s), {s.hits} hits "
          f"({len(results)} queries answered on one matrix)")

    # the cached answer matches the offline driver's answer (the fast
    # engines guarantee the same selection; the host engine also matches
    # the offline selection *order* bit for bit)
    sol = solve_dmmc(points, k, spec, cats=genre[:, None], caps=caps,
                     tau=tau, setting="streaming", metric="cosine")
    assert sorted(results[0].indices.tolist()) == sorted(sol.indices.tolist())
    assert results[0].diversity == sol.diversity
    print(f"parity with offline solve_dmmc confirmed "
          f"(div={sol.diversity:.3f})")


if __name__ == "__main__":
    main()
