"""StreamCoreset (Alg. 2 / §5.2 variant): invariants + solution quality."""
import numpy as np
import pytest
import jax.numpy as jnp

from conftest import make_clustered_points
from repro.core.matroid import (
    GeneralMatroid,
    MatroidSpec,
    PartitionMatroid,
    TransversalMatroid,
)
from repro.core.streaming import stream_coreset, stream_coreset_host


def _run(P, cats, spec, caps, k, tau):
    n = P.shape[0]
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    cs, st = stream_coreset(
        jnp.asarray(P, jnp.float32), jnp.asarray(cats), jnp.ones((n,), bool),
        spec, caps_j, k, tau,
    )
    return cs, st


def test_center_count_bounded(rng):
    P = make_clustered_points(rng, n=500, centers=12, spread=0.05)
    cats = np.zeros((500, 1), np.int32)
    spec = MatroidSpec("uniform")
    cs, st = _run(P, cats, spec, None, 4, 16)
    assert int(np.asarray(st.cvalid).sum()) <= 16


def test_coverage_radius(rng):
    """Every point is within 2*R_final + merge drift of a final center
    (Charikar-style guarantee; we assert the conservative 4R bound)."""
    P = make_clustered_points(rng, n=400, centers=6, spread=0.05)
    cats = np.zeros((400, 1), np.int32)
    cs, st = _run(P, cats, MatroidSpec("uniform"), None, 3, 12)
    centers = np.asarray(st.centers)[np.asarray(st.cvalid)]
    R = float(st.R)
    d = np.sqrt(((P[:, None] - centers[None]) ** 2).sum(-1)).min(1)
    assert d.max() <= 4 * R + 1e-5, (d.max(), R)


def test_partition_delegates_independent(rng):
    n, h, k = 300, 4, 3
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 1, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    m = PartitionMatroid(cats[:, 0], caps)
    cs, st = _run(P, cats, spec, caps, k, 10)
    # every per-center delegate set is independent and <= k
    dv = np.asarray(st.dv)
    dsrc = np.asarray(st.ds)
    cvalid = np.asarray(st.cvalid)
    for z in range(dv.shape[0]):
        if not cvalid[z]:
            continue
        sel = dsrc[z][dv[z]]
        assert len(sel) <= k
        assert m.is_independent([int(s) for s in sel])


def test_transversal_category_invariant(rng):
    """If a point was discarded, each of its categories must have >= k
    delegates at its would-be center... we check the weaker end-state
    condition used by Thm 7: every category present among a center's
    delegates appears min(k, count) times or the set is an independent
    witness of size k (post-shrink)."""
    n, h, k = 300, 4, 2
    P = make_clustered_points(rng, n=n)
    cats = np.full((n, 2), -1, np.int32)
    cats[:, 0] = rng.integers(0, h, n)
    some = rng.random(n) < 0.5
    cats[some, 1] = rng.integers(0, h, some.sum())
    spec = MatroidSpec("transversal", num_categories=h, gamma=2)
    m = TransversalMatroid(cats, h)
    cs, st = _run(P, cats, spec, None, k, 10)
    sel = np.asarray(cs.src_idx)[np.asarray(cs.valid)]
    # the coreset must contain an independent set of size k (feasibility)
    assert len(m.greedy_independent([int(s) for s in sel], k)) == k


def test_quality_improves_with_tau(rng):
    from repro.core.solve import solve_dmmc

    n, h, k = 600, 4, 4
    P = make_clustered_points(rng, n=n, centers=8, spread=0.05)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    vals = []
    for tau in (4, 32):
        s = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                       setting="streaming")
        vals.append(s.diversity)
    assert vals[1] >= vals[0] * 0.99  # larger coreset never much worse


def test_host_streaming_general_matroid(rng):
    n, k = 120, 3
    P = make_clustered_points(rng, n=n, centers=5)

    def oracle(idxs):
        return len(idxs) <= 3  # uniform-as-general

    m = GeneralMatroid(n, oracle)
    sel = stream_coreset_host(P, None, m, k, tau=8)
    assert len(sel) >= k
    assert m.is_independent(list(sel[:k]))
