"""Multi-tenant cache churn over ONE stream: per-tenant fingerprint/entry
isolation, LRU+TTL interplay under eviction pressure, and epoch
publication invalidating exactly the affected entries."""
import numpy as np
import pytest

from conftest import make_clustered_points
from repro.core.matroid import MatroidSpec, PartitionMatroid
from repro.serve.diversity import (
    DistanceCache,
    DiversityQuery,
    QueryFrontend,
    StreamRuntime,
)


def _instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _four_tenants(fe, caps):
    """default partition/euclidean + three more keys over the one stream:
    mixed metrics, taus, and matroid views."""
    return [
        fe.default_tenant,
        fe.register_tenant("cosine", metric="cosine"),
        fe.register_tenant("tau-hi", tau=fe.runtime.tau * 2),
        fe.register_tenant("uniform", spec=MatroidSpec("uniform")),
    ]


def test_tenant_fanout_isolated_entries_one_stream(rng):
    P, cats, caps, spec, k = _instance(rng)
    rt = StreamRuntime(spec, k, tau=12, caps=caps)
    fe = QueryFrontend(rt)
    tenants = _four_tenants(fe, caps)
    rt.ingest(P, cats)
    res = {t.name: fe.query(DiversityQuery(k=k), tenant=t.name)
           for t in tenants}
    # one stream, one epoch, four private entries — one build per key
    assert len({t.key for t in tenants}) == 4
    assert len(fe.cache) == 4
    assert fe.cache.stats.builds == 4
    epochs = {r.epoch for r in res.values()}
    assert len(epochs) == 1, "all tenants read the same published epoch"
    assert {r.tenant for r in res.values()} == {t.name for t in tenants}
    # per-tenant isolation: same coreset rows, but the cosine tenant's
    # entry holds re-normalized points (and so a different matrix)
    e_def = fe.cache.lookup(tenants[0].key, rt.fingerprint)
    e_cos = fe.cache.lookup(tenants[1].key, rt.fingerprint)
    assert np.array_equal(e_def.src_idx, e_cos.src_idx)
    assert not np.allclose(e_def.points, e_cos.points)
    norms = np.linalg.norm(e_cos.points, axis=1)
    assert np.allclose(norms, 1.0, atol=1e-5)
    # constraint isolation: partition tenants return independent sets, the
    # uniform tenant is free of the caps
    m = PartitionMatroid(cats[:, 0], caps)
    assert m.is_independent(list(res["default"].indices))
    assert res["uniform"].engine in ("jit_sum", "host_local_search")
    # warm path: repeat queries hit, never rebuild
    builds = fe.cache.stats.builds
    for t in tenants:
        fe.query(DiversityQuery(k=k), tenant=t.name)
    assert fe.cache.stats.builds == builds
    st = fe.stats()
    assert st["cache"]["builds"] == builds
    assert st["tenants"] == sorted(t.name for t in tenants)


def test_identical_keys_share_one_entry(rng):
    """Tenants that differ only in caps share the (spec, tau, metric) key
    and therefore one matrix — fan-out dedup, caps stay per-query."""
    P, cats, caps, spec, k = _instance(rng)
    rt = StreamRuntime(spec, k, tau=12, caps=caps)
    fe = QueryFrontend(rt)
    tight = fe.register_tenant("tight", caps=np.ones_like(caps))
    assert tight.key == fe.default_tenant.key
    rt.ingest(P, cats)
    r1 = fe.query(DiversityQuery(k=k))
    r2 = fe.query(DiversityQuery(k=k), tenant="tight")
    assert fe.cache.stats.builds == 1
    got = cats[r2.indices, 0]
    assert len(got) == len(set(got)), "tight tenant's caps=1 violated"
    assert len(set(r1.indices.tolist())) == k


def test_lru_ttl_interplay_under_eviction_pressure(rng):
    """4 tenants through a max_entries=2 + TTL cache: round-robin churn
    evicts LRU entries, answers stay correct, TTL expires survivors, and
    under capacity pressure expired entries are reclaimed before live
    ones are evicted."""
    P, cats, caps, spec, k = _instance(rng)
    clock = FakeClock()
    cache = DistanceCache(max_entries=2, ttl_s=100.0, clock=clock)
    rt = StreamRuntime(spec, k, tau=12, caps=caps)
    fe = QueryFrontend(rt, cache=cache)
    tenants = _four_tenants(fe, caps)
    rt.ingest(P, cats)
    baseline = {}
    for r in range(3):  # churn: every visit under pressure misses+rebuilds
        for t in tenants:
            clock.t += 1.0
            res = fe.query(DiversityQuery(k=k), tenant=t.name)
            if r == 0:
                baseline[t.name] = res
            else:
                assert sorted(res.indices.tolist()) == sorted(
                    baseline[t.name].indices.tolist()
                ), f"churned answer drifted for {t.name}"
    assert len(cache) == 2
    assert cache.stats.evictions >= 8  # 4 tenants x 3 rounds over 2 slots
    assert cache.stats.builds >= 10
    # TTL: age both survivors out; the next build sweeps them (lazily)
    sweeps = cache.stats.sweeps
    clock.t += 200.0
    fe.query(DiversityQuery(k=k))
    assert cache.stats.expirations >= 2
    assert cache.stats.sweeps >= sweeps
    assert len(cache) == 1
    # capacity pressure prefers reclaiming expired entries over evicting
    # live ones: with one live + one expired entry, a third build drops
    # the expired one (expiration, not eviction)
    clock.t += 1.0
    fe.query(DiversityQuery(k=k), tenant="cosine")
    assert len(cache) == 2
    clock.t += 150.0  # both now expired
    ev = cache.stats.evictions
    fe.query(DiversityQuery(k=k), tenant="uniform")
    assert cache.stats.evictions == ev, "evicted a reclaimable entry"
    assert len(cache) == 1


def test_epoch_publication_invalidates_exactly_affected_entries(rng):
    """A changed epoch on stream A invalidates exactly A's tenants'
    entries; tenants of an unrelated stream B sharing the same cache stay
    warm."""
    P, cats, caps, spec, k = _instance(rng, n=600)
    cache = DistanceCache()
    rt_a = StreamRuntime(spec, k, tau=12, caps=caps)
    rt_b = StreamRuntime(spec, k, tau=8, caps=caps)  # distinct tau -> keys
    fe_a = QueryFrontend(rt_a, cache=cache)
    fe_b = QueryFrontend(rt_b, cache=cache)
    fe_a.register_tenant("cosine", metric="cosine")
    rt_a.ingest(P[:300], cats[:300])
    rt_b.ingest(P[:300], cats[:300])
    for fe, names in ((fe_a, ("default", "cosine")), (fe_b, ("default",))):
        for name in names:
            fe.query(DiversityQuery(k=k), tenant=name)
    assert cache.stats.builds == 3
    # grow stream A until its coreset actually changes (shifted copies
    # force new centers if the tail alone didn't)
    rep = rt_a.ingest(P[300:], cats[300:])
    shift = 1
    while not rep.coreset_changed and shift < 64:
        rep = rt_a.ingest(P[:100] + 10.0 * shift, cats[:100])
        shift *= 2
    assert rep.coreset_changed
    builds = cache.stats.builds
    inval = cache.stats.invalidations
    ra = fe_a.query(DiversityQuery(k=k))
    ra2 = fe_a.query(DiversityQuery(k=k), tenant="cosine")
    # exactly A's two tenant entries rebuilt (old fingerprints invalidated)
    assert cache.stats.builds == builds + 2
    assert cache.stats.invalidations == inval + 2
    assert ra.epoch == ra2.epoch == rt_a.latest().epoch
    # B's entry is untouched and still warm
    hits = cache.stats.hits
    rb = fe_b.query(DiversityQuery(k=k))
    assert cache.stats.builds == builds + 2
    assert cache.stats.hits == hits + 1
    assert rb.from_cache


def test_tenant_registry_admission_rules(rng):
    P, cats, caps, spec, k = _instance(rng, n=100)
    rt = StreamRuntime(spec, k, tau=8, caps=caps)
    fe = QueryFrontend(rt)
    # identical re-registration is a no-op, conflicting config raises
    t = fe.register_tenant("cosine", metric="cosine")
    assert fe.register_tenant("cosine", metric="cosine") is t
    with pytest.raises(ValueError, match="different configuration"):
        fe.register_tenant("cosine", metric="euclidean")
    with pytest.raises(KeyError, match="unknown tenant"):
        fe.query(DiversityQuery(k=k), tenant="nope")
    # the same admission rules as a single-tenant service
    with pytest.raises(ValueError, match="oracle"):
        fe.register_tenant("gen", spec=MatroidSpec("general"))
    # a partition tenant passing no caps inherits the runtime's ...
    inh = fe.register_tenant("inherit", tau=99)
    assert np.array_equal(inh.caps, rt.caps)
    # ... but over a capless (uniform) stream it must bring its own
    rt_u = StreamRuntime(MatroidSpec("uniform"), k, tau=8)
    fe_u = QueryFrontend(rt_u)
    with pytest.raises(ValueError, match="caps"):
        fe_u.register_tenant(
            "capless",
            spec=MatroidSpec("partition", num_categories=4, gamma=1),
        )
    # metric derivability: a cosine-normalized stream cannot serve a
    # euclidean tenant (raw geometry is gone) — refused at registration;
    # the reverse (cosine tenant over a raw stream) is exact and allowed
    rt_c = StreamRuntime(MatroidSpec("uniform"), k, tau=8, metric="cosine")
    fe_c = QueryFrontend(rt_c)
    with pytest.raises(ValueError, match="not\\s+derivable"):
        fe_c.register_tenant("euc", metric="euclidean")
    assert fe_c.register_tenant("cos2", metric="cosine").metric == "cosine"
