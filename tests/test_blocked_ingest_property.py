"""Hypothesis property test: blocked ingestion == per-point ingestion over
random instances, batch splits, block sizes, shard counts, and all three
jit matroid kinds.

Kept separate from the always-running deterministic sweep
(test_blocked_ingest.py) because the module-level importorskip below skips
this whole module when hypothesis is missing (requirements-dev.txt).
"""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from test_blocked_ingest import (
    BLOCKS,
    _assert_states_equal,
    _ingest,
    _instance,
)
from repro.core.streaming import (
    ingest_batch,
    ingest_batch_sharded,
    init_sharded_states,
    init_stream_state,
)

# block sizes / shard counts come from small fixed menus so the jit cache is
# reused across examples (block_size is a static argument)
ingest_cases = st.tuples(
    st.sampled_from(["uniform", "partition", "transversal"]),
    st.sampled_from(BLOCKS[1:]),  # block size under test
    st.sampled_from([2, 3]),  # shard count
    st.integers(0, 10_000),  # instance seed
    st.integers(60, 120),  # n
)


@settings(max_examples=8, deadline=None)
@given(ingest_cases)
def test_blocked_and_sharded_equal_per_point_property(case):
    kind, bs, S, seed, n = case
    P, cats, caps, spec, k = _instance(kind, seed=seed, n=n)
    tau = 8
    rng = np.random.default_rng(seed + 1)
    # random batch split of the stream
    splits = []
    left = n
    while left > 0:
        b = int(rng.integers(1, left + 1))
        splits.append(b)
        left -= b
    ref = _ingest(P, cats, caps, spec, k, tau, 1, [n])
    st_blocked = _ingest(P, cats, caps, spec, k, tau, bs, splits)
    _assert_states_equal(ref, st_blocked, f"{kind} bs={bs} splits={splits}")
    # sharded: every shard bit-identical to its own per-point sub-stream
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    d, gamma = P.shape[1], cats.shape[1]
    mm = -(-n // S)
    Pb = np.zeros((S, mm, d), np.float32)
    Cb = np.full((S, mm, gamma), -1, np.int32)
    Vb = np.zeros((S, mm), bool)
    Sb = np.full((S, mm), -1, np.int32)
    for s in range(S):
        rows = np.arange(s, n, S)
        r = len(rows)
        Pb[s, :r] = P[rows]
        Cb[s, :r] = cats[rows]
        Vb[s, :r] = True
        Sb[s, :r] = rows
    sts = ingest_batch_sharded(
        init_sharded_states(S, d, gamma, spec, k, tau),
        jnp.asarray(Pb), jnp.asarray(Cb), jnp.asarray(Vb), jnp.asarray(Sb),
        spec, caps_j, k, tau, block_size=bs,
    )
    import jax

    for s in range(S):
        rows = np.arange(s, n, S)
        ref_s = init_stream_state(d, gamma, spec, k, tau)
        ref_s = ingest_batch(
            ref_s, jnp.asarray(P[rows]), jnp.asarray(cats[rows]),
            jnp.ones((len(rows),), bool), spec, caps_j, k, tau,
            src=jnp.asarray(rows, jnp.int32), block_size=1,
        )
        shard = jax.tree_util.tree_map(lambda x, s=s: x[s], sts)
        _assert_states_equal(ref_s, shard, f"{kind} S={S} shard {s}")
