"""Replicated serving suite: WAL shipping parity, fingerprint fencing,
failover durability, health-driven promotion, and the integrity auditor.

Chaos cases run over the seeded ``CHAOS_SEEDS`` matrix like the rest of
the fault-tolerance suites: every fault schedule is a pure function of
the seed, so the asserts are exact (bit-identical fingerprints, zero
acked batches lost) and reproduce with the same env var.
"""
import os
import time

import numpy as np
import pytest

from conftest import make_clustered_points
from repro import obs
from repro.core.matroid import MatroidSpec
from repro.serve.diversity import (
    AuditConfig,
    DiversityQuery,
    FaultPlan,
    FaultPolicy,
    FaultRule,
    HealthConfig,
    HealthMonitor,
    IntegrityAuditor,
    ReplicaSet,
    StreamRuntime,
)
from repro.serve.diversity.coalesce import PendingCall

SEEDS = tuple(
    int(s) for s in os.environ.get("CHAOS_SEEDS", "101,202,303,404").split(",")
)


def _instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


def _batches(P, cats, size=50):
    return [
        (P[off:off + size], cats[off:off + size])
        for off in range(0, P.shape[0], size)
    ]


def _make_set(spec, k, caps, tmp_path, **kw):
    return ReplicaSet.create(
        spec, k, dir=str(tmp_path / "replicas"), caps=caps,
        tau=12, block_size=32, registry=obs.MetricsRegistry(), **kw,
    )


def _reference_fingerprint(spec, k, caps, batches):
    ref = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        registry=obs.MetricsRegistry(),
    )
    for pts, cs in batches:
        ref.ingest(pts, cs)
    fp = ref.refresh(force=True).fingerprint
    ref.close()
    return fp


def _wait(cond, timeout=30.0):
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() > deadline:
            raise TimeoutError("condition not met in time")
        time.sleep(0.01)


# ----------------------------------------------------------------------
# shipping parity
# ----------------------------------------------------------------------

def test_standby_replays_to_bit_identical_state(tmp_path):
    """A standby fed the primary's WAL records is bit-identical at every
    synced watermark — the §3 pure-fold argument, machine-checked."""
    rng = np.random.default_rng(0)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        prt = rs.primary.runtime
        srt = rs.standbys[0].runtime
        assert prt.n_offered == srt.n_offered == P.shape[0]
        assert prt.fingerprint == srt.fingerprint
        assert rs.verify_standbys() == {"standby-0": True}
        # the standby's own WAL carries the primary's seq numbers
        assert srt._applied_seq == prt._applied_seq == rs.acked_seq
        # and it publishes its own query-able epochs
        assert srt.latest() is not None
        assert srt.latest().fingerprint == prt.latest().fingerprint
    finally:
        rs.close()


def test_standby_serves_reads_and_tenant_fanout(tmp_path):
    """Registered tenants exist on every replica, so a standby answers
    the same query with the same selection the primary would."""
    rng = np.random.default_rng(1)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        rs.register_tenant("uni", spec=MatroidSpec("uniform"))
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        direct = rs.query_batch(
            [DiversityQuery(k=k)], tenant="uni", allow_stale=False
        )
        stale = rs.standbys[0].frontend.query_batch(
            [DiversityQuery(k=k)], tenant="uni"
        )
        assert np.array_equal(
            np.sort(direct[0].indices), np.sort(stale[0].indices)
        )
        assert stale[0].epoch >= 0
    finally:
        rs.close()


@pytest.mark.parametrize("seed", SEEDS)
def test_dropped_ship_heals_from_primary_wal(tmp_path, seed):
    """``replication.ship`` drops are healed by the standby's gap fetch
    against the primary's durable log — parity is restored without a
    re-seed."""
    rng = np.random.default_rng(seed)
    P, cats, caps, spec, k = _instance(rng)
    plan = FaultPlan(seed, [
        FaultRule(site="replication.ship", kind="error", after=2,
                  every=3, times=3),
    ])
    reg = obs.MetricsRegistry()
    rs = ReplicaSet.create(
        spec, k, dir=str(tmp_path / "r"), caps=caps,
        tau=12, block_size=32, registry=reg,
    )
    rs.faults = plan  # ship-side only: the runtimes stay clean
    try:
        bs = _batches(P, cats)
        for pts, cs in bs:
            rs.submit(pts, cs)
        # a clean trailing record guarantees the gap fetch fires even
        # when the schedule dropped the last shipped batch
        rs.faults = None
        rs.submit(*bs[0])
        rs.sync(timeout=120)
        drops = int(rs._m_ship_errors.value)
        assert drops >= 1
        heals = int(reg.counter(
            "serve.replication.gap_heals", replica="standby-0"
        ).value)
        assert heals >= drops
        assert rs.verify_standbys() == {"standby-0": True}
        assert not rs.standbys[0].fenced
        assert int(rs._m_reseeds.value) == 0
    finally:
        rs.close()


@pytest.mark.parametrize("seed", SEEDS)
def test_transient_apply_fault_gap_heals(tmp_path, seed):
    """``replica.crash`` with ``kind="error"`` is a transient apply
    failure: the record is recovered from the primary's WAL by the next
    record's gap fetch, and the apply thread survives."""
    rng = np.random.default_rng(seed)
    P, cats, caps, spec, k = _instance(rng, n=200)
    plan = FaultPlan(seed, [
        FaultRule(site="replica.crash", kind="error", after=1, times=1),
    ])
    reg = obs.MetricsRegistry()
    rs = ReplicaSet.create(
        spec, k, dir=str(tmp_path / "r"), caps=caps,
        tau=12, block_size=32, registry=reg, standby_faults=plan,
    )
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        sb = rs.standbys[0]
        assert not sb.dead
        heals = int(reg.counter(
            "serve.replication.gap_heals", replica="standby-0"
        ).value)
        assert heals >= 1
        assert rs.verify_standbys() == {"standby-0": True}
    finally:
        rs.close()


def test_apply_crash_kills_standby(tmp_path):
    """``replica.crash`` with ``kind="crash"`` kills the apply thread:
    the standby is marked dead, excluded from verification/sync, and
    failover refuses to promote it."""
    rng = np.random.default_rng(2)
    P, cats, caps, spec, k = _instance(rng, n=200)
    plan = FaultPlan(7, [
        FaultRule(site="replica.crash", kind="crash", after=1, times=1),
    ])
    reg = obs.MetricsRegistry()
    rs = ReplicaSet.create(
        spec, k, dir=str(tmp_path / "r"), caps=caps,
        tau=12, block_size=32, registry=reg, standby_faults=plan,
    )
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.flush()
        sb = rs.standbys[0]
        _wait(lambda: sb.dead)
        assert not sb.promotable
        assert int(reg.counter(
            "serve.replication.apply_crashes", replica="standby-0"
        ).value) == 1
        assert rs.verify_standbys() == {"standby-0": None}
        rs.sync(timeout=30)  # dead standby is skipped, not waited on
        with pytest.raises(RuntimeError, match="no promotable standby"):
            rs.failover(reason="test")
    finally:
        rs.close()


# ----------------------------------------------------------------------
# divergence: fence + re-seed
# ----------------------------------------------------------------------

def test_divergent_standby_fences_and_reseeds(tmp_path):
    """A standby that folded a batch the primary never shipped is caught
    by the watermark exchange, fenced, then re-seeded from the primary's
    checkpoint back to parity."""
    rng = np.random.default_rng(3)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        bs = _batches(P, cats)
        for pts, cs in bs[:4]:
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        sb = rs.standbys[0]
        # corrupt the standby out-of-band: a batch the primary never saw
        sb.runtime.ingest(
            rng.normal(size=(8, P.shape[1])).astype(np.float32),
            rng.integers(0, 4, (8, 1)).astype(np.int32),
        )
        for pts, cs in bs[4:]:
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        assert rs.verify_standbys() == {"standby-0": False}
        assert int(rs._m_reseeds.value) == 1
        assert not sb.fenced  # re-seeded and back in rotation
        rs.sync(timeout=120)
        assert rs.verify_standbys() == {"standby-0": True}
        assert rs.primary.runtime.fingerprint == sb.runtime.fingerprint
    finally:
        rs.close()


def test_fenced_standby_not_promotable(tmp_path):
    rng = np.random.default_rng(4)
    P, cats, caps, spec, k = _instance(rng, n=100)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        rs.standbys[0]._fence("test")
        with pytest.raises(RuntimeError, match="no promotable standby"):
            rs.failover(reason="test")
    finally:
        rs.close()


# ----------------------------------------------------------------------
# failover
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_primary_kill_mid_ingest_promotes_with_parity(tmp_path, seed):
    """The acceptance scenario: the primary's worker is killed mid-
    stream under load; the standby promotes automatically, the post-
    failover fingerprint is bit-identical to a single-runtime replay of
    the same batch sequence, and zero acked batches are lost."""
    rng = np.random.default_rng(seed)
    P, cats, caps, spec, k = _instance(rng, n=600)
    batches = _batches(P, cats)
    plan = FaultPlan(seed, [
        FaultRule(site="worker.loop", kind="crash",
                  after=2 + seed % 5, times=1),
    ])
    rs = ReplicaSet.create(
        spec, k, dir=str(tmp_path / "r"), caps=caps,
        tau=12, block_size=32, registry=obs.MetricsRegistry(),
        faults=plan, fault_policy=FaultPolicy(max_worker_restarts=0),
    )
    try:
        for pts, cs in batches:
            rs.submit(pts, cs)  # fails over inline if the death surfaced
        rs.flush()  # fails over here if the death surfaced late
        rs.sync(timeout=120)
        st = rs.stats()
        assert st["failovers"] == 1
        assert st["primary"] == "standby-0"
        assert st["acked_batches"] == len(batches)
        # zero acked batches lost: every acked seq is applied
        prt = rs.primary.runtime
        assert prt._applied_seq == rs.acked_seq
        assert prt.n_offered == P.shape[0]
        # bit-identical to one runtime ingesting the same sequence
        assert prt.fingerprint == _reference_fingerprint(
            spec, k, caps, batches
        )
        # and the promoted primary keeps serving + accepting writes
        res = rs.query_batch([DiversityQuery(k=k)], allow_stale=False)
        assert len(res) == 1 and res[0].indices.size > 0
        rs.submit(*batches[0])
        rs.flush()
    finally:
        rs.close()


@pytest.mark.parametrize("seed", SEEDS)
def test_health_monitor_heartbeat_failures_trigger_failover(
    tmp_path, seed
):
    """``health.heartbeat`` chaos: enough consecutive probe failures
    promote the standby even though no submit ever observed an error."""
    rng = np.random.default_rng(seed)
    P, cats, caps, spec, k = _instance(rng, n=200)
    plan = FaultPlan(seed, [
        FaultRule(site="health.heartbeat", kind="error", times=None),
    ])
    rs = _make_set(spec, k, caps, tmp_path)
    mon = HealthMonitor(
        rs, HealthConfig(interval_s=0.01, failure_threshold=3)
    )
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        assert mon.probe()["healthy"]
        rs.faults = plan  # every heartbeat now fails
        statuses = [mon.probe() for _ in range(3)]
        assert not statuses[-1]["healthy"]
        assert statuses[-1]["failed_over"] == "standby-0"
        assert rs.primary.name == "standby-0"
        assert int(rs._m_failovers.value) == 1
        # the promoted primary probes healthy again
        rs.faults = None
        assert mon.probe()["healthy"]
        assert rs.primary.runtime.fingerprint is not None
    finally:
        mon.close()
        rs.close()


def test_failover_redispatches_parked_coalesced_calls(tmp_path):
    """In-window coalesced calls parked on the dying primary's frontend
    are drained un-failed and answered by the adopting frontend."""
    rng = np.random.default_rng(5)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        fe = rs.primary.frontend
        co = fe.coalescer
        assert co is not None
        # park calls directly in the window (shard threads only start on a
        # live submit, so this state is stable to inspect); drain() must
        # sweep every shard, so spread the calls across the pool
        t0 = time.perf_counter()
        parked = [
            PendingCall(
                fe.default_tenant, [DiversityQuery(k=k)], engine="auto",
                min_epoch=None, deadline=None, enq_t=t0, dispatch_by=t0,
            )
            for _ in range(2)
        ]
        for i, p in enumerate(parked):
            sh = co._shards[i % len(co._shards)]
            with sh.cv:
                sh.q.append(p)
        drained = fe.drain_pending()
        assert all(p in drained for p in parked)
        released = rs.standbys[0].frontend.adopt_pending(drained)
        assert released == len(drained)
        for p in parked:
            assert p.done.is_set()
            assert p.error is None
            assert len(p.results) == 1
            assert p.results[0].indices.size > 0
    finally:
        rs.close()


def test_most_caught_up_standby_wins_promotion(tmp_path):
    """With two standbys at different application watermarks, failover
    picks the one with the higher applied seq and replays the old
    primary's WAL tail on top."""
    rng = np.random.default_rng(6)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path, n_standbys=2)
    try:
        bs = _batches(P, cats)
        for pts, cs in bs[:4]:
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        # freeze standby-1's apply thread at seq 3; keep streaming
        sb1 = next(s for s in rs.standbys if s.name == "standby-1")
        sb1.stop(drain=False)
        behind = sb1.applied_upto
        for pts, cs in bs[4:]:
            rs.submit(pts, cs)
        rs.flush()
        sb0 = next(s for s in rs.standbys if s.name == "standby-0")
        _wait(lambda: sb0.applied_upto >= rs.acked_seq)
        assert sb1.applied_upto == behind < sb0.applied_upto
        promoted = rs.failover(reason="test")
        assert promoted == "standby-0"
        assert rs.primary.runtime._applied_seq == rs.acked_seq
        assert rs.last_failover["retired"] == "primary"
    finally:
        rs.close()


# ----------------------------------------------------------------------
# integrity auditor
# ----------------------------------------------------------------------

def test_audit_clean_stack_passes(tmp_path):
    rng = np.random.default_rng(7)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        # a query populates the pdist cache so the audit spot-checks it
        rs.query_batch([DiversityQuery(k=k)], allow_stale=False)
        aud = IntegrityAuditor(rs)
        reports = aud.audit_once()
        assert len(reports) == 2
        for r in reports:
            assert r.ok, r.violations
            assert r.checks > 0
        assert aud.total_violations == 0
        assert not rs.standbys[0].quarantined
    finally:
        rs.close()


def test_audit_catches_corrupt_pdist_cache(tmp_path):
    rng = np.random.default_rng(8)
    P, cats, caps, spec, k = _instance(rng, n=200)
    rs = _make_set(spec, k, caps, tmp_path, n_standbys=0)
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.flush()
        rs.query_batch([DiversityQuery(k=k)], allow_stale=False)
        fe = rs.primary.frontend
        with fe.cache._mu:
            entry = next(iter(fe.cache._entries.values()))
        # corrupt the cached matrix (the buffer itself is a read-only
        # device view, so swap in a corrupted host copy)
        entry.D = np.asarray(entry.D) + 10.0
        aud = IntegrityAuditor(rs, config=AuditConfig(pdist_samples=64))
        reports = aud.audit_once()
        assert any(
            v.startswith("pdist") for r in reports for v in r.violations
        )
    finally:
        rs.close()


def test_audit_catches_corrupt_state_and_quarantines(tmp_path):
    """A standby whose delegate store is corrupted in device memory
    fails the coverage (and fingerprint) checks and is quarantined —
    excluded from reads and from promotion."""
    rng = np.random.default_rng(9)
    P, cats, caps, spec, k = _instance(rng)
    rs = _make_set(spec, k, caps, tmp_path)
    try:
        for pts, cs in _batches(P, cats):
            rs.submit(pts, cs)
        rs.sync(timeout=120)
        sb = rs.standbys[0]
        rt = sb.runtime
        with rt._cv:
            st = rt._state
            rt._state = st._replace(dp=st.dp + 1.0e6)
        aud = IntegrityAuditor(rs)
        reports = aud.audit_once()
        bad = next(r for r in reports if r.replica == "standby-0")
        assert not bad.ok
        assert any(
            v.startswith(("coverage", "fingerprint"))
            for v in bad.violations
        )
        assert sb.quarantined
        assert not sb.promotable
        with pytest.raises(RuntimeError, match="no promotable standby"):
            rs.failover(reason="test")
        # the primary's report stays clean
        assert next(r for r in reports if r.replica == "primary").ok
    finally:
        rs.close()


def test_audit_single_runtime_target():
    """The auditor also works against a bare runtime (no replica set)."""
    rng = np.random.default_rng(10)
    P, cats, caps, spec, k = _instance(rng, n=200)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        registry=obs.MetricsRegistry(),
    )
    try:
        for pts, cs in _batches(P, cats):
            rt.ingest(pts, cs)
        rt.refresh(force=True)
        aud = IntegrityAuditor(rt)
        reports = aud.audit_once()
        assert len(reports) == 1 and reports[0].ok
        assert reports[0].replica == "runtime"
    finally:
        rt.close()


# ----------------------------------------------------------------------
# watermarked fingerprint history (the exchange primitive itself)
# ----------------------------------------------------------------------

def test_fingerprint_watermarks_recorded_per_ingest():
    rng = np.random.default_rng(11)
    P, cats, caps, spec, k = _instance(rng, n=200)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        registry=obs.MetricsRegistry(),
    )
    try:
        offs = []
        for pts, cs in _batches(P, cats):
            rt.ingest(pts, cs)
            offs.append(rt.n_offered)
        assert rt.fingerprint_watermarks() == offs
        for n in offs:
            assert rt.fingerprint_at(n) is not None
        assert rt.fingerprint_at(offs[-1]) == rt.fingerprint
        assert rt.fingerprint_at(offs[-1] + 7) is None
    finally:
        rt.close()
