"""Blocked-scan equivalence: blocked ingestion == per-point ingestion,
bit for bit, across block sizes, shard counts, and all three jit matroid
kinds (including the transversal add+shrink path).

This deterministic sweep always runs; the hypothesis property test over
random instances/splits lives in test_blocked_ingest_property.py (a
module-level importorskip skips its whole module when hypothesis is
missing — keeping it separate preserves this sweep).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from conftest import make_clustered_points
from repro.core.matroid import MatroidSpec
from repro.core.streaming import (
    ingest_batch,
    ingest_batch_sharded,
    init_sharded_states,
    init_stream_state,
)

BLOCKS = [1, 3, 16, 50]


def _instance(kind, seed, n):
    rng = np.random.default_rng(seed)
    P = make_clustered_points(rng, n=n, d=4, centers=4, spread=0.08)
    if kind == "uniform":
        cats = np.zeros((n, 1), np.int32)
        return P, cats, None, MatroidSpec("uniform"), 3
    if kind == "partition":
        h = 3
        cats = rng.integers(0, h, (n, 1)).astype(np.int32)
        caps = np.full(h, 2, np.int32)
        return P, cats, caps, MatroidSpec(
            "partition", num_categories=h, gamma=1
        ), 3
    h, gamma = 3, 2
    cats = np.full((n, gamma), -1, np.int32)
    cats[:, 0] = rng.integers(0, h, n)
    extra = rng.random(n) < 0.5
    cats[extra, 1] = rng.integers(0, h, extra.sum())
    # k=2 with dense clusters: delegate adds trigger the greedy-matching
    # shrink, so the equivalence covers the transversal shrink path too
    return P, cats, None, MatroidSpec(
        "transversal", num_categories=h, gamma=gamma
    ), 2


def _ingest(P, cats, caps, spec, k, tau, block_size, splits):
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    st = init_stream_state(P.shape[1], cats.shape[1], spec, k, tau)
    off = 0
    for b in splits:
        st = ingest_batch(
            st, jnp.asarray(P[off:off + b]), jnp.asarray(cats[off:off + b]),
            jnp.ones((b,), bool), spec, caps_j, k, tau, base_index=off,
            block_size=block_size,
        )
        off += b
    assert off == P.shape[0]
    return st


def _assert_states_equal(a, b, label):
    for f in a._fields:
        assert np.array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        ), f"{label}: field {f} diverged"


@pytest.mark.parametrize("kind", ["uniform", "partition", "transversal"])
def test_blocked_equals_per_point_sweep(kind):
    n, tau = 150, 8
    P, cats, caps, spec, k = _instance(kind, seed=0, n=n)
    ref = _ingest(P, cats, caps, spec, k, tau, 1, [n])
    for bs in BLOCKS[1:]:
        st = _ingest(P, cats, caps, spec, k, tau, bs, [n])
        _assert_states_equal(ref, st, f"{kind} block={bs} one-shot")
    # ragged batch splits resume mid-block
    st = _ingest(P, cats, caps, spec, k, tau, 16, [47, 30, 73])
    _assert_states_equal(ref, st, f"{kind} block=16 split")


@pytest.mark.parametrize("block_size", [16, 50])
def test_blocked_equals_per_point_diameter_variant(block_size):
    """The Alg.-2 diameter variant has its own precheck arm (thr_new and
    the d1 > 2R restructure trigger) — assert bit-identity there too."""
    n, tau = 150, 8
    P, cats, caps, spec, k = _instance("partition", seed=2, n=n)
    caps_j = jnp.asarray(caps, jnp.int32)

    def run(bs):
        st = init_stream_state(P.shape[1], 1, spec, k, tau)
        return ingest_batch(
            st, jnp.asarray(P), jnp.asarray(cats), jnp.ones((n,), bool),
            spec, caps_j, k, tau, variant="diameter", block_size=bs,
        )

    _assert_states_equal(run(1), run(block_size),
                         f"diameter block={block_size}")


@pytest.mark.parametrize("kind", ["uniform", "partition", "transversal"])
@pytest.mark.parametrize("num_shards", [2, 4])
def test_sharded_equals_per_shard_scans(kind, num_shards):
    n, tau, bs = 120, 8, 16
    P, cats, caps, spec, k = _instance(kind, seed=1, n=n)
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    S = num_shards
    d, gamma = P.shape[1], cats.shape[1]
    mm = -(-n // S)
    Pb = np.zeros((S, mm, d), np.float32)
    Cb = np.full((S, mm, gamma), -1, np.int32)
    Vb = np.zeros((S, mm), bool)
    Sb = np.full((S, mm), -1, np.int32)
    for s in range(S):
        rows = np.arange(s, n, S)
        r = len(rows)
        Pb[s, :r] = P[rows]
        Cb[s, :r] = cats[rows]
        Vb[s, :r] = True
        Sb[s, :r] = rows
    sts = ingest_batch_sharded(
        init_sharded_states(S, d, gamma, spec, k, tau),
        jnp.asarray(Pb), jnp.asarray(Cb), jnp.asarray(Vb), jnp.asarray(Sb),
        spec, caps_j, k, tau, block_size=bs,
    )
    for s in range(S):
        rows = np.arange(s, n, S)
        ref = init_stream_state(d, gamma, spec, k, tau)
        ref = ingest_batch(
            ref, jnp.asarray(P[rows]), jnp.asarray(cats[rows]),
            jnp.ones((len(rows),), bool), spec, caps_j, k, tau,
            src=jnp.asarray(rows, jnp.int32), block_size=1,
        )
        import jax

        shard = jax.tree_util.tree_map(lambda x, s=s: x[s], sts)
        _assert_states_equal(ref, shard, f"{kind} shard {s}/{S}")


