"""DistanceCache bounds: max-entries LRU eviction + TTL expiry (the
multi-tenant prerequisite — many (spec, tau, metric) keys, one cache)."""
import numpy as np
import pytest

from repro.core.matroid import MatroidSpec
from repro.serve.diversity.cache import CacheKey, DistanceCache


def _key(tau):
    return CacheKey(spec=MatroidSpec("uniform"), tau=tau, metric="euclidean")


def _build(cache, key, fp=0, m=4):
    pts = np.arange(m * 2, dtype=np.float32).reshape(m, 2)
    cats = np.zeros((m, 1), np.int32)
    src = np.arange(m, dtype=np.int64)
    return cache.build(key, pts, cats, src, fp)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_lru_eviction_keeps_recently_used():
    clock = FakeClock()
    cache = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32),
        max_entries=2, clock=clock,
    )
    _build(cache, _key(1))
    clock.t = 1.0
    _build(cache, _key(2))
    clock.t = 2.0
    assert cache.lookup(_key(1), 0) is not None  # key 1 now most recent
    clock.t = 3.0
    _build(cache, _key(3))  # exceeds max_entries=2 -> evicts LRU = key 2
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    assert cache.lookup(_key(2), 0) is None
    assert cache.lookup(_key(1), 0) is not None
    assert cache.lookup(_key(3), 0) is not None


def test_ttl_sweeps_abandoned_keys_on_build():
    """A ttl_s-only cache must reclaim entries for keys never queried again
    (abandoned tenants), not just keys that hit lookup() after expiry."""
    clock = FakeClock()
    cache = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32),
        ttl_s=10.0, clock=clock,
    )
    _build(cache, _key(1))  # tenant 1 builds, then goes silent
    clock.t = 20.0
    _build(cache, _key(2))  # any other tenant's build sweeps the expired one
    assert len(cache) == 1
    assert cache.stats.expirations == 1
    assert cache.lookup(_key(2), 0) is not None


def test_ttl_expiry_forces_rebuild():
    clock = FakeClock()
    cache = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32),
        ttl_s=10.0, clock=clock,
    )
    _build(cache, _key(1))
    clock.t = 9.0
    assert cache.lookup(_key(1), 0) is not None  # within TTL
    clock.t = 11.0
    assert cache.lookup(_key(1), 0) is None  # expired
    assert cache.stats.expirations == 1
    assert len(cache) == 0
    _build(cache, _key(1))  # rebuild resets the TTL anchor
    clock.t = 20.0
    assert cache.lookup(_key(1), 0) is not None


def test_unbounded_by_default_and_validation():
    cache = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32)
    )
    for tau in range(10):
        _build(cache, _key(tau))
    assert len(cache) == 10 and cache.stats.evictions == 0
    with pytest.raises(ValueError):
        DistanceCache(max_entries=0)


def test_sweep_is_lazy_deadline_gated():
    """The full expiry scan runs only once the earliest possible expiry
    deadline has passed — a busy cache with nothing expiring never pays a
    full sweep per insert (satellite: no sweep on every operation)."""
    clock = FakeClock()
    cache = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32),
        ttl_s=100.0, clock=clock,
    )
    for i in range(20):  # 20 inserts well inside the TTL window
        clock.t = float(i)
        _build(cache, _key(i))
        cache.lookup(_key(i), 0)
    assert cache.stats.sweeps == 0, "swept before anything could expire"
    clock.t = 150.0  # past the earliest deadline: the next insert sweeps
    _build(cache, _key(99))
    assert cache.stats.sweeps == 1
    assert cache.stats.expirations == 20
    assert len(cache) == 1
    # a ttl-less cache never sweeps at all
    plain = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32),
        max_entries=2,
    )
    for i in range(5):
        _build(plain, _key(i))
    assert plain.stats.sweeps == 0 and plain.stats.evictions == 3


def test_fingerprint_mismatch_still_invalidates():
    clock = FakeClock()
    cache = DistanceCache(
        build_fn=lambda p: np.zeros((p.shape[0],) * 2, np.float32),
        max_entries=4, ttl_s=100.0, clock=clock,
    )
    _build(cache, _key(1), fp=7)
    assert cache.lookup(_key(1), 7) is not None
    assert cache.lookup(_key(1), 8) is None  # coreset changed
    assert cache.stats.invalidations == 1
