"""Coreset composition (§3): union of per-shard coresets, shard snapshot,
and merge_stream_states re-filtering back to tau centers."""
import numpy as np
import pytest
import jax.numpy as jnp

from conftest import make_clustered_points
from repro.core.compose import (
    compact_coreset,
    merge_stream_states,
    snapshot_shards,
    union_coresets,
    unstack_shards,
)
from repro.core.matroid import MatroidSpec, PartitionMatroid
from repro.core.streaming import (
    ingest_batch,
    ingest_batch_sharded,
    init_sharded_states,
    init_stream_state,
    snapshot_coreset,
)


def _sharded_ingest(P, cats, caps_j, spec, k, tau, S, block_size=32):
    n, d = P.shape
    gamma = cats.shape[1]
    sts = init_sharded_states(S, d, gamma, spec, k, tau)
    mm = -(-n // S)
    Pb = np.zeros((S, mm, d), np.float32)
    Cb = np.full((S, mm, gamma), -1, np.int32)
    Vb = np.zeros((S, mm), bool)
    Sb = np.full((S, mm), -1, np.int32)
    for s in range(S):
        rows = np.arange(s, n, S)
        r = len(rows)
        Pb[s, :r] = P[rows]
        Cb[s, :r] = cats[rows]
        Vb[s, :r] = True
        Sb[s, :r] = rows
    return ingest_batch_sharded(
        sts, jnp.asarray(Pb), jnp.asarray(Cb), jnp.asarray(Vb),
        jnp.asarray(Sb), spec, caps_j, k, tau, block_size=block_size,
    )


def _instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


def test_sharded_ingest_equals_per_shard_loop(rng):
    P, cats, caps, spec, k = _instance(rng)
    n = P.shape[0]
    tau, S = 10, 4
    caps_j = jnp.asarray(caps)
    sts = _sharded_ingest(P, cats, caps_j, spec, k, tau, S)
    for s, shard_st in enumerate(unstack_shards(sts)):
        rows = np.arange(s, n, S)
        ref = init_stream_state(P.shape[1], 1, spec, k, tau)
        ref = ingest_batch(
            ref, jnp.asarray(P[rows]), jnp.asarray(cats[rows]),
            jnp.ones((len(rows),), bool), spec, caps_j, k, tau,
            src=jnp.asarray(rows, jnp.int32), block_size=1,
        )
        for f in ref._fields:
            assert np.array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(shard_st, f))
            ), f"shard {s} field {f}"


def test_snapshot_shards_is_union(rng):
    P, cats, caps, spec, k = _instance(rng)
    tau, S = 10, 3
    caps_j = jnp.asarray(caps)
    sts = _sharded_ingest(P, cats, caps_j, spec, k, tau, S)
    union = snapshot_shards(sts)
    manual = union_coresets(
        [snapshot_coreset(st) for st in unstack_shards(sts)]
    )
    for f in union._fields:
        assert np.array_equal(
            np.asarray(getattr(union, f)), np.asarray(getattr(manual, f))
        ), f
    _, _, src = compact_coreset(union)
    assert len(set(src.tolist())) == len(src)  # shards partition the stream


def test_merge_refilters_to_tau_centers(rng):
    P, cats, caps, spec, k = _instance(rng, n=600)
    tau, S = 8, 4
    caps_j = jnp.asarray(caps)
    sts = _sharded_ingest(P, cats, caps_j, spec, k, tau, S)
    merged = merge_stream_states(sts, spec, caps_j, k, tau)
    assert int(np.asarray(merged.cvalid).sum()) <= tau
    pts_m, cats_m, src_m = compact_coreset(snapshot_coreset(merged))
    # merged delegates keep global stream identities and their payloads
    assert set(src_m.tolist()) <= set(range(P.shape[0]))
    assert np.allclose(pts_m, P[src_m], atol=1e-6)
    assert np.array_equal(cats_m, cats[src_m])
    # the merged coreset stays feasible for the matroid
    m = PartitionMatroid(cats[:, 0], caps)
    sel = m.greedy_independent([int(s) for s in src_m], k)
    assert len(sel) == k


def test_merge_accepts_list_of_states(rng):
    P, cats, caps, spec, k = _instance(rng, n=300)
    tau = 8
    caps_j = jnp.asarray(caps)
    halves = []
    for rows in (np.arange(0, 150), np.arange(150, 300)):
        st = init_stream_state(P.shape[1], 1, spec, k, tau)
        halves.append(ingest_batch(
            st, jnp.asarray(P[rows]), jnp.asarray(cats[rows]),
            jnp.ones((len(rows),), bool), spec, caps_j, k, tau,
            src=jnp.asarray(rows, jnp.int32),
        ))
    merged = merge_stream_states(halves, spec, caps_j, k, tau)
    assert int(np.asarray(merged.cvalid).sum()) <= tau
    _, _, src_m = compact_coreset(snapshot_coreset(merged))
    assert len(src_m) > 0
    # a single unstacked state is accepted too (wrapped, not iterated)
    solo = merge_stream_states(halves[0], spec, caps_j, k, tau)
    assert int(np.asarray(solo.cvalid).sum()) <= tau
