"""Training substrate: convergence, checkpoint/resume, elastic restore,
gradient compression."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (
    compress_with_feedback,
    init_residual,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.train.train_state import StepConfig, init_train_state, make_train_step


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      schedule="constant")
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)))
    params = {"w": jnp.zeros((8, 8))}
    state = adamw_init(params, cfg)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 1e-2


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) < 0.2
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1.0) < 0.1
    assert float(lr_at(cfg, jnp.int32(100))) <= 0.11


def test_training_reduces_loss():
    """A few hundred steps on a tiny LM memorize the synthetic stream."""
    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg)
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=60, warmup_steps=5)
    state = init_train_state(lm, jax.random.PRNGKey(0), opt_cfg)
    step = jax.jit(make_train_step(lm, opt_cfg, StepConfig()))
    toks = jax.random.randint(jax.random.PRNGKey(7), (4, 32), 0, cfg.vocab)
    losses = []
    for _ in range(60):
        state, m = step(state, {"tokens": toks})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_train_state(lm, jax.random.PRNGKey(0), opt_cfg)
    step = jax.jit(make_train_step(lm, opt_cfg, StepConfig()))
    toks = jax.random.randint(jax.random.PRNGKey(9), (4, 16), 0, cfg.vocab)

    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for i in range(3):
        state, _ = step(state, {"tokens": toks})
    mgr.save(3, state)
    state_c, _ = step(state, {"tokens": toks})  # step 4 (continuous)

    # restart: restore and take the same step
    abstract = jax.eval_shape(
        lambda: init_train_state(lm, jax.random.PRNGKey(0), opt_cfg)
    )
    assert mgr.latest_step() == 3
    state_r = mgr.restore(3, abstract)
    state_r, _ = step(state_r, {"tokens": toks})
    for a, b in zip(jax.tree.leaves(state_c["params"]),
                    jax.tree.leaves(state_r["params"])):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_checkpoint_keep_n_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    # a stale .tmp dir must be invisible to restore
    os.makedirs(os.path.join(str(tmp_path), "step_0000000099.tmp"))
    assert mgr.latest_step() == 4


def test_compression_error_feedback_converges():
    """int8 error-feedback SGD reaches the optimum of a quadratic (the
    residual re-injects what quantization dropped)."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    w = jnp.zeros((64,))
    resid = init_residual({"w": w})["w"]
    for _ in range(400):
        g = 2 * (w - target)
        q, s, resid = compress_with_feedback({"w": g}, {"w": resid})
        q, s, resid = q["w"], s["w"], resid["w"]
        g_hat = q.astype(jnp.float32) * s
        w = w - 0.05 * g_hat
    assert float(jnp.max(jnp.abs(w - target))) < 5e-2


def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, num_domains=4,
                     selector_tau=4)
    p1, p2 = Pipeline(cfg), Pipeline(cfg)
    b5a = p1.batch_at(5)
    _ = p1.batch_at(6)
    b5b = p2.batch_at(5)  # fresh pipeline, direct seek
    np.testing.assert_array_equal(np.asarray(b5a["tokens"]),
                                  np.asarray(b5b["tokens"]))


def test_diverse_selection_respects_caps_and_beats_random():
    cfg = DataConfig(vocab=256, seq_len=8, global_batch=16, num_domains=4,
                     candidates_per_batch=8, selector_tau=8)
    pipe = Pipeline(cfg)
    b = pipe.batch_at(0)
    doms = np.asarray(b["domains"])
    counts = np.bincount(doms, minlength=4)
    assert counts.max() <= int(pipe.caps[0])
    # diversity: min pairwise distance of selected embeddings >= random pick
    from repro.data.pipeline import _candidate_pool

    toks, domains, emb = _candidate_pool(cfg, 0)
    emb = np.asarray(emb)

    def min_pdist(idx):
        E = emb[idx]
        D = np.sqrt(((E[:, None] - E[None]) ** 2).sum(-1))
        np.fill_diagonal(D, np.inf)
        return D.min()

    cfg2 = DataConfig(**{**cfg.__dict__, "diverse_selection": False})
    rand_idx = np.asarray(Pipeline(cfg2).batch_at(0)["domains"])  # first-16
    sel_idx = [int(i) for i in np.asarray(
        jnp.argmax(jnp.all(toks[None] == pipe.batch_at(0)["tokens"][:, None], -1), 1)
    )]
    assert min_pdist(sel_idx) >= min_pdist(list(range(16))) - 1e-6
