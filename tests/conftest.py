import os

# Tests must see exactly ONE device (the dry-run sets its own count in its
# own process). Kernel tests force the interpret/ref paths explicitly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_clustered_points(rng, n=400, d=6, centers=5, spread=0.05):
    """Low-doubling-dimension testbed: Gaussian clusters on a 2-D manifold."""
    base = rng.normal(size=(centers, d)) * 3.0
    asg = rng.integers(0, centers, n)
    return (base[asg] + spread * rng.normal(size=(n, d))).astype(np.float32)
