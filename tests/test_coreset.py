"""Coreset constructions: the (1-eps) guarantee, size bounds, composability.

The headline property test: on instances small enough for exhaustive search,
div(best solution within coreset) >= (1-eps) * div(best solution in S),
for every matroid type x every Table-1 objective — the Definition-3 coreset
property, verified end to end.
"""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from conftest import make_clustered_points
from repro.core.coreset import (
    concat_coresets,
    default_capacity,
    seq_coreset,
    seq_coreset_host,
)
from repro.core.diversity import VARIANTS, diversity
from repro.core.exhaustive import exhaustive_best
from repro.core.geometry import dists
from repro.core.matroid import (
    GeneralMatroid,
    MatroidSpec,
    PartitionMatroid,
    TransversalMatroid,
    make_host_matroid,
)


def _exhaustive_opt(P, matroid, k, variant):
    D = np.asarray(dists(jnp.asarray(P), jnp.asarray(P)))
    _, val, complete = exhaustive_best(D, matroid, k, range(len(P)), variant)
    assert complete
    return val


CASES = [
    ("partition", "sum"), ("partition", "star"), ("partition", "tree"),
    ("partition", "cycle"), ("partition", "bipartition"),
    ("transversal", "sum"), ("transversal", "tree"),
]


@pytest.mark.parametrize("matroid_kind,variant", CASES)
def test_one_minus_eps_guarantee(matroid_kind, variant):
    """Definition 3 with the Alg.-1 radius-target construction, eps = 0.5."""
    rng = np.random.default_rng(hash((matroid_kind, variant)) % 2**31)
    n, h, k, eps = 60, 3, 4, 0.5
    P = make_clustered_points(rng, n=n, d=4, centers=6, spread=0.03)
    if matroid_kind == "partition":
        cats = rng.integers(0, h, (n, 1)).astype(np.int32)
        caps = np.full(h, 2, np.int32)
        spec = MatroidSpec("partition", num_categories=h, gamma=1)
        matroid = PartitionMatroid(cats[:, 0], caps)
    else:
        cats = np.full((n, 2), -1, np.int32)
        cats[:, 0] = rng.integers(0, h, n)
        extra = rng.random(n) < 0.4
        cats[extra, 1] = rng.integers(0, h, extra.sum())
        caps = None
        spec = MatroidSpec("transversal", num_categories=h, gamma=2)
        matroid = TransversalMatroid(cats, h)

    opt = _exhaustive_opt(P, matroid, k, variant)
    sel, info = seq_coreset_host(
        P, cats, spec, caps, k, eps=eps, metric="euclidean"
    )
    D = np.asarray(dists(jnp.asarray(P), jnp.asarray(P)))
    _, val, complete = exhaustive_best(D, matroid, k, sel, variant)
    assert complete
    assert val >= (1 - eps) * opt - 1e-6, (val, opt, info)


def test_general_matroid_coreset():
    """Thm 3: general-matroid construction (oracle-backed) is a coreset."""
    rng = np.random.default_rng(5)
    n, k = 40, 3
    P = make_clustered_points(rng, n=n, d=4, centers=5, spread=0.02)
    # a 'laminar-ish' custom matroid: at most 2 from the first half,
    # at most 2 from the second half, at most 3 total
    def oracle(idxs):
        a = sum(1 for i in idxs if i < n // 2)
        b = len(idxs) - a
        return a <= 2 and b <= 2 and len(idxs) <= 3

    m = GeneralMatroid(n, oracle)
    spec = MatroidSpec("general")
    opt = _exhaustive_opt(P, m, k, "sum")
    sel, _ = seq_coreset_host(P, None, spec, None, k, eps=0.5, oracle=oracle)
    D = np.asarray(dists(jnp.asarray(P), jnp.asarray(P)))
    _, val, _ = exhaustive_best(D, m, k, sel, "sum")
    assert val >= 0.5 * opt - 1e-6


def test_jit_seq_coreset_matches_host_partition(rng):
    """Fixed-tau jit construction selects a superset-equivalent coreset of
    the host Algorithm 1 for partition matroids (same GMM, same EXTRACT)."""
    n, h, k, tau = 120, 4, 3, 8
    P = make_clustered_points(rng, n=n, d=5)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    sel_host, _ = seq_coreset_host(P, cats, spec, caps, k, tau=tau)
    cs, res, ovf = seq_coreset(
        jnp.asarray(P), jnp.asarray(cats), jnp.ones((n,), bool),
        spec, jnp.asarray(caps), k, tau,
    )
    assert int(ovf) == 0
    sel_jit = np.sort(np.asarray(cs.src_idx)[np.asarray(cs.valid)])
    np.testing.assert_array_equal(sel_jit, sel_host)


def test_capacity_bounds(rng):
    """Thm 1: partition coreset size <= k * tau, never overflows."""
    n, h, k, tau = 200, 5, 4, 10
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    cs, _res, ovf = seq_coreset(
        jnp.asarray(P), jnp.asarray(cats), jnp.ones((n,), bool),
        spec, jnp.asarray(caps), k, tau,
    )
    assert int(ovf) == 0
    assert int(cs.size()) <= k * tau
    assert cs.capacity == default_capacity(spec, k, tau)


def test_composability(rng):
    """Union of per-shard coresets contains a (1-eps)-quality solution —
    the property that makes the MR construction correct (Thm 6)."""
    n, h, k = 80, 3, 4
    P = make_clustered_points(rng, n=n, d=4, centers=5, spread=0.03)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    matroid = PartitionMatroid(cats[:, 0], caps)
    opt = _exhaustive_opt(P, matroid, k, "sum")

    shards = 4
    parts = []
    for s in range(shards):
        sl = slice(s * n // shards, (s + 1) * n // shards)
        cs, _r, _o = seq_coreset(
            jnp.asarray(P[sl]), jnp.asarray(cats[sl]),
            jnp.ones((n // shards,), bool), spec, jnp.asarray(caps), k, 6,
            base_index=jnp.int32(s * n // shards),
        )
        parts.append(cs)
    union = concat_coresets(parts)
    sel = np.asarray(union.src_idx)[np.asarray(union.valid)]
    D = np.asarray(dists(jnp.asarray(P), jnp.asarray(P)))
    _, val, _ = exhaustive_best(D, matroid, k, sel, "sum")
    assert val >= 0.5 * opt  # eps=0.5-class quality from tau=6/shard
