"""Gonzalez GMM clustering: invariants + the Alg.-1 stopping rule."""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import make_clustered_points
from repro.core.gmm import gmm, gmm_fixed, gmm_radius


def test_assignment_is_nearest_center(rng):
    pts = make_clustered_points(rng, n=300)
    res = gmm_fixed(jnp.asarray(pts), jnp.ones((300,), bool), 10)
    centers = np.asarray(res.centers)[: int(res.num_centers)]
    P = np.asarray(pts)
    D = np.sqrt(((P[:, None] - P[None, centers]) ** 2).sum(-1))
    # min_dist matches distance to assigned center and is the row min
    assign = np.asarray(res.assign)
    md = np.asarray(res.min_dist)
    np.testing.assert_allclose(md, D.min(axis=1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        md, D[np.arange(300), assign], rtol=1e-5, atol=1e-5
    )


def test_radius_2approx(rng):
    """Gonzalez guarantee: gmm radius(tau) <= 2 * r*_tau <= 2 * radius of ANY
    concrete tau-clustering (we build one with k-means)."""
    centers = 5
    pts = make_clustered_points(rng, n=500, centers=centers, spread=0.02)
    P = np.asarray(pts)
    res = gmm_fixed(jnp.asarray(pts), jnp.ones((500,), bool), centers)
    gmm_radius_val = float(res.radius)
    # construct one concrete 5-clustering: true generator assignment
    # (recover by proximity to cluster means)
    from scipy.cluster.vq import kmeans2

    centroids, labels = kmeans2(P, centers, minit="++", seed=1)
    r_ref = 0.0
    for c in range(centers):
        m = labels == c
        if m.any():
            # radius around the member closest to the centroid
            d = np.sqrt(((P[m] - centroids[c]) ** 2).sum(-1))
            anchor = P[m][np.argmin(d)]
            r_ref = max(r_ref, np.sqrt(((P[m] - anchor) ** 2).sum(-1)).max())
    assert gmm_radius_val <= 2.0 * r_ref + 1e-5


def test_delta_brackets_diameter(rng):
    pts = make_clustered_points(rng, n=200)
    P = np.asarray(pts)
    res = gmm_fixed(jnp.asarray(pts), jnp.ones((200,), bool), 4)
    diam = np.sqrt(((P[:, None] - P[None]) ** 2).sum(-1)).max()
    delta = float(res.delta)
    assert diam / 2 - 1e-6 <= delta <= diam + 1e-6


def test_radius_target_stopping(rng):
    """Alg. 1: stop when radius <= eps*delta/(16k)."""
    pts = make_clustered_points(rng, n=400, centers=8, spread=0.01)
    k, eps = 3, 0.8
    res = gmm_radius(jnp.asarray(pts), jnp.ones((400,), bool), k, eps, 400)
    target = eps * float(res.delta) / (16 * k)
    assert float(res.radius) <= target
    # and it should not have used absurdly many centers on clustered data
    assert int(res.num_centers) < 400


def test_masked_points_ignored(rng):
    pts = np.concatenate(
        [make_clustered_points(rng, n=100), 1e6 * np.ones((5, 6), np.float32)]
    )
    valid = np.ones(105, bool)
    valid[100:] = False
    res = gmm_fixed(jnp.asarray(pts), jnp.asarray(valid), 6)
    centers = np.asarray(res.centers)[: int(res.num_centers)]
    assert all(c < 100 for c in centers)
    assert float(res.radius) < 1e3
