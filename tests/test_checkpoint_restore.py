"""Restore parity: checkpoint -> kill -> restore -> WAL replay yields a
stream bit-identical to the uninterrupted run — coreset buffers, epoch
fingerprint, and query answers — across all placement drives, through a
mid-shrink checkpoint, and from the WAL alone.

The guarantee is the paper's §3 composability made operational: a
``StreamState`` is a pure fold over the batch sequence under a
deterministic scan, so (serialized state) + (replayed tail, in
submission order) IS the state the dead process would have reached.
"""
import os
import threading

import numpy as np
import pytest

from conftest import make_clustered_points
from repro.core.matroid import MatroidSpec
from repro.serve.diversity import (
    DiversityQuery,
    DiversityService,
    DurabilityConfig,
    FaultPlan,
    FaultRule,
    InjectedCrash,
    StreamRuntime,
    WriteAheadLog,
    latest_checkpoint,
    list_checkpoints,
)

PLACEMENTS = [
    ("vmap", 1),  # resolves to the single-shard scan
    ("vmap", 4),
    ("shard_map", 4),
    ("pipeline", 4),
]


def _instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


def _batches(P, cats, size):
    return [
        (P[off:off + size], cats[off:off + size])
        for off in range(0, P.shape[0], size)
    ]


def _assert_state_equal(a, b):
    """Bit-identical scan state(s): every field of every shard."""
    if isinstance(a, list):
        assert isinstance(b, list) and len(a) == len(b)
        for sa, sb in zip(a, b):
            _assert_state_equal(sa, sb)
        return
    for f in a._fields:
        assert np.array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        ), f


@pytest.mark.parametrize("placement,num_shards", PLACEMENTS)
def test_restore_is_bit_identical_across_placements(
    rng, tmp_path, placement, num_shards
):
    """Durable async run with a mid-stream checkpoint, abandoned without
    close() (the 'kill'); restore must replay the WAL tail to the exact
    pre-kill stream, matching the uninterrupted synchronous run."""
    P, cats, caps, spec, k = _instance(rng)
    batches = _batches(P, cats, 50)
    dur = DurabilityConfig(dir=str(tmp_path), checkpoint_every=10 ** 9)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        num_shards=num_shards, placement=placement, durability=dur,
    )
    half = len(batches) // 2
    for pts, cs in batches[:half]:
        rt.submit(pts, cs)
    rt.flush()
    assert rt.checkpoint(force=True) is not None
    for pts, cs in batches[half:]:
        rt.submit(pts, cs)
    rt.flush()
    live = rt.latest()
    # "kill": no close(), no final checkpoint — the WAL tail holds the
    # second half of the stream
    restored = StreamRuntime.restore(str(tmp_path))
    rep = restored.restore_report
    assert rep["checkpoint"] is not None
    assert rep["replayed_batches"] == len(batches) - half
    got = restored.latest()
    assert got.fingerprint == live.fingerprint
    assert restored.n_offered == rt.n_offered == P.shape[0]
    assert np.array_equal(got.points, live.points)
    assert np.array_equal(got.cats, live.cats)
    assert np.array_equal(got.src_idx, live.src_idx)
    _assert_state_equal(restored.state, rt.state)
    # ... and both match the uninterrupted synchronous reference
    ref = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        num_shards=num_shards, placement=placement,
    )
    for pts, cs in batches:
        ref.ingest(pts, cs)
    assert ref.refresh(force=True).fingerprint == got.fingerprint
    _assert_state_equal(restored.state, ref.state)
    restored.close()
    ref.close()


def test_restore_preserves_query_answers(rng, tmp_path):
    """Same coreset -> same answers: queries on the restored service are
    bit-identical to the uninterrupted one's."""
    P, cats, caps, spec, k = _instance(rng)
    svc = DiversityService(
        spec, k, tau=12, caps=caps, block_size=32,
        durability=str(tmp_path),
    )
    for pts, cs in _batches(P, cats, 80):
        svc.ingest(pts, cs)
    ref_sum = svc.query(DiversityQuery(k=k))
    ref_star = svc.query(DiversityQuery(k=3, variant="star"))
    svc.close()
    back = DiversityService.restore(str(tmp_path))
    assert back.runtime.restore_report["fingerprint"] is not None
    got_sum = back.query(DiversityQuery(k=k))
    got_star = back.query(DiversityQuery(k=3, variant="star"))
    assert got_sum.indices.tolist() == ref_sum.indices.tolist()
    assert got_sum.diversity == ref_sum.diversity
    assert got_star.indices.tolist() == ref_star.indices.tolist()
    assert got_star.diversity == ref_star.diversity
    back.close()


def test_mid_shrink_checkpoint_restores_exactly(rng, tmp_path):
    """tau small enough that the scan shrinks (R doubles) repeatedly;
    a checkpoint after EVERY batch means the newest one lands mid-shrink
    wherever the shrink happens — restore parity must hold anyway."""
    P, cats, caps, spec, k = _instance(rng, n=600)
    batches = _batches(P, cats, 40)
    dur = DurabilityConfig(dir=str(tmp_path), checkpoint_every=1, keep=2)
    rt = StreamRuntime(
        spec, k, tau=8, caps=caps, block_size=32, durability=dur,
    )
    for pts, cs in batches:
        rt.ingest(pts, cs)
    live = rt.refresh(force=True)
    assert len(list_checkpoints(str(tmp_path))) <= 2  # keep= pruned
    restored = StreamRuntime.restore(str(tmp_path))
    got = restored.latest()
    assert got.fingerprint == live.fingerprint
    assert np.array_equal(got.points, live.points)
    _assert_state_equal(restored.state, rt.state)
    restored.close()
    rt.close()


def test_wal_only_restore_replays_the_whole_stream(rng, tmp_path):
    """No checkpoint ever taken: restore rebuilds the stream from the
    WAL alone, given the constructor config as overrides."""
    P, cats, caps, spec, k = _instance(rng, n=200)
    dur = DurabilityConfig(dir=str(tmp_path), checkpoint_every=10 ** 9)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32, durability=dur,
    )
    for pts, cs in _batches(P, cats, 50):
        rt.submit(pts, cs)
    rt.flush()
    live = rt.latest()
    assert latest_checkpoint(str(tmp_path)) is None
    restored = StreamRuntime.restore(
        str(tmp_path), spec=spec, k=k, tau=12, caps=caps, block_size=32,
    )
    assert restored.restore_report["checkpoint"] is None
    assert restored.restore_report["replayed_batches"] == 4
    assert restored.latest().fingerprint == live.fingerprint
    _assert_state_equal(restored.state, rt.state)
    restored.close()
    # without the config, WAL-only restore must refuse loudly
    with pytest.raises(ValueError, match="WAL-only"):
        StreamRuntime.restore(str(tmp_path) + "-nothing-here")


def test_wal_survives_torn_tail(rng, tmp_path):
    """A crash mid-append leaves a torn record; replay stops cleanly at
    the last whole record and restore still succeeds."""
    P, cats, caps, spec, k = _instance(rng, n=150)
    dur = DurabilityConfig(dir=str(tmp_path), checkpoint_every=10 ** 9)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32, durability=dur,
    )
    batches = _batches(P, cats, 50)
    for pts, cs in batches:
        rt.submit(pts, cs)
    rt.flush()
    # tear the tail: chop the last record mid-payload
    wal_path = dur.wal_path
    size = os.path.getsize(wal_path)
    with open(wal_path, "r+b") as f:
        f.truncate(size - 37)
    restored = StreamRuntime.restore(
        str(tmp_path), spec=spec, k=k, tau=12, caps=caps, block_size=32,
    )
    # the torn (last) batch is gone; everything whole replayed
    assert restored.restore_report["replayed_batches"] == len(batches) - 1
    assert restored.n_offered == P.shape[0] - batches[-1][0].shape[0]
    ref = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    for pts, cs in batches[:-1]:
        ref.ingest(pts, cs)
    assert (
        ref.refresh(force=True).fingerprint
        == restored.latest().fingerprint
    )
    restored.close()
    ref.close()


def test_wal_compaction_keeps_replay_correct(rng, tmp_path):
    """Checkpoint-driven compaction drops only records the oldest
    retained checkpoint already covers; restore stays exact."""
    P, cats, caps, spec, k = _instance(rng)
    dur = DurabilityConfig(dir=str(tmp_path), checkpoint_every=2, keep=2)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32, durability=dur,
    )
    for pts, cs in _batches(P, cats, 40):
        rt.submit(pts, cs)
    rt.flush()
    live = rt.latest()
    # cadence checkpoints ran and compacted: the log must not contain
    # records at or below the oldest retained checkpoint's watermark
    wal = WriteAheadLog(dur.wal_path)
    seqs = [rec.seq for rec in wal.replay()]
    assert len(seqs) < 10  # compaction actually dropped something
    restored = StreamRuntime.restore(str(tmp_path))
    assert restored.latest().fingerprint == live.fingerprint
    _assert_state_equal(restored.state, rt.state)
    restored.close()
    rt.close()


def test_sync_ingest_while_pending_refuses_on_durable_runtime(
    rng, tmp_path
):
    """Interleaving sync ingest between in-flight async batches would
    break WAL replay order — the durable runtime refuses it."""
    P, cats, caps, spec, k = _instance(rng, n=100)
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        durability=str(tmp_path),
    )
    # no pending batches: sync ingest on a durable runtime is fine
    rt.ingest(P[:50], cats[:50])
    with rt._cv:
        rt._pending = 1  # simulate an in-flight async batch
        with pytest.raises(RuntimeError, match="replay order"):
            rt.ingest(P[50:], cats[50:])
        rt._pending = 0
    rt.close()


def _ref_fp(spec, k, caps, batches):
    ref = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    for pts, cs in batches:
        ref.ingest(pts, cs)
    fp = ref.refresh(force=True).fingerprint
    ref.close()
    return fp


@pytest.mark.parametrize("generation", ["old", "new"])
def test_compaction_crash_restores_from_either_generation(
    rng, tmp_path, generation
):
    """A crash mid-compaction — after the replacement log is fully
    written, around the atomic swap — leaves BOTH WAL generations on
    disk. Whichever one survives (old superset log, or the compacted
    replacement if the crash landed just after the swap), the stream
    restores bit-identically, keeps accepting appends, and restores
    bit-identically again."""
    P, cats, caps, spec, k = _instance(rng)
    batches = _batches(P, cats, 40)  # 10 batches
    dur = DurabilityConfig(
        dir=str(tmp_path), checkpoint_every=10 ** 9, keep=1
    )
    plan = FaultPlan(13, [
        # the first compaction (mid-stream checkpoint) succeeds; the
        # second crashes between replacement-write and swap
        FaultRule(site="wal.compact", kind="crash", after=1, times=1),
    ])
    rt = StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32, durability=dur,
        faults=plan,
    )
    for pts, cs in batches[:5]:
        rt.submit(pts, cs)
    rt.flush()
    assert rt.checkpoint(force=True) is not None  # compaction #1 is clean
    for pts, cs in batches[5:8]:
        rt.submit(pts, cs)
    rt.flush()
    with pytest.raises(InjectedCrash):
        rt.checkpoint(force=True)  # checkpoint saved; compaction #2 dies
    # both generations exist at the crash point
    tmp_log = dur.wal_path + ".compact"
    assert os.path.exists(dur.wal_path) and os.path.exists(tmp_log)
    if generation == "new":
        # emulate a crash immediately AFTER the atomic swap
        os.replace(tmp_log, dur.wal_path)
    # "kill" the primary (no close); restore from whatever survived
    back = StreamRuntime.restore(str(tmp_path))
    assert back.latest().fingerprint == _ref_fp(
        spec, k, caps, batches[:8]
    )
    _assert_state_equal(back.state, rt.state)
    # the survivor log accepts appends and round-trips again
    for pts, cs in batches[8:]:
        back.submit(pts, cs)
    back.flush()
    live_state = back.state
    back.close()
    again = StreamRuntime.restore(str(tmp_path))
    assert again.latest().fingerprint == _ref_fp(spec, k, caps, batches)
    _assert_state_equal(again.state, live_state)
    again.close()


def test_restore_races_concurrent_submit_and_query(rng, tmp_path):
    """``DiversityService.restore`` hands a live stream straight to
    traffic: readers racing a writer across the restart never see a torn
    epoch, and the pre-kill ``min_epoch`` contract carries across the
    handoff (the epoch counter is restored, not reset)."""
    P, cats, caps, spec, k = _instance(rng, n=600)
    batches = _batches(P, cats, 50)  # 12 batches
    svc = DiversityService(
        spec, k, tau=12, caps=caps, block_size=32,
        durability=str(tmp_path),
    )
    for pts, cs in batches[:3]:
        svc.ingest(pts, cs)
    svc.runtime.checkpoint(force=True)
    for pts, cs in batches[3:6]:
        svc.ingest(pts, cs)
    e_old = svc.frontend.flush()
    assert e_old >= 0
    # "kill": no close — the second half of the pre-kill stream lives
    # only in the WAL tail past the mid-stream checkpoint
    back = DiversityService.restore(str(tmp_path))
    # min_epoch contract across the handoff: an epoch token issued by
    # the dead service is still satisfiable on the restored one
    res = back.frontend.query_batch(
        [DiversityQuery(k=k)], min_epoch=e_old
    )
    assert res[0].epoch >= e_old

    stop = threading.Event()
    errors: list = []
    results: list = []

    def _reader():
        try:
            while not stop.is_set():
                for r in back.frontend.query_batch(
                    [DiversityQuery(k=k), DiversityQuery(k=3)]
                ):
                    results.append(r)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    readers = [threading.Thread(target=_reader) for _ in range(3)]
    for t in readers:
        t.start()
    try:
        # the writer races the readers through the restored runtime
        for pts, cs in batches[6:]:
            back.runtime.submit(pts, cs)
        e_new = back.frontend.flush()
        assert e_new > e_old
        # read-your-writes still holds under concurrency
        r = back.frontend.query_batch(
            [DiversityQuery(k=k)], min_epoch=e_new
        )[0]
        assert r.epoch >= e_new
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=60)
    assert not errors
    # no torn epochs: every racing read was answered from a published
    # snapshot — valid unique in-range indices, never empty
    assert results
    for r in results:
        assert r.epoch >= 0
        assert r.indices.size > 0
        assert np.unique(r.indices).size == r.indices.size
        assert int(r.indices.max()) < P.shape[0]
    # and the final stream equals the uninterrupted reference
    assert back.runtime.latest().fingerprint == _ref_fp(
        spec, k, caps, batches
    )
    back.close()
