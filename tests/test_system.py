"""End-to-end behaviour of the paper's system (sequential/streaming against
the AMT full-input baseline), plus serving-engine and HLO-cost integration.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import make_clustered_points
from repro.core import (
    PartitionMatroid,
    TransversalMatroid,
    local_search_sum,
    solve_dmmc,
)
from repro.core.geometry import dists, normalize_for_metric
from repro.core.matroid import MatroidSpec


@pytest.fixture(scope="module")
def instance():
    rng = np.random.default_rng(11)
    n, h, k = 1500, 5, 5
    P = make_clustered_points(rng, n=n, d=8, centers=7, spread=0.05)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    return P, cats, caps, h, k


def _amt_baseline(P, cats, caps, k):
    m = PartitionMatroid(cats[:, 0], caps)
    Pn = np.asarray(normalize_for_metric(jnp.asarray(P), "euclidean"))
    D = np.asarray(dists(jnp.asarray(Pn), jnp.asarray(Pn)))
    _, val, _ = local_search_sum(D, m, k, range(len(P)))
    return val


def test_sequential_matches_amt_quality(instance):
    P, cats, caps, h, k = instance
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    base = _amt_baseline(P, cats, caps, k)
    sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=64,
                     setting="sequential")
    assert sol.diversity >= 0.95 * base, (sol.diversity, base)
    m = PartitionMatroid(cats[:, 0], caps)
    assert m.is_independent(list(sol.indices))
    assert len(sol.indices) == k
    # the whole point: the solver ran on a coreset << n
    assert sol.coreset_size < len(P) // 3


def test_streaming_close_to_sequential(instance):
    P, cats, caps, h, k = instance
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    seq = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=64,
                     setting="sequential")
    stm = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=64,
                     setting="streaming")
    # paper Fig. 3: streaming slightly below SeqCoreset quality
    assert stm.diversity >= 0.80 * seq.diversity


def test_cosine_metric_path(instance):
    P, cats, caps, h, k = instance
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=32,
                     setting="sequential", metric="cosine")
    assert len(sol.indices) == k and sol.diversity > 0


def test_all_variants_feasible_solutions(instance):
    P, cats, caps, h, k = instance
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    sub = P[:300]
    subcats = cats[:300]
    for variant in ("sum", "star", "tree", "cycle", "bipartition"):
        sol = solve_dmmc(sub, 4, spec, cats=subcats, caps=caps, tau=8,
                         variant=variant, setting="sequential")
        msub = PartitionMatroid(subcats[:, 0], caps)
        assert msub.is_independent(list(sol.indices)), variant
        assert len(sol.indices) == 4
        assert sol.diversity > 0


def test_serving_engine_greedy_decode():
    from repro.configs import get_config
    from repro.models import LM
    from repro.serve.engine import Engine

    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = Engine(lm, params, max_len=24)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    out = eng.generate(prompt, steps=6)
    assert out.shape == (2, 6)
    # reference: recompute greedily with full forwards
    seq = np.asarray(prompt)
    for t in range(6):
        logits, _, _ = lm.forward(params, jnp.asarray(seq), remat=False)
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        np.testing.assert_array_equal(nxt, np.asarray(out[:, t]))
        seq = np.concatenate([seq, nxt[:, None]], axis=1)


def test_hlo_cost_loop_aware_exact():
    """The loop-aware cost parser counts scan-body flops x trip count
    exactly (the calibration case from EXPERIMENTS.md)."""
    from repro.launch.hlo_cost import analyze

    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    co = jax.jit(
        lambda ws, x: jax.lax.scan(
            lambda c, w: (jnp.tanh(c @ w), None), x, ws
        )[0].sum()
    ).lower(ws, x).compile()
    res = analyze(co.as_text())
    assert res["flops"] == 2 * 16 * 64 * 64 * 7
