"""Seeded chaos suite for the serving stack's fault-tolerance subsystem.

Every fault here comes from a deterministic ``FaultPlan``: the schedule
is a pure function of the seed and each site's hit ordinals, so the
suite asserts *exact* post-fault state (bit-identical streams, exact
retry/poison counts) and passes identically on every run. CI sweeps the
seed matrix via the ``CHAOS_SEEDS`` env var (comma-separated ints).
"""
import os
import time

import numpy as np
import pytest

from conftest import make_clustered_points
from repro import obs
from repro.core.matroid import MatroidSpec
from repro.serve.diversity import (
    DiversityQuery,
    DurabilityConfig,
    FaultPlan,
    FaultPolicy,
    FaultRule,
    QueryFrontend,
    StreamRuntime,
    WalError,
    WriteAheadLog,
)

SEEDS = tuple(
    int(s) for s in os.environ.get("CHAOS_SEEDS", "101,202,303,404").split(",")
)


def _instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


def _batches(P, cats, size=50):
    return [
        (P[off:off + size], cats[off:off + size])
        for off in range(0, P.shape[0], size)
    ]


def _make_runtime(spec, k, caps, *, registry=None, **kw):
    return StreamRuntime(
        spec, k, tau=12, caps=caps, block_size=32,
        registry=registry if registry is not None else obs.MetricsRegistry(),
        **kw,
    )


def _reference_fingerprint(spec, k, caps, batches):
    ref = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    for pts, cs in batches:
        ref.ingest(pts, cs)
    fp = ref.refresh(force=True).fingerprint
    ref.close()
    return fp


# ----------------------------------------------------------------------
# the harness itself
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_fault_plan_is_deterministic(seed):
    """Same seed -> identical fire schedule, independent of what other
    sites see in between (per-rule generators keyed on site ordinals)."""
    rules = [
        FaultRule(site="a", kind="error", p=0.5, times=None),
        FaultRule(site="b", kind="error", p=0.3, times=None, every=2),
    ]
    p1, p2 = FaultPlan(seed, rules), FaultPlan(seed, rules)
    sched1, sched2 = [], []
    for plan, out in ((p1, sched1), (p2, sched2)):
        for i in range(200):
            for site in ("a", "b"):
                # plan 2 sees 3x the "b" traffic; "a"'s decision
                # sequence must not shift (per-rule generators)
                reps = 3 if site == "b" and plan is p2 else 1
                for _ in range(reps):
                    try:
                        plan.check(site)
                        out.append((site, i, False))
                    except Exception:
                        out.append((site, i, True))
    a1 = [x for x in sched1 if x[0] == "a"]
    a2 = [x for x in sched2 if x[0] == "a"]
    assert a1 == a2
    assert p1.fired("a") == p2.fired("a") > 0
    other = FaultPlan(seed + 1, rules)
    for i in range(200):
        try:
            other.check("a")
        except Exception:
            pass
    # a different seed draws a different schedule (overwhelmingly)
    assert [f["hit"] for f in other.fires()] != [
        f["hit"] for f in p1.fires() if f["site"] == "a"
    ]


# ----------------------------------------------------------------------
# supervised worker: crash -> restart -> bit-identical stream
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_worker_crash_restart_is_bit_identical(rng, seed):
    P, cats, caps, spec, k = _instance(rng)
    batches = _batches(P, cats)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(seed, [
        FaultRule(site="worker.loop", kind="crash",
                  after=seed % 3, times=2, every=2),
    ])
    rt = _make_runtime(
        spec, k, caps, registry=reg, faults=plan,
        fault_policy=FaultPolicy(max_worker_restarts=5),
    )
    for pts, cs in batches:
        rt.submit(pts, cs)
    rt.flush()  # must not raise: the supervisor absorbed the crashes
    fp = rt.latest().fingerprint
    assert rt.n_offered == P.shape[0]
    crashes = reg.counter("serve.worker.crashes").value
    assert crashes == plan.fired("worker.loop") == 2
    assert reg.counter("serve.worker.restarts").value == crashes
    assert reg.counter("serve.worker.errors").value == 0
    rt.close()
    assert fp == _reference_fingerprint(spec, k, caps, batches)


def test_worker_restarts_exhausted_surfaces_one_error(rng):
    P, cats, caps, spec, k = _instance(rng, n=200)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(0, [
        FaultRule(site="worker.loop", kind="crash", times=None),
    ])
    rt = _make_runtime(
        spec, k, caps, registry=reg, faults=plan,
        fault_policy=FaultPolicy(max_worker_restarts=2),
    )
    # the storm may exhaust restarts while we are still submitting, so
    # the error can surface on a later submit() or on the flush() —
    # either way it is the same single failure
    with pytest.raises(RuntimeError, match="worker failed"):
        for pts, cs in _batches(P, cats):
            rt.submit(pts, cs)
        rt.flush()
    # crash storms don't inflate the error count: exactly one failure
    # surfaced, however many times callers re-raise it
    assert reg.counter("serve.worker.errors").value == 1
    assert reg.counter("serve.worker.restarts").value == 2
    with pytest.raises(RuntimeError, match="worker failed"):
        rt.flush()
    assert reg.counter("serve.worker.errors").value == 1
    rt.close()


# ----------------------------------------------------------------------
# retry/backoff + poison queue
# ----------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_transient_errors_retry_to_success(rng, seed):
    P, cats, caps, spec, k = _instance(rng)
    batches = _batches(P, cats)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(seed, [
        FaultRule(site="worker.ingest", kind="error",
                  after=seed % 4, times=3, every=3),
    ])
    rt = _make_runtime(
        spec, k, caps, registry=reg, faults=plan,
        fault_policy=FaultPolicy(max_retries=3, backoff_s=0.01),
    )
    for pts, cs in batches:
        rt.submit(pts, cs)
    rt.flush()
    fp = rt.latest().fingerprint
    # every injected error was retried away: no failures, no truncation,
    # and (faults fire once per attempt ordinal) retries == fires
    assert reg.counter("serve.worker.errors").value == 0
    assert reg.counter("serve.worker.retries").value == plan.fired(
        "worker.ingest"
    ) == 3
    assert len(rt.poison) == 0
    rt.close()
    assert fp == _reference_fingerprint(spec, k, caps, batches)


@pytest.mark.parametrize("seed", SEEDS)
def test_poison_queue_quarantines_and_stream_continues(rng, seed):
    P, cats, caps, spec, k = _instance(rng)
    batches = _batches(P, cats)
    reg = obs.MetricsRegistry()
    max_retries = 1
    # enough consecutive fires to exhaust one batch's attempt budget:
    # that batch quarantines, later batches must keep flowing
    plan = FaultPlan(seed, [
        FaultRule(site="worker.ingest", kind="error",
                  after=2, times=max_retries + 1),
    ])
    rt = _make_runtime(
        spec, k, caps, registry=reg, faults=plan,
        fault_policy=FaultPolicy(
            max_retries=max_retries, backoff_s=0.01,
            on_failure="quarantine",
        ),
    )
    for pts, cs in batches:
        rt.submit(pts, cs)
    rt.flush()  # must NOT raise: quarantine keeps the stream alive
    assert len(rt.poison) == 1
    bad = rt.poison[0]
    assert bad.attempts == max_retries + 1
    assert reg.counter("serve.worker.errors").value == 1  # once per batch
    assert reg.counter("serve.worker.poisoned").value == 1
    # exactly one batch's points are missing from the stream
    assert rt.n_offered == P.shape[0] - bad.points.shape[0]
    # parity with a reference stream that skips the poisoned batch
    kept = [
        b for b in batches if b[0].shape[0] != bad.points.shape[0]
        or not np.array_equal(b[0], bad.points)
    ]
    assert rt.latest().fingerprint == _reference_fingerprint(
        spec, k, caps, kept
    )
    # the quarantined data is intact for re-submission
    rt.submit(bad.points, bad.cats)
    rt.flush()
    assert rt.n_offered == P.shape[0]
    rt.close()


# ----------------------------------------------------------------------
# WAL + checkpoint fault paths
# ----------------------------------------------------------------------

def test_wal_append_failure_surfaces_to_submitter(rng, tmp_path):
    P, cats, caps, spec, k = _instance(rng, n=150)
    batches = _batches(P, cats)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(0, [
        FaultRule(site="wal.append", kind="error", after=1, times=1),
    ])
    rt = _make_runtime(
        spec, k, caps, registry=reg, faults=plan,
        durability=str(tmp_path),
    )
    rt.submit(*batches[0])
    with pytest.raises(WalError, match="not durable"):
        rt.submit(*batches[1])  # rejected at the door, not enqueued
    rt.submit(*batches[2])  # the stream is still healthy
    rt.flush()
    assert reg.counter("serve.wal.append_errors").value == 1
    assert rt.n_offered == batches[0][0].shape[0] + batches[2][0].shape[0]
    rt.close()
    # restore sees exactly the two accepted batches (seq gap is fine)
    back = StreamRuntime.restore(str(tmp_path))
    assert back.latest().fingerprint == _reference_fingerprint(
        spec, k, caps, [batches[0], batches[2]]
    )
    back.close()


def test_checkpoint_write_failure_keeps_serving(rng, tmp_path):
    P, cats, caps, spec, k = _instance(rng, n=200)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(0, [
        FaultRule(site="checkpoint.write", kind="error", times=1),
    ])
    rt = _make_runtime(
        spec, k, caps, registry=reg, faults=plan,
        durability=DurabilityConfig(dir=str(tmp_path), checkpoint_every=2),
    )
    for pts, cs in _batches(P, cats):
        rt.submit(pts, cs)
    rt.flush()
    live = rt.latest()
    assert reg.counter("serve.ckpt.failures").value == 1
    assert reg.counter("serve.ckpt.saved").value >= 1  # later saves OK
    rt.close()
    back = StreamRuntime.restore(str(tmp_path))
    assert back.latest().fingerprint == live.fingerprint
    back.close()


def test_clock_skew_never_tears_staleness(rng):
    """All epoch/staleness stamps read the plan's (skewed) clock, so a
    skewed runtime still reports non-negative staleness and sane
    publication ordering."""
    P, cats, caps, spec, k = _instance(rng, n=200)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(0, clock_skew_s=-1800.0)
    rt = _make_runtime(spec, k, caps, registry=reg, faults=plan)
    for pts, cs in _batches(P, cats):
        rt.submit(pts, cs)
    rt.flush()
    stale = reg.histogram("serve.epoch.staleness_s")
    assert stale.count == 4
    assert stale.describe()["min"] >= 0.0
    assert rt.latest().published_at < time.monotonic()  # skewed backwards
    rt.close()


# ----------------------------------------------------------------------
# close(): drain-or-raise, forced drops are counted
# ----------------------------------------------------------------------

def test_close_drains_by_default_and_raises_on_timeout(rng):
    P, cats, caps, spec, k = _instance(rng, n=300)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(0, [
        FaultRule(site="worker.ingest", kind="delay", delay_s=0.25,
                  times=None),
    ])
    rt = _make_runtime(spec, k, caps, registry=reg, faults=plan)
    for pts, cs in _batches(P, cats):
        rt.submit(pts, cs)
    with pytest.raises(TimeoutError, match="drain"):
        rt.close(timeout=0.05)
    assert rt.pending > 0  # NOT closed, nothing dropped
    rt.close()  # full drain: every accepted batch lands
    assert rt.pending == 0
    assert rt.n_offered == P.shape[0]
    assert reg.counter(
        "serve.worker.dropped_batches", reason="close"
    ).value == 0


def test_forced_close_counts_dropped_batches(rng):
    P, cats, caps, spec, k = _instance(rng, n=300)
    reg = obs.MetricsRegistry()
    plan = FaultPlan(0, [
        FaultRule(site="worker.ingest", kind="delay", delay_s=0.25,
                  times=None),
    ])
    rt = _make_runtime(spec, k, caps, registry=reg, faults=plan)
    for pts, cs in _batches(P, cats):
        rt.submit(pts, cs)
    rt.close(drain=False)
    dropped = reg.counter(
        "serve.worker.dropped_batches", reason="close"
    ).value
    assert dropped > 0
    # the drop is surfaced, not silent: flush tells the truth
    with pytest.raises(RuntimeError, match="worker failed"):
        rt.flush()
    # ... and errors were not inflated per-drop
    assert reg.counter("serve.worker.errors").value == 0


# ----------------------------------------------------------------------
# deadline-aware admission
# ----------------------------------------------------------------------

def _seeded_frontend(rng, reg):
    P, cats, caps, spec, k = _instance(rng)
    rt = _make_runtime(spec, k, caps, registry=reg)
    rt.ingest(P, cats)
    return QueryFrontend(rt), k


def test_deadline_degrades_exact_to_greedy(rng):
    reg = obs.MetricsRegistry()
    fe, k = _seeded_frontend(rng, reg)
    # teach the predictor that host_exhaustive blows any budget
    reg.histogram(
        "serve.solve.latency_s", tenant="default",
        engine="host_exhaustive",
    ).observe(30.0)
    res = fe.query_batch(
        [DiversityQuery(k=3, variant="star"),
         DiversityQuery(k=3, variant="tree")],
        deadline_s=1.0,
    )
    assert all(r.degraded and r.engine == "jit_greedy" for r in res)
    assert all(not r.shed and len(r.indices) == 3 for r in res)
    assert reg.counter("serve.query.degraded", tenant="default").value == 2
    # exact queries without a deadline still run exact
    res2 = fe.query(DiversityQuery(k=3, variant="star"))
    assert res2.engine == "host_exhaustive" and not res2.degraded


def test_deadline_sheds_when_nothing_fits(rng):
    reg = obs.MetricsRegistry()
    fe, k = _seeded_frontend(rng, reg)
    for eng in (
        "host_exhaustive", "jit_greedy", "jit_sum", "host_local_search"
    ):
        reg.histogram(
            "serve.solve.latency_s", tenant="default", engine=eng,
        ).observe(30.0)
    res = fe.query_batch(
        [DiversityQuery(k=k), DiversityQuery(k=3, variant="star")],
        deadline_s=0.5,
    )
    assert all(r.shed and r.engine == "shed" for r in res)
    assert all(len(r.indices) == 0 for r in res)
    assert reg.counter("serve.query.shed", tenant="default").value == 2
    # shedding is an answer, not an error: the frontend stays healthy
    ok = fe.query(DiversityQuery(k=k))
    assert not ok.shed and len(ok.indices) == k


@pytest.mark.parametrize("seed", SEEDS)
def test_saturation_burst_bounded_by_deadline(rng, seed):
    """4x-saturation acceptance shape: under a burst of exact queries
    with a deadline, every request completes, degrades, or sheds within
    its budget — nothing queues unboundedly, nothing raises."""
    reg = obs.MetricsRegistry()
    fe, k = _seeded_frontend(rng, reg)
    # warm the engines once so predictions exist and compiles are paid
    fe.query_batch([
        DiversityQuery(k=3, variant="star"),
        DiversityQuery(k=3, variant="star", engine_hint="jit_greedy"),
        DiversityQuery(k=k),
    ])
    deadline_s = 2.0
    qrng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    outcomes = {"ok": 0, "degraded": 0, "shed": 0}
    for _ in range(12):
        qs = [
            DiversityQuery(
                k=3, variant=("star" if qrng.random() < 0.5 else "tree")
            )
            for _ in range(4)
        ]
        t1 = time.perf_counter()
        for r in fe.query_batch(qs, deadline_s=deadline_s):
            if r.shed:
                outcomes["shed"] += 1
            elif r.degraded:
                outcomes["degraded"] += 1
            else:
                outcomes["ok"] += 1
        # the per-batch wall time respects the deadline (generous slack
        # for CI noise: the contract is "bounded", not "tight")
        assert time.perf_counter() - t1 < deadline_s + 2.0
    assert sum(outcomes.values()) == 48
    assert time.perf_counter() - t0 < 12 * (deadline_s + 2.0)


# ----------------------------------------------------------------------
# input validation: non-finite batches are rejected at the door
# ----------------------------------------------------------------------

def test_nonfinite_batch_rejected_before_wal(rng, tmp_path):
    """NaN/Inf coordinates raise ``ValueError`` BEFORE the WAL append —
    a poisoned log entry would replay poison on every restore — and the
    rejection is counted under ``serve.ingest.rejected``."""
    P, cats, caps, spec, k = _instance(rng, n=100)
    reg = obs.MetricsRegistry()
    rt = _make_runtime(
        spec, k, caps, registry=reg, durability=str(tmp_path)
    )
    rt.ingest(P[:50], cats[:50])
    bad_nan = P[50:].copy()
    bad_nan[3, 1] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        rt.ingest(bad_nan, cats[50:])
    bad_inf = P[50:].copy()
    bad_inf[0, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        rt.submit(bad_inf, cats[50:])
    assert int(reg.counter(
        "serve.ingest.rejected", reason="nonfinite"
    ).value) == 2
    # the stream is unharmed and keeps accepting good batches
    rt.submit(P[50:], cats[50:])
    rt.flush()
    assert rt.n_offered == 100
    ref_fp = _reference_fingerprint(
        spec, k, caps, [(P[:50], cats[:50]), (P[50:], cats[50:])]
    )
    assert rt.latest().fingerprint == ref_fp
    # the WAL never saw the poison: only the two good batches are on
    # disk (inspect before close — the parting checkpoint compacts it),
    # so a restore replays a clean stream
    wal = WriteAheadLog(DurabilityConfig(dir=str(tmp_path)).wal_path)
    assert [r.seq for r in wal.replay()] == [0, 1]
    wal.close()
    rt.close()
    restored = StreamRuntime.restore(str(tmp_path))
    assert restored.latest().fingerprint == ref_fp
    restored.close()


def test_nonfinite_rejected_on_nondurable_runtime(rng):
    """The same validation guards the in-memory path (no WAL): the
    sync and async ingest APIs both refuse, the counter ticks."""
    P, cats, caps, spec, k = _instance(rng, n=100)
    reg = obs.MetricsRegistry()
    rt = _make_runtime(spec, k, caps, registry=reg)
    bad = P[:50].copy()
    bad[7, 0] = -np.inf
    with pytest.raises(ValueError, match="non-finite"):
        rt.ingest(bad, cats[:50])
    with pytest.raises(ValueError, match="non-finite"):
        rt.submit(bad, cats[:50])
    assert int(reg.counter(
        "serve.ingest.rejected", reason="nonfinite"
    ).value) == 2
    assert rt.n_offered == 0
    rt.close()
