"""Contracts of the ``repro.obs`` observability layer and its integration
with the serving stack: registry semantics, log-bucket histogram geometry,
the tracer-leak guard, trace-ID propagation (including across the async
ingest worker's thread), staleness/publish-latency accounting under
concurrent submit+flush, recompile-counter exactness at pow-2 bucket
boundaries, the on_publish error containment fix, and the CacheStats
back-compat surface.
"""
import json
import math
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.matroid import MatroidSpec
from repro.obs.metrics import bucket_index, bucket_lo
from repro.serve.diversity import (
    DiversityQuery,
    QueryFrontend,
    StreamRuntime,
)
from repro.serve.diversity.cache import CacheStats, DistanceCache

SPEC = MatroidSpec("partition", num_categories=4, gamma=1)
CAPS = np.full(4, 4, np.int32)


def make_runtime(**kw):
    kw.setdefault("registry", obs.MetricsRegistry())
    return StreamRuntime(SPEC, 8, tau=16, caps=CAPS, **kw)


def feed(rng, n=64):
    return (
        rng.normal(size=(n, 4)).astype(np.float32),
        rng.integers(0, 4, size=(n, 1)).astype(np.int32),
    )


# ---------------------------------------------------------------------------
# registry + histogram geometry
# ---------------------------------------------------------------------------


def test_registry_series_identity_and_labels():
    reg = obs.MetricsRegistry()
    a = reg.counter("req", tenant="a")
    b = reg.counter("req", tenant="b")
    assert a is reg.counter("req", tenant="a")  # get-or-create
    assert a is not b
    a.inc(3)
    b.inc()
    snap = reg.snapshot()
    assert snap["req{tenant=a}"]["value"] == 3
    assert snap["req{tenant=b}"]["value"] == 1
    # label order never matters
    c = reg.gauge("g", x="1", y="2")
    assert c is reg.gauge("g", y="2", x="1")
    # same series name under a different instrument kind is a loud error
    with pytest.raises(TypeError):
        reg.histogram("req", tenant="a")


def test_histogram_log2_bucket_boundaries():
    # buckets are keyed off the frexp exponent: bucket i holds
    # [2^(i-30), 2^(i-29)) (since 1e-9 ~ 1.074 * 2^-30), so the edges sit
    # exactly at powers of two — one ulp below an edge is the previous
    # bucket, and bucket_lo(i) = 1e-9 * 2^i always lands inside bucket i
    for i in (1, 5, 30, 60):
        edge = 2.0 ** (i - 30)
        assert bucket_index(edge) == i
        assert bucket_index(np.nextafter(edge, 0.0)) == i - 1
        assert bucket_index(bucket_lo(i)) == i
        assert bucket_lo(i) / bucket_lo(i - 1) == 2.0
    # monotone in v across four decades
    idx = [bucket_index(1e-8 * 1.9 ** j) for j in range(16)]
    assert idx == sorted(idx)
    # clamps: tiny to bucket 0, absurd to the last bucket — never a throw,
    # never an allocation
    assert bucket_index(0.0) == 0
    assert bucket_index(-1.0) == 0
    assert bucket_index(1e30) == 95


def test_histogram_quantiles_within_bucket_resolution():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat")
    vals = [0.001 * (1 + i % 7) for i in range(1000)]
    for v in vals:
        h.observe(v)
    d = h.describe()
    assert d["count"] == 1000
    assert d["min"] == pytest.approx(min(vals))
    assert d["max"] == pytest.approx(max(vals))
    assert d["sum"] == pytest.approx(sum(vals))
    # log2 buckets: a quantile is off by at most 2x, clamped to [min, max]
    for q, true in ((0.5, np.quantile(vals, 0.5)),
                    (0.95, np.quantile(vals, 0.95))):
        got = h.quantile(q)
        assert true / 2 <= got <= true * 2
        assert d["min"] <= got <= d["max"]
    # single observation reports itself exactly (clamp to min == max)
    h1 = reg.histogram("one")
    h1.observe(0.0042)
    assert h1.quantile(0.5) == pytest.approx(0.0042)


def test_registry_reset_and_disable():
    reg = obs.MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("h")
    c.inc(5)
    h.observe(1.0)
    reg.reset()
    assert c.value == 0 and h.count == 0
    assert reg.counter("n") is c  # handles survive reset
    reg.enabled = False
    c.inc()
    h.observe(1.0)
    assert c.value == 0 and h.count == 0  # disabled ops are no-ops


def test_write_jsonl(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("a", engine="x").inc(2)
    reg.histogram("b").observe(0.5)
    p = tmp_path / "metrics.jsonl"
    reg.write_jsonl(str(p))
    recs = [json.loads(line) for line in p.read_text().splitlines()]
    by_series = {r["series"]: r for r in recs}
    assert by_series["a{engine=x}"]["value"] == 2
    assert by_series["a{engine=x}"]["labels"] == {"engine": "x"}
    assert by_series["b"]["count"] == 1


# ---------------------------------------------------------------------------
# tracer-leak guard
# ---------------------------------------------------------------------------


def test_metric_mutation_inside_jit_trace_raises():
    reg = obs.MetricsRegistry()
    c = reg.counter("leaked")
    h = reg.histogram("leaked_h")

    @jax.jit
    def f(x):
        c.inc()
        return x * 2

    with pytest.raises(obs.TracerLeakError):
        f(jnp.ones(3))
    assert c.value == 0  # the trace-time call never landed

    @jax.jit
    def g(x):
        h.observe(0.1)
        return x

    with pytest.raises(obs.TracerLeakError):
        g(jnp.ones(3))


def test_span_inside_jit_trace_raises():
    buf = obs.TraceBuffer(capacity=16)

    @jax.jit
    def f(x):
        with buf.span("inside"):
            return x + 1

    with pytest.raises(obs.TracerLeakError):
        f(jnp.ones(3))
    assert buf.drain() == []


def test_guard_is_thread_local():
    # the ingest worker mutating metrics while ANOTHER thread is tracing
    # must not trip the guard: jax trace state is thread-local
    reg = obs.MetricsRegistry()
    c = reg.counter("worker_side")
    errs = []
    go = threading.Event()
    done = threading.Event()

    def worker():
        go.wait(5.0)
        try:
            c.inc()
        except Exception as e:  # pragma: no cover - the failure mode
            errs.append(e)
        done.set()

    th = threading.Thread(target=worker, daemon=True)
    th.start()

    @jax.jit
    def f(x):
        go.set()
        done.wait(5.0)  # worker increments WHILE this trace is active
        return x

    f(jnp.ones(2))
    th.join(5.0)
    assert not errs and c.value == 1


def test_instrumented_serving_paths_are_trace_clean(rng):
    # end-to-end: ingest + query through every instrumented layer raises
    # no TracerLeakError (i.e. no host-side obs call leaked into a trace)
    rt = make_runtime()
    fe = QueryFrontend(rt)
    P, C = feed(rng, 128)
    rt.ingest(P, C)
    res = fe.query_batch([DiversityQuery(k=4)])
    assert len(res) == 1


# ---------------------------------------------------------------------------
# tracing: spans, IDs, export
# ---------------------------------------------------------------------------


def test_trace_id_propagates_through_query_batch_spans(rng):
    rt = make_runtime()
    fe = QueryFrontend(rt)
    P, C = feed(rng, 128)
    rt.ingest(P, C)
    buf = obs.default_buffer()
    buf.clear()
    fe.query_batch([DiversityQuery(k=4), DiversityQuery(k=3)])
    spans = buf.drain()
    names = {s.name for s in spans}
    assert {"query_batch", "resolve_tenant", "acquire_epoch",
            "cache_entry", "solve", "device_sync"} <= names
    ids = {s.trace_id for s in spans}
    assert len(ids) == 1 and None not in ids  # one request, one trace
    # a second request gets a DIFFERENT trace id
    buf.clear()
    fe.query_batch([DiversityQuery(k=4)])
    ids2 = {s.trace_id for s in buf.drain()}
    assert len(ids2) == 1 and ids2 != ids


def test_trace_id_crosses_submit_to_worker_thread(rng):
    rt = make_runtime()
    P, C = feed(rng, 64)
    buf = obs.default_buffer()
    buf.clear()
    rt.submit(P, C)
    rt.flush()
    spans = buf.drain()
    sub = [s for s in spans if s.name == "submit"]
    wrk = [s for s in spans if s.name == "worker_ingest"]
    assert len(sub) == 1 and len(wrk) == 1
    assert sub[0].trace_id is not None
    assert wrk[0].trace_id == sub[0].trace_id  # resumed across threads
    assert wrk[0].tid != sub[0].tid  # ...on a genuinely different thread
    rt.close()


def test_chrome_trace_export(tmp_path):
    buf = obs.TraceBuffer(capacity=8)
    with buf.span("outer", cat="test", n=3):
        with buf.span("inner", cat="test"):
            pass
    p = tmp_path / "trace.json"
    buf.dump(str(p))
    doc = json.loads(p.read_text())
    evs = doc["traceEvents"]
    assert [e["name"] for e in evs] == ["outer", "inner"]
    for e in evs:
        assert e["ph"] == "X" and e["dur"] >= 0 and "pid" in e
    # spans record on exit, so the outer span's window covers the inner's
    outer, inner = evs
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert evs[0]["args"]["n"] == 3


def test_ring_buffer_overwrites_oldest():
    buf = obs.TraceBuffer(capacity=4)
    for i in range(10):
        with buf.span(f"s{i}"):
            pass
    got = [s.name for s in buf.drain()]
    assert got == ["s6", "s7", "s8", "s9"]  # newest capacity survive


# ---------------------------------------------------------------------------
# recompile watch: exactness at pow-2 bucket boundaries
# ---------------------------------------------------------------------------


def test_recompile_counter_exact_across_pow2_buckets():
    from repro.core.solvers.jit_sum import bucket_pow2

    watch = obs.RecompileWatch()
    try:
        @jax.jit
        def f(x):
            return jnp.sum(x * 2.0)

        def call(n):
            b = bucket_pow2(n)
            x = jnp.zeros((b,), jnp.float32)  # OUTSIDE the region: array
            # creation may itself compile helpers; only f's compile may
            # be attributed to the bucket key
            with obs.compile_region(f"test[b={b}]"):
                f(x).block_until_ready()
            return b

        watch.reset()
        # 5, 6, 8 share the pow-2 bucket 8: exactly ONE compile
        for n in (5, 6, 8):
            assert call(n) == 8
        assert watch.by_key().get("test[b=8]", 0) == 1
        # 9 crosses the boundary into bucket 16: exactly one more
        assert call(9) == 16
        assert watch.by_key().get("test[b=16]", 0) == 1
        # re-crossing back re-uses the cached executable: no new events
        before = watch.total()
        call(7)
        call(16)
        assert watch.total() == before
        assert watch.by_key().get("test[b=8]", 0) == 1
        assert watch.by_key().get("test[b=16]", 0) == 1
    finally:
        watch.close()


def test_recompile_watch_windows_and_unattributed():
    watch = obs.RecompileWatch()
    try:
        @jax.jit
        def g(x):
            return x + 1

        x = jnp.zeros(3)  # created OUTSIDE any region: helper compiles
        # (zeros fill etc.) must not be attributed to win[a]
        with obs.compile_region("win[a]"):
            g(x).block_until_ready()
        assert watch.by_key().get("win[a]") == 1
        assert watch.seconds_by_key()["win[a]"] > 0
        watch.reset()  # a fresh measurement window
        with obs.compile_region("win[a]"):
            g(x).block_until_ready()  # cached: no event
        assert watch.total() == 0

        @jax.jit
        def h(x):
            return x - 1

        h(x).block_until_ready()  # no active region
        assert watch.by_key().get(obs.UNATTRIBUTED, 0) >= 1
        assert watch.total(include_unattributed=False) == 0
    finally:
        watch.close()


# ---------------------------------------------------------------------------
# serving integration: staleness, publish latency, worker containment
# ---------------------------------------------------------------------------


def test_staleness_and_publish_latency_under_concurrent_submit(rng):
    reg = obs.MetricsRegistry()
    rt = make_runtime(registry=reg, publish_every=2)
    P, C = feed(rng, 64)
    rt.ingest(P, C)  # init + compile off the measured path
    n_batches = 12
    threads = [
        threading.Thread(
            target=lambda i=i: rt.submit(*feed(np.random.default_rng(i), 32)),
            daemon=True,
        )
        for i in range(n_batches)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join(30.0)
    rt.flush()
    stale = reg.histogram("serve.epoch.staleness_s")
    pub = reg.histogram("serve.epoch.publish_latency_s")
    # every worker-ingested batch lands in the staleness histogram exactly
    # once (publish time - submit time), regardless of publish cadence
    assert stale.count == n_batches
    assert stale.sum >= 0 and math.isfinite(stale.sum)
    assert pub.count == reg.counter("serve.epoch.published").value > 0
    assert reg.counter("serve.submit.batches").value == n_batches
    assert reg.counter("serve.worker.errors").value == 0
    d = stale.describe()
    assert d["min"] >= 0 and d["p95"] >= d["min"]
    rt.close()


def test_on_publish_error_is_counted_not_fatal(rng):
    reg = obs.MetricsRegistry()
    boom = []

    def bad_callback(snap):
        boom.append(snap.epoch)
        raise RuntimeError("subscriber bug")

    rt = make_runtime(registry=reg, on_publish=bad_callback)
    P, C = feed(rng, 64)
    rt.submit(P, C)
    epoch = rt.flush()  # must NOT raise, must NOT kill the worker
    assert epoch >= 1 and boom
    errs = reg.counter("serve.publish.callback_errors").value
    assert errs == len(boom) > 0
    # the stream did not truncate: later submits still ingest
    n0 = rt.n_offered
    rt.submit(P, C)
    rt.flush()
    assert rt.n_offered == n0 + 64
    assert reg.counter("serve.worker.errors").value == 0
    rt.close()


def test_ingest_errors_still_truncate_the_stream(rng):
    # containment is for SUBSCRIBER bugs only: a real ingest failure must
    # keep surfacing on the next submit/flush (pinned by test_freshness)
    rt = make_runtime()
    P, C = feed(rng, 64)
    rt.submit(P, C)
    rt.flush()
    rt.submit(np.full((8, 3), 1.0, np.float32), None)  # wrong dim: fails
    with pytest.raises(RuntimeError, match="worker failed"):
        rt.flush()
    rt.close()


def test_query_metrics_labeled_by_tenant_and_engine(rng):
    reg = obs.MetricsRegistry()
    rt = make_runtime(registry=reg)
    fe = QueryFrontend(rt)
    P, C = feed(rng, 128)
    rt.ingest(P, C)
    fe.register_tenant("cosine", metric="cosine")
    fe.query_batch([DiversityQuery(k=4)] * 3)
    fe.query_batch([DiversityQuery(k=4)], tenant="cosine")
    snap = reg.snapshot()
    assert snap["serve.query.latency_s{tenant=default}"]["count"] == 1
    assert snap["serve.query.latency_s{tenant=cosine}"]["count"] == 1
    assert snap["serve.query.batch_size{tenant=default}"]["max"] == 3
    solve_keys = [
        key for key in snap
        if key.startswith("serve.solve.latency_s{")
        and snap[key]["count"] > 0
    ]
    assert any("engine=" in key and "tenant=default" in key
               for key in solve_keys)
    assert reg.counter(
        "serve.query.cache_misses", tenant="default"
    ).value == 1  # one entry build per (tenant, epoch), not per query
    # a second default batch over the unchanged epoch hits the warm entry
    fe.query_batch([DiversityQuery(k=4)])
    assert reg.counter(
        "serve.query.cache_hits", tenant="default"
    ).value == 1
    assert reg.counter(
        "serve.query.cache_misses", tenant="default"
    ).value == 1
    rt.close()


def test_stats_backcompat_view_still_works(rng):
    rt = make_runtime()
    fe = QueryFrontend(rt)
    P, C = feed(rng, 128)
    rt.ingest(P, C)
    fe.query(DiversityQuery(k=4))
    s = fe.stats()
    assert s["epoch"] >= 1
    assert s["cache"]["builds"] == 1 and s["cache"]["misses"] == 1
    fe.query(DiversityQuery(k=4))
    assert fe.stats()["cache"]["hits"] == 1
    rt.close()


# ---------------------------------------------------------------------------
# CacheStats back-compat
# ---------------------------------------------------------------------------


def test_cache_stats_registry_backed_backcompat():
    reg = obs.MetricsRegistry()
    s = CacheStats(reg, cache="t0")
    assert s.hits == 0 and s.misses == 0
    s.incr("hits")
    s.incr("builds", 2)
    assert s.hits == 1 and s.builds == 2  # plain-int attribute reads
    snap = s.snapshot()
    assert snap == {
        "hits": 1, "misses": 0, "builds": 2, "invalidations": 0,
        "evictions": 0, "expirations": 0, "sweeps": 0,
    }
    # and the same counts are visible as first-class registry series
    assert reg.snapshot()["serve.cache.builds{cache=t0}"]["value"] == 2
    with pytest.raises(AttributeError):
        s.nonexistent_field


def test_distance_cache_counts_in_isolated_registry():
    reg = obs.MetricsRegistry()
    cache = DistanceCache(registry=reg)
    key = ("spec", 1, "euclidean")
    assert cache.lookup(key, 7) is None
    pts = np.random.default_rng(0).normal(size=(6, 3)).astype(np.float32)
    cats = np.zeros((6, 1), np.int32)
    src = np.arange(6)
    cache.build(key, pts, cats, src, 7)
    assert cache.lookup(key, 7) is not None
    assert cache.stats.misses == 1
    assert cache.stats.builds == 1
    assert cache.stats.hits == 1
    # two caches over one registry never share series (cache=cN label)
    other = DistanceCache(registry=reg)
    assert other.stats.misses == 0


# ---------------------------------------------------------------------------
# module-level conveniences
# ---------------------------------------------------------------------------


def test_set_enabled_toggles_default_registry_and_buffer():
    obs.set_enabled(False)
    try:
        c = obs.counter("toggle_test")
        v0 = c.value
        c.inc()
        assert c.value == v0  # disabled
        buf = obs.default_buffer()
        n0 = len(buf.drain())
        with obs.span("toggle_span"):
            pass
        assert len(buf.drain()) == n0
    finally:
        obs.set_enabled(True)
    c = obs.counter("toggle_test")
    c.inc()
    assert c.value >= 1


def test_observability_report_shape():
    rep = obs.observability_report(obs.MetricsRegistry())
    assert set(rep) == {
        "metrics", "recompiles_by_key", "recompile_seconds_by_key"
    }
