"""Solver-engine registry: coverage, dispatch policy, cross-engine parity
(the acceptance bar: every engine eligible for a (variant, matroid) cell
returns the same objective as the host reference engine), kmax bucketing,
and the multi-label partition guard."""
import numpy as np
import pytest

from conftest import make_clustered_points
from repro.core import solve_dmmc
from repro.core.matroid import (
    MatroidSpec,
    PartitionMatroid,
    TransversalMatroid,
    UniformMatroid,
)
from repro.core.solvers import (
    MATROID_KINDS,
    EngineSolution,
    SolveContext,
    SolveSpec,
    SolverEngine,
    coverage_matrix,
    get_engine,
    partition_by_engine,
    register_engine,
    registered_engines,
    resolve_engine,
    select_engine,
    selection_value,
)
from repro.core.solvers import base as solvers_base
from repro.core.solvers.jit_sum import bucket_pow2, solve_sum_batch
from repro.core.diversity import VARIANTS


def _dist(P):
    D = np.sqrt(((P[:, None] - P[None, :]) ** 2).sum(-1)).astype(np.float32)
    np.fill_diagonal(D, 0.0)
    return D


def _ctx_for(kind, rng, m=32, h=4, gamma=2):
    """Random coreset-sized SolveContext + a host-oracle factory."""
    P = make_clustered_points(rng, n=m, d=5)
    D = _dist(P)
    if kind == "uniform":
        spec = MatroidSpec("uniform")
        return SolveContext(
            D=D, spec=spec, cats=None, caps=None,
            matroid_fn=lambda s: UniformMatroid(m, s.k),
        )
    if kind == "partition":
        cats = rng.integers(0, h, (m, 1)).astype(np.int32)
        caps = np.full(h, 2, np.int32)
        spec = MatroidSpec("partition", num_categories=h, gamma=1)
        return SolveContext(
            D=D, spec=spec, cats=cats, caps=caps,
            matroid_fn=lambda s: PartitionMatroid(
                cats, caps if s.caps is None else np.asarray(s.caps)
            ),
        )
    if kind == "transversal":
        cats = np.full((m, gamma), -1, np.int32)
        cats[:, 0] = rng.integers(0, h, m)
        extra = rng.random(m) < 0.4
        cats[extra, 1] = rng.integers(0, h, extra.sum())
        spec = MatroidSpec("transversal", num_categories=h, gamma=gamma)
        return SolveContext(
            D=D, spec=spec, cats=cats, caps=None,
            matroid_fn=lambda s: TransversalMatroid(cats, h),
        )
    raise ValueError(kind)


# --------------------------------------------------------------------------
# registry + dispatch policy
# --------------------------------------------------------------------------


def test_coverage_matrix_shape_and_policy():
    cm = coverage_matrix()
    assert set(cm) == {(v, k) for v in VARIANTS for k in MATROID_KINDS}
    # the jit sum engine covers exactly uniform/partition/transversal
    for kind in ("uniform", "partition", "transversal"):
        assert cm[("sum", kind)][0] == "jit_sum"
        for variant in ("star", "tree"):
            assert cm[(variant, kind)][0] == "jit_greedy"
    assert cm[("sum", "general")] == ["host_local_search"]
    # every cell keeps a host reference engine
    for (variant, kind), engines in cm.items():
        host = "host_local_search" if variant == "sum" else "host_exhaustive"
        assert host in engines, (variant, kind)


def test_auto_selects_parity_engines_only(rng):
    ctx = _ctx_for("uniform", rng)
    # sum: the jit engine is parity -> auto picks it
    assert select_engine(ctx, SolveSpec(k=3)).name == "jit_sum"
    # star/tree: jit_greedy is NOT parity -> auto keeps the exact host
    for variant in ("star", "tree"):
        e = select_engine(ctx, SolveSpec(k=3, variant=variant))
        assert e.name == "host_exhaustive"
        # ...unless explicitly hinted
        e = select_engine(
            ctx, SolveSpec(k=3, variant=variant), hint="jit_greedy"
        )
        assert e.name == "jit_greedy"
    # a hint that does not apply falls back to auto instead of failing
    e = select_engine(ctx, SolveSpec(k=3, variant="cycle"), hint="jit_greedy")
    assert e.name == "host_exhaustive"
    # forcing an ineligible engine raises
    with pytest.raises(ValueError):
        resolve_engine("jit_sum", ctx, SolveSpec(k=3, variant="cycle"))
    with pytest.raises(ValueError):
        get_engine("definitely_not_registered")


def test_partition_by_engine_groups(rng):
    ctx = _ctx_for("partition", rng)
    specs = [
        SolveSpec(k=2),
        SolveSpec(k=3, variant="tree"),
        SolveSpec(k=2),
        SolveSpec(k=2, variant="star"),
    ]
    groups = partition_by_engine(ctx, specs, engine="auto",
                                 hints=[None, "jit_greedy", None, None])
    assert groups == {
        "jit_sum": [0, 2], "jit_greedy": [1], "host_exhaustive": [3]
    }
    # forcing host resolves per-variant to the two host engines
    groups = partition_by_engine(ctx, specs, engine="host")
    assert groups == {
        "host_local_search": [0, 2], "host_exhaustive": [1, 3]
    }


def test_register_custom_engine(rng):
    class EchoEngine(SolverEngine):
        name = "echo"
        priority = 1
        exact_parity = False  # never picked by auto

        def supports(self, variant, matroid_kind):
            return variant == "sum"

        def solve_one(self, ctx, spec):
            loc = np.flatnonzero(spec.allow_mask(ctx.size))[: spec.k]
            return EngineSolution(
                local_indices=loc.astype(np.int64),
                value=selection_value(ctx.D, loc, spec.variant),
                engine=self.name,
            )

    saved = dict(solvers_base._REGISTRY)
    try:
        register_engine(EchoEngine())
        with pytest.raises(ValueError):
            register_engine(EchoEngine())  # duplicate name
        ctx = _ctx_for("uniform", rng)
        spec = SolveSpec(k=3)
        # explicit request works, auto still refuses non-parity engines
        assert resolve_engine("echo", ctx, spec).name == "echo"
        assert select_engine(ctx, spec).name == "jit_sum"
        sol = resolve_engine("echo", ctx, spec).solve_one(ctx, spec)
        assert sol.local_indices.tolist() == [0, 1, 2]
        assert "echo" in [e.name for e in registered_engines()]
    finally:
        solvers_base._REGISTRY.clear()
        solvers_base._REGISTRY.update(saved)


# --------------------------------------------------------------------------
# cross-engine parity property (acceptance criterion)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["uniform", "partition", "transversal"])
def test_cross_engine_sum_parity_property(rng, kind):
    """For random coresets, every parity engine eligible for a cell
    returns the same selection set and the same canonical objective as
    the host engine — including per-query caps and candidate filters."""
    for trial in range(6):
        ctx = _ctx_for(kind, rng)  # m fixed at 32: one jit shape
        k = int(rng.integers(2, 6))
        caps = None
        if kind == "partition" and trial % 2:
            caps = tuple(rng.integers(1, 3, ctx.spec.num_categories).tolist())
        allow = None
        if trial % 3 == 0:
            allow = rng.random(ctx.size) < 0.8
        spec = SolveSpec(k=k, variant="sum", caps=caps, allow=allow)
        host = resolve_engine("host", ctx, spec).solve_one(ctx, spec)
        for e in registered_engines():
            if not (e.exact_parity and e.eligible(ctx, spec)):
                continue
            got = e.solve_one(ctx, spec)
            assert sorted(got.local_indices.tolist()) == sorted(
                host.local_indices.tolist()
            ), (kind, trial, k, e.name)
            assert got.value == host.value, (kind, trial, k, e.name)


def test_transversal_jit_batch_matches_host_local_search(rng):
    """The tentpole assertion: transversal sum queries run through the jit
    batch engine and land on the host local-search answer."""
    ctx = _ctx_for("transversal", rng)
    specs = [SolveSpec(k=k) for k in (2, 3, 4, 5)]
    jit = get_engine("jit_sum")
    assert jit.eligible(ctx, specs[0])
    sols = jit.solve_batch(ctx, specs)
    from repro.core.solvers.local_search import local_search_sum

    for spec, sol in zip(specs, sols):
        X, _val, _ = local_search_sum(
            ctx.D, ctx.matroid_fn(spec), spec.k, list(range(ctx.size))
        )
        assert sol.local_indices.tolist() == X  # same order, even
        assert sol.value == selection_value(ctx.D, X, "sum")
        assert ctx.matroid_fn(spec).is_independent(
            sol.local_indices.tolist()
        )


def test_solve_dmmc_engine_dispatch(rng):
    P = make_clustered_points(rng, n=200)
    h = 4
    cats = rng.integers(0, h, (200, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    kw = dict(cats=cats, caps=caps, tau=10, setting="streaming")
    a = solve_dmmc(P, 4, spec, **kw)  # default engine="host"
    b = solve_dmmc(P, 4, spec, engine="auto", **kw)
    c = solve_dmmc(P, 4, spec, engine="jit_sum", **kw)
    assert sorted(a.indices.tolist()) == sorted(b.indices.tolist())
    assert b.indices.tolist() == c.indices.tolist()
    assert a.diversity == b.diversity == c.diversity


# --------------------------------------------------------------------------
# kmax bucketing (jit cache stability across novel max-k values)
# --------------------------------------------------------------------------


def test_bucket_pow2():
    assert [bucket_pow2(n) for n in (1, 2, 3, 4, 5, 7, 8, 9, 31)] == [
        1, 2, 4, 4, 8, 8, 8, 16, 32
    ]


def test_kmax_bucketing_reuses_compiled_solver(rng):
    ctx = _ctx_for("partition", rng)
    jit = get_engine("jit_sum")
    # warm the (kmax=8, B=1) bucket, then novel max-k values in (4, 8]
    # must NOT recompile; answers must be unaffected by the padding
    base = {k: jit.solve_one(ctx, SolveSpec(k=k)) for k in (5, 8)}
    if hasattr(solve_sum_batch, "_cache_size"):
        before = solve_sum_batch._cache_size()
        for k in (6, 7, 8):
            jit.solve_one(ctx, SolveSpec(k=k))
        assert solve_sum_batch._cache_size() == before, (
            "novel max-k inside one power-of-two bucket recompiled"
        )
    # same query, different batch compositions -> same answer
    again = jit.solve_batch(ctx, [SolveSpec(k=5), SolveSpec(k=8)])
    assert again[0].local_indices.tolist() == base[5].local_indices.tolist()
    assert again[1].local_indices.tolist() == base[8].local_indices.tolist()


def test_unknown_engine_hint_raises(rng):
    """A typo'd hint must not silently downgrade to a slower engine."""
    ctx = _ctx_for("uniform", rng)
    spec = SolveSpec(k=3, variant="star")
    with pytest.raises(ValueError, match="unknown solver engine"):
        select_engine(ctx, spec, hint="jit_greddy")
    # ...while a registered-but-ineligible hint still falls back softly
    assert select_engine(ctx, SolveSpec(k=3, variant="cycle"),
                         hint="jit_greedy").name == "host_exhaustive"


def test_final_solve_accepts_1d_cats(rng):
    """final_solve(cats=...) with single-label 1-D cats reaches the jit
    partition path (SolveContext normalizes the shape)."""
    from repro.core.final_solve import final_solve

    m, h = 32, 4
    D = _dist(make_clustered_points(rng, n=m, d=4))
    cats1d = rng.integers(0, h, m).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    matroid = PartitionMatroid(cats1d, caps)
    X_jit, v_jit = final_solve(
        D, matroid, 4, "sum", engine="jit_sum", cats=cats1d, caps=caps
    )
    X_host, v_host = final_solve(D, matroid, 4, "sum")
    assert sorted(X_jit) == sorted(X_host)
    assert v_jit == v_host


def test_final_solve_preserves_idxs_order(rng):
    """Host tie-breaks are visit-order dependent: with duplicated points,
    the first idxs entry of a tied pair wins, whatever order idxs is in —
    and jit engines refuse the order-sensitive request under auto."""
    from repro.core.final_solve import final_solve

    P = make_clustered_points(rng, n=8, d=3)
    P[5] = P[2]  # exact duplicate: rows 2 and 5 tie everywhere
    D = _dist(P)
    matroid = UniformMatroid(8, 2)
    fwd, _ = final_solve(D, matroid, 2, "sum", idxs=[2, 5, 0, 7])
    rev, _ = final_solve(D, matroid, 2, "sum", idxs=[5, 2, 0, 7])
    assert (2 in fwd) != (5 in fwd) and (2 in rev) != (5 in rev)
    swap = {2: 5, 5: 2}
    assert sorted(swap.get(i, i) for i in rev) == sorted(fwd)
    # auto on a non-ascending idxs request stays on the host engine
    ctx = _ctx_for("uniform", rng)
    spec = SolveSpec(k=2, idxs=(5, 2, 0))
    assert not get_engine("jit_sum").eligible(ctx, spec)
    assert select_engine(ctx, spec).name == "host_local_search"
    # ascending idxs keep the fast path
    assert select_engine(ctx, SolveSpec(k=2, idxs=(0, 2, 5))).name == "jit_sum"


# --------------------------------------------------------------------------
# multi-label partition guard
# --------------------------------------------------------------------------


def test_multilabel_partition_guard(rng):
    m, h = 16, 3
    D = _dist(make_clustered_points(rng, n=m, d=4))
    cats = np.full((m, 2), -1, np.int32)
    cats[:, 0] = rng.integers(0, h, m)
    cats[2, 1] = 1  # one point with a second real label
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=2)
    ctx = SolveContext(
        D=D, spec=spec, cats=cats, caps=caps,
        matroid_fn=lambda s: PartitionMatroid(cats, caps),
    )
    q = SolveSpec(k=3)
    # the jit engine refuses (no silent truncation of cats[:, 1:])...
    assert not get_engine("jit_sum").eligible(ctx, q)
    with pytest.raises(ValueError):
        resolve_engine("jit_sum", ctx, q)
    # ...auto routes to host, whose oracle raises the descriptive error
    eng = select_engine(ctx, q)
    assert eng.name == "host_local_search"
    with pytest.raises(ValueError, match="transversal"):
        eng.solve_one(ctx, q)
    # benign -1 padding in extra columns stays on the fast path
    cats_pad = cats.copy()
    cats_pad[:, 1] = -1
    ctx2 = SolveContext(
        D=D, spec=spec, cats=cats_pad, caps=caps,
        matroid_fn=lambda s: PartitionMatroid(cats_pad, caps),
    )
    assert select_engine(ctx2, q).name == "jit_sum"
