"""Matroid axioms (hypothesis property tests) + oracle cross-checks."""
import itertools

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core.matroid import (
    MatroidSpec,
    PartitionMatroid,
    TransversalMatroid,
    UniformMatroid,
    partition_extract_mask,
    rank_in_group,
    transversal_extract_mask,
)

import jax.numpy as jnp


# --------------------------------------------------------------------------
# instance generators
# --------------------------------------------------------------------------

partition_instances = st.tuples(
    st.integers(4, 14),  # n
    st.integers(2, 4),  # h
    st.integers(1, 3),  # cap
    st.randoms(use_true_random=False),
)

transversal_instances = st.tuples(
    st.integers(4, 12),  # n
    st.integers(2, 5),  # h
    st.integers(1, 2),  # gamma
    st.randoms(use_true_random=False),
)


def _mk_partition(n, h, cap, rnd):
    cats = np.array([rnd.randrange(h) for _ in range(n)], np.int32)
    caps = np.full(h, cap, np.int32)
    return PartitionMatroid(cats, caps)


def _mk_transversal(n, h, gamma, rnd):
    cats = np.full((n, gamma), -1, np.int32)
    for i in range(n):
        k = rnd.randrange(1, gamma + 1)
        cs = rnd.sample(range(h), k)
        cats[i, : len(cs)] = cs
    return TransversalMatroid(cats, h)


def _check_axioms(m, n, rnd, trials=40):
    # hereditary: subsets of independent sets are independent
    for _ in range(trials):
        size = rnd.randrange(1, min(n, 6) + 1)
        s = rnd.sample(range(n), size)
        if m.is_independent(s):
            for r in range(len(s)):
                sub = s[:r] + s[r + 1:]
                assert m.is_independent(sub), (s, sub)
    # augmentation: |A| > |B| both independent => exists x in A\B extending B
    for _ in range(trials):
        a = rnd.sample(range(n), min(n, rnd.randrange(2, 6)))
        b = rnd.sample(range(n), rnd.randrange(1, len(a)))
        a = m.greedy_independent(a, len(a))
        b = m.greedy_independent(b, len(b))
        if len(a) > len(b):
            assert any(
                m.is_independent(b + [x]) for x in a if x not in b
            ), (a, b)


@settings(max_examples=25, deadline=None)
@given(partition_instances)
def test_partition_axioms(inst):
    n, h, cap, rnd = inst
    _check_axioms(_mk_partition(n, h, cap, rnd), n, rnd)


@settings(max_examples=25, deadline=None)
@given(transversal_instances)
def test_transversal_axioms(inst):
    n, h, gamma, rnd = inst
    _check_axioms(_mk_transversal(n, h, gamma, rnd), n, rnd)


@settings(max_examples=20, deadline=None)
@given(transversal_instances)
def test_transversal_matching_vs_bruteforce(inst):
    """Kuhn maximum matching == brute-force max independent subset size."""
    n, h, gamma, rnd = inst
    m = _mk_transversal(n, h, gamma, rnd)
    idxs = list(range(min(n, 8)))

    def brute_max():
        best = 0
        for r in range(len(idxs), 0, -1):
            for comb in itertools.combinations(idxs, r):
                # check perfect matching by brute force over category choices
                def ok(rem, used):
                    if not rem:
                        return True
                    x = rem[0]
                    for c in m.cats[x]:
                        if c >= 0 and c not in used:
                            if ok(rem[1:], used | {int(c)}):
                                return True
                    return False

                if ok(list(comb), set()):
                    return r
        return 0

    assert m.max_matching(idxs) == brute_max()


@settings(max_examples=20, deadline=None)
@given(transversal_instances)
def test_greedy_independent_is_maximum(inst):
    n, h, gamma, rnd = inst
    m = _mk_transversal(n, h, gamma, rnd)
    full = m.greedy_independent(list(range(n)), n)
    assert len(full) == m.max_matching(range(n))
    assert m.is_independent(full)


# --------------------------------------------------------------------------
# jit-side vectorized helpers
# --------------------------------------------------------------------------


def test_rank_in_group():
    g = jnp.array([0, 1, 0, 0, 1, 2], jnp.int32)
    v = jnp.array([1, 1, 1, 0, 1, 1], bool)
    r = rank_in_group(g, v, 3)
    assert list(np.asarray(r)[[0, 1, 2, 4, 5]]) == [0, 0, 1, 1, 0]
    assert int(r[3]) > 100  # invalid parked


@settings(max_examples=20, deadline=None)
@given(partition_instances, st.integers(1, 4), st.integers(1, 3))
def test_partition_extract_matches_host_greedy(inst, k, tau):
    """The vectorized Thm-1 EXTRACT picks, per cluster, an independent set of
    the size the host greedy achieves (largest <= k)."""
    n, h, cap, rnd = inst
    m = _mk_partition(n, h, cap, rnd)
    assign = np.array([rnd.randrange(tau) for _ in range(n)], np.int32)
    mask = np.asarray(partition_extract_mask(
        jnp.asarray(assign), jnp.asarray(m.cats[:, None]),
        jnp.asarray(m.caps, jnp.int32), jnp.ones((n,), bool), k, tau, h,
    ))
    for c in range(tau):
        members = np.flatnonzero(assign == c)
        sel = [i for i in members if mask[i]]
        assert m.is_independent(sel)
        want = len(m.greedy_independent(list(members), k))
        assert len(sel) == want, (c, sel, want)


@settings(max_examples=20, deadline=None)
@given(transversal_instances, st.integers(1, 3), st.integers(1, 3))
def test_transversal_extract_covers_categories(inst, k, tau):
    """The matching-free rule keeps min(k, |A ∩ C|) points of every category
    present in every cluster (the sufficient condition of DESIGN.md §8.4)."""
    n, h, gamma, rnd = inst
    m = _mk_transversal(n, h, gamma, rnd)
    assign = np.array([rnd.randrange(tau) for _ in range(n)], np.int32)
    mask = np.asarray(transversal_extract_mask(
        jnp.asarray(assign), jnp.asarray(m.cats),
        jnp.ones((n,), bool), k, tau, h,
    ))
    for c in range(tau):
        members = np.flatnonzero(assign == c)
        for a in range(h):
            in_cat = [i for i in members if a in set(m.cats[i])]
            kept = [i for i in in_cat if mask[i]]
            assert len(kept) >= min(k, len(in_cat)), (c, a, kept, in_cat)


def test_uniform_matroid():
    m = UniformMatroid(10, 3)
    assert m.is_independent([0, 1, 2])
    assert not m.is_independent([0, 1, 2, 3])
    assert not m.is_independent([0, 0, 1])
