"""Freshness contract of the epoch-snapshot serving runtime: queries
concurrent with async ingestion always answer from a *published* epoch
(never a torn state), ``flush()`` barriers to the newest epoch, and the
epoch-aware snapshot path is a no-op on an unchanged stream."""
import threading

import numpy as np
import pytest

from conftest import make_clustered_points
from repro.core.matroid import MatroidSpec, PartitionMatroid
from repro.serve.diversity import (
    DiversityQuery,
    DiversityService,
    QueryFrontend,
    StreamRuntime,
)


def _instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


def test_flush_round_trips_to_newest_epoch(rng):
    """Every batch submitted before flush() is covered by the returned
    epoch, and the async stream is bit-identical to the same batches
    ingested synchronously."""
    P, cats, caps, spec, k = _instance(rng)
    n, batch = P.shape[0], 100
    rt = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    fe = QueryFrontend(rt)
    with rt:
        for off in range(0, n, batch):
            rt.submit(P[off:off + batch], cats[off:off + batch])
        e = rt.flush()
        assert rt.n_offered == n  # the barrier covered every batch
        snap = rt.latest()
        assert snap.epoch == e and snap.n_offered == n
        res = fe.query(DiversityQuery(k=k), min_epoch=e)
        assert res.epoch >= e
    # parity with the synchronous façade over the same batch sequence
    svc = DiversityService(spec, k, tau=12, caps=caps, block_size=32)
    for off in range(0, n, batch):
        svc.ingest(P[off:off + batch], cats[off:off + batch])
    _, _, src = svc.snapshot()
    assert np.array_equal(snap.src_idx, src)
    ref = svc.query(DiversityQuery(k=k))
    assert sorted(res.indices.tolist()) == sorted(ref.indices.tolist())
    assert res.diversity == ref.diversity


def test_concurrent_queries_always_answer_published_epochs(rng):
    """Under concurrent submit+query load every answer names a published
    epoch and is internally consistent with exactly that epoch's snapshot
    (size and membership) — the no-torn-reads guarantee."""
    P, cats, caps, spec, k = _instance(rng, n=800)
    n, batch = P.shape[0], 50
    history: dict[int, tuple] = {}

    def on_publish(snap):
        history[snap.epoch] = (
            snap.fingerprint, snap.size, set(snap.src_idx.tolist())
        )

    rt = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32,
                       publish_every=2, on_publish=on_publish)
    fe = QueryFrontend(rt)
    # seed + warm the query path so the concurrent phase measures steady
    # state rather than first-compile
    rt.ingest(P[:batch], cats[:batch])
    fe.query(DiversityQuery(k=k))
    results, errors = [], []

    def reader():
        try:
            for _ in range(25):
                results.append(fe.query(DiversityQuery(k=k)))
        except BaseException as exc:  # pragma: no cover - surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    with rt:
        for t in threads:
            t.start()
        for off in range(batch, n, batch):
            rt.submit(P[off:off + batch], cats[off:off + batch])
        for t in threads:
            t.join()
        rt.flush()
    assert not errors
    assert results
    m = PartitionMatroid(cats[:, 0], caps)
    seen_epochs = [r.epoch for r in results]
    assert min(seen_epochs) >= 1
    for r in results:
        assert r.epoch in history, "answer from an unpublished epoch"
        _fp, size, src = history[r.epoch]
        assert r.coreset_size == size, "torn read: size != epoch snapshot"
        assert set(r.indices.tolist()) <= src, (
            "torn read: selection outside the epoch's coreset"
        )
        assert m.is_independent(list(r.indices))
    # publication is monotone and flush() landed the newest epoch
    assert rt.latest().epoch == max(history)
    assert rt.latest().n_offered == n


def test_min_epoch_blocks_until_published_and_validates(rng):
    P, cats, caps, spec, k = _instance(rng, n=200)
    rt = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    fe = QueryFrontend(rt)
    with rt:
        rt.ingest(P[:100], cats[:100])
        e1 = rt.refresh().epoch
        # min_epoch ahead of anything in flight is refused, not deadlocked
        with pytest.raises(ValueError, match="min_epoch"):
            fe.query(DiversityQuery(k=k), min_epoch=e1 + 5)
        # a submit in flight satisfies a future min_epoch once drained
        rt.submit(P[100:], cats[100:])
        e2 = rt.flush()
        assert e2 > e1
        res = fe.query(DiversityQuery(k=k), min_epoch=e2)
        assert res.epoch >= e2


def test_worker_errors_surface_and_truncate_the_stream(rng):
    P, cats, caps, spec, k = _instance(rng, n=100)
    rt = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    with rt:
        rt.ingest(P[:50], cats[:50])
        bad = np.zeros((10, 3), np.int32)  # wrong cats width -> scan refuses
        rt.submit(P[50:60], bad)
        try:
            # a batch behind the failing one must NOT be ingested out of
            # order around the gap — the stream truncates at the failure
            rt.submit(P[60:70], cats[60:70])
        except RuntimeError:
            pass  # the worker may have recorded the error already
        with pytest.raises(RuntimeError, match="async ingest worker"):
            rt.flush()
        with pytest.raises(RuntimeError, match="async ingest worker"):
            rt.submit(P[70:80], cats[70:80])
        assert rt.n_offered == 50, "stream did not truncate at the failure"
        assert rt.pending == 0, "dropped batches left pending stuck"


def test_close_is_idempotent_and_stops_submit(rng):
    P, cats, caps, spec, k = _instance(rng, n=100)
    rt = StreamRuntime(spec, k, tau=12, caps=caps, block_size=32)
    rt.submit(P[:50], cats[:50])
    rt.flush()
    rt.close()
    rt.close()
    with pytest.raises(RuntimeError, match="closed"):
        rt.submit(P[50:], cats[50:])
    # synchronous paths and published epochs stay usable after close
    rt.ingest(P[50:], cats[50:])
    assert rt.n_offered == 100
    assert rt.refresh(force=True).n_offered == 100


def test_snapshot_is_epoch_aware_noop_on_unchanged_state(rng):
    """Satellite: repeated ``snapshot()`` (and the cache entry behind
    ``query``) with no state change returns the already-materialized epoch
    buffers — no fresh device pull, same host arrays."""
    P, cats, caps, spec, k = _instance(rng, n=300)
    svc = DiversityService(spec, k, tau=12, caps=caps)
    svc.ingest(P, cats)
    a = svc.snapshot()
    mats = svc.runtime.snapshot_materializations
    b = svc.snapshot()
    assert all(x is y for x, y in zip(a, b)), "unchanged snapshot recopied"
    assert svc.runtime.snapshot_materializations == mats
    # a no-op ingest (duplicate of an existing delegate, full cluster)
    # advances the stream but must not re-materialize
    rep = svc.ingest(a[0][:1], a[1][:1])
    svc.query(DiversityQuery(k=k))
    c = svc.snapshot()
    if not rep.coreset_changed:
        assert c[0] is a[0]
        assert svc.runtime.snapshot_materializations == mats
    # an all-invalid (warmup-style) padded batch is a scan no-op too
    svc.ingest(np.zeros((0, P.shape[1]), np.float32), pad_to=svc.block_size)
    svc.snapshot()
    assert svc.runtime.snapshot_materializations == (
        mats if not rep.coreset_changed else mats + 1
    )


def test_unchanged_epoch_not_bumped_by_queries(rng):
    """The sequential ingest->query flow does not inflate the epoch
    counter: queries on an unchanged stream serve the same epoch."""
    P, cats, caps, spec, k = _instance(rng, n=300)
    svc = DiversityService(spec, k, tau=12, caps=caps)
    svc.ingest(P, cats)
    e1 = svc.query(DiversityQuery(k=k)).epoch
    e2 = svc.query(DiversityQuery(k=k)).epoch
    assert e1 == e2
    assert svc.runtime.epochs_published == e2
