"""Multi-device behaviour (subprocess with forced host device count):
MapReduce coreset sharding, compressed pod all-reduce, elastic restore."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_mapreduce_coreset_8_shards():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp, json
        from repro.core import solve_dmmc, PartitionMatroid
        from repro.core.matroid import MatroidSpec
        rng = np.random.default_rng(0)
        n, h, k = 1600, 4, 4
        base = rng.normal(size=(n, 2)) @ rng.normal(size=(2, 8))
        P = (base + 0.05*rng.normal(size=(n, 8))).astype(np.float32)
        cats = rng.integers(0, h, (n, 1)).astype(np.int32)
        caps = np.full(h, 2, np.int32)
        spec = MatroidSpec("partition", num_categories=h, gamma=1)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        s_mr = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=64,
                          setting="mapreduce", mesh=mesh)
        s_mr2 = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=64,
                           setting="mapreduce", mesh=mesh, round2_tau=16)
        s_seq = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=64,
                           setting="sequential")
        m = PartitionMatroid(cats[:, 0], caps)
        assert m.is_independent(list(s_mr.indices)), s_mr.indices
        assert m.is_independent(list(s_mr2.indices))
        assert s_mr2.coreset_size < s_mr.coreset_size
        print(json.dumps(dict(mr=s_mr.diversity, mr2=s_mr2.diversity,
                              seq=s_seq.diversity)))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # MR quality within 5% of sequential; round-2 within 10%
    assert res["mr"] >= 0.95 * res["seq"], res
    assert res["mr2"] >= 0.90 * res["seq"], res


def test_compressed_pod_allreduce():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp, json, functools
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import (
            pod_allreduce_compressed, init_residual)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("pod",))
        g_global = jnp.asarray(
            np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)

        from repro.compat import shard_map
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P("pod"), P("pod")),
                           out_specs=(P("pod"), P("pod")))
        def run(g, r):
            red, new_r = pod_allreduce_compressed(
                {"g": g[0]}, {"g": r[0]}, "pod")
            return red["g"][None], new_r["g"][None]

        r0 = jnp.zeros((8, 64))
        red, _ = run(g_global, r0)
        want = jnp.mean(g_global, axis=0)
        err = float(jnp.max(jnp.abs(red[0] - want)))
        scale = float(jnp.max(jnp.abs(want)))
        print(json.dumps(dict(err=err, scale=scale)))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # int8 quantization error bounded by ~scale/127 * small factor
    assert res["err"] <= res["scale"] / 127 * 8 + 1e-6, res


def test_elastic_restore_across_device_counts(tmp_path):
    """Checkpoint on 4 devices, restore + continue on 8, compare with an
    uninterrupted 1-device run — losses must match closely."""
    common = """
        import numpy as np, jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import LM
        from repro.models.sharding import param_specs
        from repro.train.checkpoint import CheckpointManager
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_state import (
            StepConfig, abstract_train_state, init_train_state,
            make_train_step)
        cfg = get_config("smollm-135m").reduced()
        lm = LM(cfg)
        opt = AdamWConfig(lr=1e-3, master_dtype="float32")
        toks = jax.random.randint(jax.random.PRNGKey(5), (8, 16), 0,
                                  cfg.vocab)
        n = len(jax.devices())
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((n,), ("data",))
        pspecs = param_specs(lm.abstract_params(), ("data",), tp=None)
        sspecs = {"params": pspecs,
                  "opt": {"m": pspecs, "v": pspecs, "step": P(),
                          "master": pspecs},
                  "step": P()}
        ns = lambda t: jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), t,
            is_leaf=lambda x: isinstance(x, P))
        step = jax.jit(make_train_step(lm, opt, StepConfig()),
                       in_shardings=(ns(sspecs), None),
                       out_shardings=(ns(sspecs), None))
        abstract = jax.eval_shape(
            lambda: init_train_state(lm, jax.random.PRNGKey(0), opt))
    """
    d = str(tmp_path)
    # phase 1: 4 devices, 3 steps, save
    run_py(common + f"""
        with mesh:
            state = init_train_state(lm, jax.random.PRNGKey(0), opt)
            for _ in range(3):
                state, m = step(state, {{"tokens": toks}})
            CheckpointManager({d!r}, async_write=False).save(3, state)
        print("saved", float(m["loss"]))
    """, devices=4)
    # phase 2: 8 devices, restore, 2 more steps
    out8 = run_py(common + f"""
        with mesh:
            mgr = CheckpointManager({d!r}, async_write=False)
            state = mgr.restore(3, abstract, ns(sspecs))
            for _ in range(2):
                state, m = step(state, {{"tokens": toks}})
        print(json.dumps(float(m["loss"])))
    """, devices=8)
    # reference: single device, 5 uninterrupted steps
    out1 = run_py(common + """
        with mesh:
            state = init_train_state(lm, jax.random.PRNGKey(0), opt)
            for _ in range(5):
                state, m = step(state, {"tokens": toks})
        print(json.dumps(float(m["loss"])))
    """, devices=1)
    l8 = json.loads(out8.strip().splitlines()[-1])
    l1 = json.loads(out1.strip().splitlines()[-1])
    assert abs(l8 - l1) < 5e-2, (l8, l1)


def test_global_gmm_matches_single_machine():
    """Beyond-paper distributed GMM: the 8-shard global traversal produces
    the SAME centers/radius as single-machine GMM on the concatenated data,
    and its coreset beats the per-shard-union construction at equal tau."""
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp, json
        from repro.core.distributed_gmm import distributed_coreset
        from repro.core.gmm import gmm_fixed
        from repro.core.matroid import MatroidSpec
        rng = np.random.default_rng(3)
        n, h, k, tau = 1600, 4, 4, 16
        base = rng.normal(size=(n, 2)) @ rng.normal(size=(2, 8))
        P = (base + 0.05*rng.normal(size=(n, 8))).astype(np.float32)
        cats = rng.integers(0, h, (n, 1)).astype(np.int32)
        caps = np.full(h, 2, np.int32)
        spec = MatroidSpec("partition", num_categories=h, gamma=1)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        cs, radius, delta = distributed_coreset(
            mesh, jnp.asarray(P), jnp.asarray(cats), jnp.ones((n,), bool),
            spec, jnp.asarray(caps), k, tau)
        ref = gmm_fixed(jnp.asarray(P), jnp.ones((n,), bool), tau)
        print(json.dumps(dict(
            radius=float(radius), ref_radius=float(ref.radius),
            delta=float(delta), ref_delta=float(ref.delta),
            size=int(np.asarray(cs.valid).sum()))))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["radius"] - res["ref_radius"]) < 1e-4, res
    assert abs(res["delta"] - res["ref_delta"]) < 1e-4, res
    assert res["size"] > 0
