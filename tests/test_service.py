"""Online diversity service: incremental ingestion, cache discipline,
service/offline parity, and the vmapped batched solver."""
import numpy as np
import pytest
import jax.numpy as jnp

from conftest import make_clustered_points
from repro.core import solve_dmmc
from repro.core.diversity import VARIANTS
from repro.core.matroid import (
    MatroidSpec,
    PartitionMatroid,
    TransversalMatroid,
)
from repro.core.streaming import (
    ingest_batch,
    init_stream_state,
    snapshot_coreset,
    stream_coreset,
)
from repro.serve.diversity import DiversityQuery, DiversityService


def _partition_instance(rng, n=400, h=4, k=4):
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P, cats, caps, spec, k


def _transversal_instance(rng, n=300, h=5, gamma=2, k=3):
    P = make_clustered_points(rng, n=n)
    cats = np.full((n, gamma), -1, np.int32)
    cats[:, 0] = rng.integers(0, h, n)
    extra = rng.random(n) < 0.4
    cats[extra, 1] = rng.integers(0, h, extra.sum())
    spec = MatroidSpec("transversal", num_categories=h, gamma=gamma)
    return P, cats, None, spec, k


# --------------------------------------------------------------------------
# ingestion API
# --------------------------------------------------------------------------


@pytest.mark.parametrize("block_size", [1, 7, 64, 256])
def test_incremental_ingestion_matches_one_shot(rng, block_size):
    """Batched == one-shot, and every blocked scan == the per-point scan
    (the one-shot reference is pinned to block_size=1)."""
    P, cats, caps, spec, k = _partition_instance(rng)
    n, d = P.shape
    tau = 12
    caps_j = jnp.asarray(caps)
    cs1, st1 = stream_coreset(
        jnp.asarray(P), jnp.asarray(cats), jnp.ones((n,), bool),
        spec, caps_j, k, tau, block_size=1,
    )
    st = init_stream_state(d, 1, spec, k, tau)
    off = 0
    for b in (100, 37, 163, 100):
        st = ingest_batch(
            st, jnp.asarray(P[off:off + b]), jnp.asarray(cats[off:off + b]),
            jnp.ones((b,), bool), spec, caps_j, k, tau, base_index=off,
            block_size=block_size,
        )
        off += b
    assert off == n
    for f in st1._fields:
        assert np.array_equal(
            np.asarray(getattr(st1, f)), np.asarray(getattr(st, f))
        ), f"StreamState field {f} diverged between one-shot and batched"
    cs2 = snapshot_coreset(st)
    assert np.array_equal(np.asarray(cs1.src_idx), np.asarray(cs2.src_idx))
    assert np.array_equal(np.asarray(cs1.valid), np.asarray(cs2.valid))


def test_service_snapshot_matches_offline_coreset(rng):
    P, cats, caps, spec, k = _partition_instance(rng)
    tau = 12
    svc = DiversityService(spec, k, tau=tau, caps=caps)
    for off in range(0, P.shape[0], 128):
        svc.ingest(P[off:off + 128], cats[off:off + 128])
    sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                     setting="streaming")
    _, _, src = svc.snapshot()
    assert np.array_equal(src, sol.coreset_indices)


# --------------------------------------------------------------------------
# sharded ingestion (§3 composability: per-shard coresets union on snapshot)
# --------------------------------------------------------------------------


def test_sharded_service_matches_per_shard_streams(rng):
    """Each shard's state equals ingesting that shard's round-robin
    sub-stream alone; the snapshot is their union in shard order.

    placement="vmap" pins the row-granular drive this test describes
    (the CPU auto default is the batch-granular pipeline drive)."""
    from repro.core.compose import unstack_shards

    P, cats, caps, spec, k = _partition_instance(rng)
    n = P.shape[0]
    tau, S = 12, 3
    svc = DiversityService(spec, k, tau=tau, caps=caps, num_shards=S,
                           block_size=32, placement="vmap")
    for off in range(0, n, 150):
        svc.ingest(P[off:off + 150], cats[off:off + 150])
    caps_j = jnp.asarray(caps)
    union_src = []
    for s, shard_st in enumerate(unstack_shards(svc.state)):
        rows = np.arange(s, n, S)
        st = init_stream_state(P.shape[1], 1, spec, k, tau)
        st = ingest_batch(
            st, jnp.asarray(P[rows]), jnp.asarray(cats[rows]),
            jnp.ones((len(rows),), bool), spec, caps_j, k, tau,
            src=jnp.asarray(rows, jnp.int32),
        )
        for f in st._fields:
            assert np.array_equal(
                np.asarray(getattr(st, f)), np.asarray(getattr(shard_st, f))
            ), f"shard {s} field {f} diverged"
        cs = snapshot_coreset(st)
        v = np.asarray(cs.valid)
        union_src.append(np.asarray(cs.src_idx)[v])
    _, _, src = svc.snapshot()
    assert np.array_equal(src, np.concatenate(union_src))


def test_sharded_service_quality_and_cache(rng):
    """Union coreset answers are within the §3 composability guarantee of
    the one-shot coreset's answer, and the pdist cache is invalidated only
    when the union changes."""
    P, cats, caps, spec, k = _partition_instance(rng, n=600)
    tau = 12
    svc1 = DiversityService(spec, k, tau=tau, caps=caps)
    svc4 = DiversityService(spec, k, tau=tau, caps=caps, num_shards=4,
                            block_size=32)
    svc1.ingest(P, cats)
    svc4.ingest(P, cats)
    r1 = svc1.query(DiversityQuery(k=k))
    r4 = svc4.query(DiversityQuery(k=k))
    # the union is a superset-quality coreset: allow a generous slack but
    # catch gross degradation (empirically the union is >= the single shard)
    assert r4.diversity >= 0.8 * r1.diversity
    assert r4.coreset_size >= r1.coreset_size
    m = PartitionMatroid(cats[:, 0], caps)
    assert m.is_independent(list(r4.indices))
    # warm path: re-ingesting a delegate's duplicate that changes nothing
    builds = svc4.cache.stats.builds
    pts_c, cats_c, _ = svc4.snapshot()
    rep = svc4.ingest(pts_c[:1], cats_c[:1])
    svc4.query(DiversityQuery(k=k))
    assert svc4.cache.stats.builds == builds + (1 if rep.coreset_changed else 0)


def test_sharded_ingest_requires_multiple_shards(rng):
    P, cats, caps, spec, k = _partition_instance(rng, n=50)
    svc = DiversityService(spec, k, tau=8, caps=caps)
    with pytest.raises(ValueError):
        svc.ingest_sharded(P, cats)
    with pytest.raises(ValueError):
        svc.ingest_pipeline(P, cats)
    with pytest.raises(ValueError):
        DiversityService(spec, k, tau=8, caps=caps, num_shards=0)
    # the row-granular drive must refuse a pipeline service rather than
    # silently replacing its per-shard state list with a stacked state
    pipe = DiversityService(spec, k, tau=8, caps=caps, num_shards=2,
                            placement="pipeline")
    with pytest.raises(ValueError, match="pipeline"):
        pipe.ingest_sharded(P, cats)
    with pytest.raises(ValueError):
        DiversityService(spec, k, tau=8, caps=caps, num_shards=2,
                         placement="nope")


def test_placement_resolution(rng):
    """Explicit placements stick; auto resolves per backend/devices (on
    the CPU test environment: pipeline for sharded, vmap for 1 shard)."""
    import jax

    P, cats, caps, spec, k = _partition_instance(rng, n=50)
    for pl in ("vmap", "shard_map", "pipeline"):
        svc = DiversityService(spec, k, tau=8, caps=caps, num_shards=2,
                               placement=pl)
        assert svc.placement == pl
    auto = DiversityService(spec, k, tau=8, caps=caps, num_shards=2)
    if jax.default_backend() == "cpu":
        assert auto.placement == "pipeline"
    assert DiversityService(spec, k, tau=8, caps=caps).placement == "vmap"


def test_shard_map_placement_matches_vmap(rng):
    """The shard_map drive is the same scan under a different parallel
    drive: bit-identical service state to the vmap drive."""
    P, cats, caps, spec, k = _partition_instance(rng)
    svcs = {
        pl: DiversityService(spec, k, tau=12, caps=caps, num_shards=2,
                             block_size=32, placement=pl)
        for pl in ("vmap", "shard_map")
    }
    for off in range(0, P.shape[0], 150):
        for svc in svcs.values():
            svc.ingest(P[off:off + 150], cats[off:off + 150])
    a, b = svcs["vmap"].state, svcs["shard_map"].state
    for f in a._fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f
    ra = svcs["vmap"].query(DiversityQuery(k=k))
    rb = svcs["shard_map"].query(DiversityQuery(k=k))
    assert ra.indices.tolist() == rb.indices.tolist()


def test_pipeline_placement_matches_per_batch_streams(rng):
    """Pipeline placement: batch b goes wholly to shard b % S; each shard
    state equals ingesting its own batch sub-stream through the plain
    scan; the snapshot is the shard-major union; queries answer on it."""
    from repro.core.matroid import PartitionMatroid
    from repro.core.streaming import ingest_batch, init_stream_state

    P, cats, caps, spec, k = _partition_instance(rng)
    n, batch, tau, S = P.shape[0], 100, 12, 2
    svc = DiversityService(spec, k, tau=tau, caps=caps, num_shards=S,
                           block_size=32, placement="pipeline")
    for off in range(0, n, batch):
        svc.ingest(P[off:off + batch], cats[off:off + batch])
    assert isinstance(svc.state, list) and len(svc.state) == S
    caps_j = jnp.asarray(caps)
    union_src = []
    for s in range(S):
        st = init_stream_state(P.shape[1], 1, spec, k, tau)
        for bi, off in enumerate(range(0, n, batch)):
            if bi % S != s:
                continue
            m = min(batch, n - off)
            pad = -m % 32
            pts = np.concatenate(
                [P[off:off + m], np.zeros((pad, P.shape[1]), np.float32)]
            )
            ca = np.concatenate(
                [cats[off:off + m], np.full((pad, 1), -1, np.int32)]
            )
            st = ingest_batch(
                st, jnp.asarray(pts), jnp.asarray(ca),
                jnp.asarray(np.arange(m + pad) < m), spec, caps_j, k, tau,
                base_index=off, block_size=32,
            )
        for f in st._fields:
            assert np.array_equal(
                np.asarray(getattr(st, f)), np.asarray(getattr(svc.state[s], f))
            ), f"pipeline shard {s} field {f}"
        cs = snapshot_coreset(st)
        v = np.asarray(cs.valid)
        union_src.append(np.asarray(cs.src_idx)[v])
    _, _, src = svc.snapshot()
    assert np.array_equal(src, np.concatenate(union_src))
    r = svc.query(DiversityQuery(k=k))
    m = PartitionMatroid(cats[:, 0], caps)
    assert m.is_independent(list(r.indices))
    # cache discipline: a no-op re-ingest keeps the fingerprint/cache warm
    builds = svc.cache.stats.builds
    pts_c, cats_c, _ = svc.snapshot()
    rep = svc.ingest(pts_c[:1], cats_c[:1])
    svc.query(DiversityQuery(k=k))
    assert svc.cache.stats.builds == builds + (
        1 if rep.coreset_changed else 0
    )


def test_warmup_compiles_ahead_of_time(rng):
    """warmup() is a bit-exact no-op on the stream state, primes the jit
    cache for the bucketed ingest/query shapes, and makes the first real
    query cheap. Works before the first ingest (given d) and after."""
    P, cats, caps, spec, k = _partition_instance(rng, n=300)
    svc = DiversityService(spec, k, tau=12, caps=caps)
    with pytest.raises(ValueError):
        svc.warmup()  # no state yet and no dimension given
    rep = svc.warmup(d=P.shape[1], ingest_sizes=(300,))
    assert any(key.startswith("ingest[") for key in rep)
    assert rep["queries"].startswith("skipped")
    assert svc.n_offered == 0  # warmup offered nothing to the stream
    svc.ingest(P, cats)
    rep2 = svc.warmup(ks=(k,), query_batch_sizes=(1,))
    assert f"query[sum k={k} b=1]" in rep2
    fp = svc._fingerprint
    builds = svc.cache.stats.builds
    assert builds == 1  # warmup built the matrix once
    res = svc.query(DiversityQuery(k=k))
    assert res.from_cache and svc.cache.stats.builds == builds
    assert svc._fingerprint == fp
    # parity with a never-warmed service over the same stream
    ref = DiversityService(spec, k, tau=12, caps=caps)
    ref.ingest(P, cats)
    r2 = ref.query(DiversityQuery(k=k))
    assert res.indices.tolist() == r2.indices.tolist()
    assert res.diversity == r2.diversity


def test_warmup_sharded_states_unchanged(rng):
    """Sharded warmup primes without perturbing any shard state (the
    all-invalid batch is a scan no-op) for both sharded placements."""
    P, cats, caps, spec, k = _partition_instance(rng, n=200)
    for pl in ("vmap", "pipeline"):
        svc = DiversityService(spec, k, tau=12, caps=caps, num_shards=2,
                               block_size=32, placement=pl)
        svc.ingest(P[:100], cats[:100])
        before = svc.snapshot()
        svc.warmup(ingest_sizes=(100,), ks=(k,))
        after = svc.snapshot()
        for a, b in zip(before, after):
            assert np.array_equal(a, b), pl
        svc.ingest(P[100:], cats[100:])  # service still ingests fine


# --------------------------------------------------------------------------
# service/offline parity (satellite: indices AND diversity value)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("instance", ["partition", "transversal"])
def test_service_matches_solve_dmmc(rng, instance, variant):
    if instance == "partition":
        P, cats, caps, spec, k = _partition_instance(rng, n=300)
    else:
        P, cats, caps, spec, k = _transversal_instance(rng)
    tau = 10
    svc = DiversityService(spec, k, tau=tau, caps=caps)
    for off in range(0, P.shape[0], 97):
        svc.ingest(P[off:off + 97], cats[off:off + 97])
    sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                     setting="streaming", variant=variant)
    # the host engine is bit-identical to the offline driver: same
    # selection order, same canonical value
    res = svc.query(DiversityQuery(k=k, variant=variant), engine="host")
    assert res.indices.tolist() == sol.indices.tolist()
    assert res.diversity == sol.diversity
    assert res.coreset_size == sol.coreset_size
    # the auto engine (default) carries the parity guarantee: same set,
    # same canonical value, whatever engine the registry picked
    auto = svc.query(DiversityQuery(k=k, variant=variant))
    assert sorted(auto.indices.tolist()) == sorted(res.indices.tolist())
    assert auto.diversity == res.diversity


def test_vmap_engine_matches_host(rng):
    P, cats, caps, spec, k = _partition_instance(rng, n=500, h=5, k=5)
    svc = DiversityService(spec, k, tau=16, caps=caps)
    svc.ingest(P, cats)
    qs = [
        DiversityQuery(k=kk, caps=cc, allowed_cats=ac)
        for kk in (2, 3, 5)
        for cc in (None, (1,) * 5)
        for ac in (None, frozenset({0, 1, 2, 3}))
    ]
    hosts = svc.query_batch(qs, engine="host")
    vmaps = svc.query_batch(qs, engine="vmap")  # legacy alias of jit_sum
    for q, a, b in zip(qs, hosts, vmaps):
        assert sorted(a.indices.tolist()) == sorted(b.indices.tolist()), q
        # both engines report the canonical (sorted, float64) objective of
        # their selection, so agreement on the set means equal floats
        assert b.diversity == a.diversity
        assert a.engine == "host_local_search" and b.engine == "jit_sum"


def test_query_default_engine_consistency(rng):
    """query() and query_batch() share the engine="auto" default: one
    query answered alone equals the same query answered in a batch."""
    P, cats, caps, spec, k = _partition_instance(rng, n=300)
    svc = DiversityService(spec, k, tau=12, caps=caps)
    svc.ingest(P, cats)
    q = DiversityQuery(k=k)
    one = svc.query(q)
    batch = svc.query_batch([q])[0]
    # the cost model may route a tiny batch to either parity engine, but
    # query() and query_batch([q]) must agree (same model, same shape)
    assert one.engine == batch.engine
    assert one.engine in ("jit_sum", "host_local_search")
    assert one.indices.tolist() == batch.indices.tolist()
    assert one.diversity == batch.diversity


def test_uniform_vmap_engine(rng):
    P = make_clustered_points(rng, n=400)
    spec = MatroidSpec("uniform")
    svc = DiversityService(spec, 6, tau=12)
    svc.ingest(P)
    a = svc.query(DiversityQuery(k=6), engine="host")
    b = svc.query(DiversityQuery(k=6), engine="vmap")
    assert sorted(a.indices.tolist()) == sorted(b.indices.tolist())


# --------------------------------------------------------------------------
# query semantics: caps overrides and category filters
# --------------------------------------------------------------------------


def test_query_respects_caps_and_filters(rng):
    P, cats, caps, spec, k = _partition_instance(rng, n=400, h=4, k=4)
    svc = DiversityService(spec, k, tau=12, caps=caps)
    svc.ingest(P, cats)
    for engine in ("host", "vmap"):
        r = svc.query(DiversityQuery(k=4, caps=(1, 1, 1, 1)), engine=engine)
        got = cats[r.indices, 0]
        assert len(got) == len(set(got)), f"caps=1 violated ({engine})"
        r2 = svc.query(
            DiversityQuery(k=3, allowed_cats=frozenset({0, 1})), engine=engine
        )
        assert set(cats[r2.indices, 0]) <= {0, 1}, engine
    m = PartitionMatroid(cats[:, 0], caps)
    r3 = svc.query(DiversityQuery(k=4))
    assert m.is_independent(list(r3.indices))


def test_transversal_batch_independent(rng):
    P, cats, _, spec, k = _transversal_instance(rng)
    svc = DiversityService(spec, k, tau=10)
    svc.ingest(P, cats)
    m = TransversalMatroid(cats, spec.num_categories)
    qs = [DiversityQuery(k=kk) for kk in (2, 3)]
    auto = svc.query_batch(qs)
    hosts = svc.query_batch(qs, engine="host")
    for r, hr in zip(auto, hosts):
        assert m.is_independent(list(r.indices))
        # transversal sum is covered by both parity engines; the cost
        # model picks by estimated latency for the batch shape
        assert r.engine in ("jit_sum", "host_local_search")
        assert hr.engine == "host_local_search"
        assert sorted(r.indices.tolist()) == sorted(hr.indices.tolist())
        assert r.diversity == hr.diversity


def test_transversal_star_tree_hint_engines(rng):
    """star/tree queries stay on the exact host engine under auto, and
    opt into the vmapped greedy via engine_hint (never silently)."""
    P, cats, _, spec, k = _transversal_instance(rng)
    svc = DiversityService(spec, k, tau=10)
    svc.ingest(P, cats)
    m = TransversalMatroid(cats, spec.num_categories)
    for variant in ("star", "tree"):
        exact = svc.query(DiversityQuery(k=3, variant=variant))
        fast = svc.query(
            DiversityQuery(k=3, variant=variant, engine_hint="jit_greedy")
        )
        assert exact.engine == "host_exhaustive"
        assert fast.engine == "jit_greedy"
        assert m.is_independent(list(fast.indices))
        # greedy is a heuristic: never better than the exact optimum
        assert fast.diversity <= exact.diversity + 1e-9
        # hint that doesn't apply falls back to the auto policy
        r = svc.query(DiversityQuery(k=3, engine_hint="jit_greedy"))
        assert r.engine in ("jit_sum", "host_local_search")


# --------------------------------------------------------------------------
# cache discipline (acceptance: warm batch reuses the matrix, no rebuilds)
# --------------------------------------------------------------------------


def test_warm_batch_of_32_reuses_cached_matrix(rng):
    P, cats, caps, spec, k = _partition_instance(rng, n=500, h=4, k=5)
    svc = DiversityService(spec, k, tau=16, caps=caps)
    svc.ingest(P, cats)
    svc.query(DiversityQuery(k=k))  # warm-up: builds the matrix once
    assert svc.cache.stats.builds == 1
    qs = [
        DiversityQuery(
            k=2 + i % 4,
            variant="sum" if i % 3 else "tree",
            caps=None if i % 2 else (1,) * 4,
            allowed_cats=None if i % 5 else frozenset({0, 1, 2}),
        )
        for i in range(32)
    ]
    out = svc.query_batch(qs)
    assert len(out) == 32
    assert all(r.from_cache for r in out)
    assert svc.cache.stats.builds == 1, "warm batch recomputed pdist"
    engines = {r.engine for r in out}
    assert "host_exhaustive" in engines  # tree queries stay exact
    assert all(
        r.engine in ("jit_sum", "host_local_search")
        for r in out if r.variant == "sum"
    )
    # heterogeneous ks answered
    assert sorted({len(r.indices) for r in out if r.variant == "sum"}) == [
        2, 3, 4, 5
    ]


def test_cache_invalidated_only_on_coreset_change(rng):
    P, cats, caps, spec, k = _partition_instance(rng, n=300)
    svc = DiversityService(spec, k, tau=12, caps=caps)
    rep = svc.ingest(P[:250], cats[:250])
    assert rep.coreset_changed
    svc.query(DiversityQuery(k=k))
    assert svc.cache.stats.builds == 1
    # re-ingesting points identical to existing delegates' neighborhoods may
    # or may not change the coreset; assert the flag and the cache agree
    rep2 = svc.ingest(P[250:], cats[250:])
    svc.query(DiversityQuery(k=k))
    expected_builds = 2 if rep2.coreset_changed else 1
    assert svc.cache.stats.builds == expected_builds
    # a duplicate of an existing delegate handled by a full cluster: state
    # advances but a no-op ingest (coreset unchanged) must keep the cache
    pts_c, cats_c, _ = svc.snapshot()
    rep3 = svc.ingest(pts_c[:1], cats_c[:1])
    svc.query(DiversityQuery(k=k))
    if not rep3.coreset_changed:
        assert svc.cache.stats.builds == expected_builds
    else:
        assert svc.cache.stats.builds == expected_builds + 1
    assert svc.n_offered == 301


def test_ingest_reports(rng):
    P, cats, caps, spec, k = _partition_instance(rng, n=200)
    svc = DiversityService(spec, k, tau=10, caps=caps)
    r1 = svc.ingest(P[:120], cats[:120])
    r2 = svc.ingest(P[120:], cats[120:])
    assert (r1.n, r2.n) == (120, 80)
    assert r2.total == 200
    assert r2.coreset_size > 0
    with pytest.raises(ValueError):
        DiversityService(MatroidSpec("general"), k, tau=10)
    with pytest.raises(ValueError):
        DiversityService(spec, k, tau=10)  # partition without caps
