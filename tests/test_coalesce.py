"""Micro-batch coalescing + cost-model routing suite (PR 8 acceptance).

Three contracts under test:

* **cost model** — static seeds give host engines the dispatch-dominated
  tiny batches and jit engines the large ones; online observations
  override the seeds (and extrapolate along them across batch-size
  buckets); the ``engine="auto"`` routing they drive is recorded with
  its estimates.
* **cold-tenant admission** — the deadline predictor's empty-histogram
  fallback is the cost model, not "0.0 ⇒ admit anything" (the PR 7 bug:
  a cold tenant's first exhaustive query sailed past any deadline).
* **coalescing parity** — answers produced through the concurrent
  window (multi-thread, multi-tenant, mixed engines/hints/buckets) are
  bit-identical to the direct per-call path, and no caller's window wait
  can stretch past its deadline.
"""
import threading
import time
import zlib

import numpy as np
import pytest

from conftest import make_clustered_points
from repro import obs
from repro.core.matroid import MatroidSpec
from repro.core.solvers import (
    CostModel,
    SolveContext,
    SolveSpec,
    partition_by_engine,
)
from repro.serve.diversity import (
    CoalesceConfig,
    DiversityQuery,
    QueryFrontend,
    StreamRuntime,
)
from repro.serve.diversity.coalesce import AdaptiveWindow, Coalescer


# --------------------------------------------------------------------------
# cost model
# --------------------------------------------------------------------------


def test_seed_crossover_host_small_jit_large():
    cm = CostModel()
    m, k = 20, 4
    # dispatch dominates a single query: the host engine must win
    assert cm.estimate("host_local_search", B=1, kmax=k, m=m) < cm.estimate(
        "jit_sum", B=1, kmax=k, m=m
    )
    # amortized over a big batch the vmapped engine must win
    assert cm.estimate("jit_sum", B=64, kmax=k, m=m) < cm.estimate(
        "host_local_search", B=64, kmax=k, m=m
    )
    # so a finite pow-2 crossover exists and is consistent with both
    b = cm.crossover("jit_sum", "host_local_search", kmax=k, m=m)
    assert b is not None and b & (b - 1) == 0 and 1 < b <= 64


def test_exhaustive_seed_explodes_with_k():
    cm = CostModel()
    small = cm.estimate("host_exhaustive", B=1, kmax=2, m=50)
    big = cm.estimate("host_exhaustive", B=1, kmax=4, m=50)
    assert big > 100 * small  # m**k growth, not linear


def test_observations_override_seeds():
    cm = CostModel()
    seed_est = cm.estimate("jit_sum", B=8, kmax=4, m=32)
    for _ in range(4):
        cm.observe("jit_sum", 8, 4, 32, 0.5)
    assert cm.estimate("jit_sum", B=8, kmax=4, m=32) == pytest.approx(
        0.5, rel=0.3
    )
    assert cm.estimate("jit_sum", B=8, kmax=4, m=32) != seed_est
    assert cm.calibrated("jit_sum", B=8, kmax=4, m=32)
    assert not cm.calibrated("jit_sum", B=8, kmax=4, m=4096)


def test_nearest_bucket_extrapolation():
    """A B=1 measurement informs B=16 estimates along the seed shape —
    10x slower than seed at B=1 stays ~10x slower at B=16."""
    cm = CostModel()
    static1 = cm.estimate("host_local_search", B=1, kmax=4, m=32)
    static16 = cm.estimate("host_local_search", B=16, kmax=4, m=32)
    cm.observe("host_local_search", 1, 4, 32, 10.0 * static1)
    est16 = cm.estimate("host_local_search", B=16, kmax=4, m=32)
    assert est16 == pytest.approx(10.0 * static16, rel=1e-6)


def test_choose_ties_keep_caller_order():
    cm = CostModel(seeds={})  # every engine on the flat fallback seed
    winner, ests = cm.choose(["b_engine", "a_engine"], B=2, kmax=2, m=8)
    assert winner == "b_engine"  # first in caller (priority) order
    assert set(ests) == {"b_engine", "a_engine"}


def test_decision_ring_records_estimates():
    cm = CostModel()
    w, ests = cm.choose(["jit_sum", "host_local_search"], B=4, kmax=4, m=16)
    cm.record_decision(engine=w, candidates=ests, B=4, kmax=4, m=16)
    d = cm.decisions()[-1]
    assert d["engine"] == w and d["B"] == 4
    assert set(d["estimates"]) == {"jit_sum", "host_local_search"}
    assert cm.snapshot()["decisions"][-1] == d


def _sum_ctx(rng, m=24):
    from repro.core.matroid import make_host_matroid

    D = np.abs(rng.normal(size=(m, m))).astype(np.float64)
    D = (D + D.T) / 2
    np.fill_diagonal(D, 0.0)
    spec = MatroidSpec("uniform")
    cats = np.zeros((m, 1), np.int32)
    return SolveContext(
        D=D, spec=spec, cats=cats,
        # host engines need the oracle to be auto-candidates
        matroid_fn=lambda s: make_host_matroid(spec, cats, None, m, s.k),
    )


def test_partition_by_engine_cost_model_routes_by_batch_size(rng):
    ctx = _sum_ctx(rng)
    spec = SolveSpec(k=4)
    small = partition_by_engine(
        ctx, [spec], cost_model=CostModel()
    )
    assert list(small) == ["host_local_search"]
    big = partition_by_engine(
        ctx, [spec] * 64, cost_model=CostModel()
    )
    assert list(big) == ["jit_sum"]
    # batch_size override: one spec routed as if merged into a big group
    merged = partition_by_engine(
        ctx, [spec], cost_model=CostModel(), batch_size=64
    )
    assert list(merged) == ["jit_sum"]
    # None keeps the historical static priority policy bit-for-bit
    legacy = partition_by_engine(ctx, [spec])
    assert list(legacy) == ["jit_sum"]


# --------------------------------------------------------------------------
# frontends under test
# --------------------------------------------------------------------------


def _frontend(rng, reg, *, coalesce=None, n=300, tau=24):
    spec = MatroidSpec("partition", num_categories=4, gamma=1)
    caps = np.full(4, 3, np.int32)
    rt = StreamRuntime(spec, 5, tau=tau, caps=caps, registry=reg)
    fe = QueryFrontend(rt, registry=reg, coalesce=coalesce)
    P = make_clustered_points(rng, n=n)
    cats = rng.integers(0, 4, (n, 1)).astype(np.int32)
    rt.ingest(P, cats)
    return rt, fe


# --------------------------------------------------------------------------
# cold-tenant deadline admission (satellite: PR 7 regression)
# --------------------------------------------------------------------------


def test_cold_predictor_seeds_from_cost_model(rng):
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg)
    # empty histograms: the prediction must come from the cost model,
    # not the old optimistic 0.0
    p = fe._predict_s("default", "host_exhaustive", B=1, kmax=4, m=100)
    assert p == fe.cost_model.estimate("host_exhaustive", B=1, kmax=4, m=100)
    assert p > 1.0  # m**4 exhaustive: clearly over any sane budget
    # once the tenant has history, the measured p95 takes over
    reg.histogram(
        "serve.solve.latency_s", tenant="default", engine="host_exhaustive"
    ).observe(0.25)
    assert fe._predict_s(
        "default", "host_exhaustive", B=1, kmax=4, m=100
    ) == pytest.approx(0.25, rel=0.5)
    rt.close()


def test_cold_tenant_exhaustive_not_admitted_past_deadline(rng):
    """Regression: a cold tenant's first star query used to be admitted
    optimistically (empty histogram -> 0.0 predicted) and then run a
    multi-second exhaustive solve past its deadline. The cost-model seed
    must degrade it to jit_greedy (or shed) up front."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg)
    t0 = time.perf_counter()
    res = fe.query(DiversityQuery(k=4, variant="star"), deadline_s=0.05)
    elapsed = time.perf_counter() - t0
    assert res.degraded or res.shed
    assert res.engine in ("jit_greedy", "shed")
    # the proof we never ran the exhaustive solve: it takes seconds at
    # this coreset size (jit_greedy compile is the only slow part left)
    assert elapsed < 30.0
    assert reg.counter("serve.query.shed", tenant="default").value + \
        reg.counter("serve.query.degraded", tenant="default").value >= 1
    rt.close()


# --------------------------------------------------------------------------
# coalescing: parity + window semantics
# --------------------------------------------------------------------------


def _mixed_calls(fe):
    """(tenant, queries) workload mixing tenants, ks across pow-2
    buckets, engine hints, and category filters."""
    return [
        ("default", [DiversityQuery(k=2), DiversityQuery(k=5)]),
        ("default", [DiversityQuery(k=3, allowed_cats=frozenset({0, 1, 2}))]),
        ("uniform", [DiversityQuery(k=8)]),
        ("uniform", [DiversityQuery(k=4, variant="star",
                                    engine_hint="jit_greedy")]),
        ("default", [DiversityQuery(k=4, caps=(1, 1, 1, 1))]),
        ("uniform", [DiversityQuery(k=2), DiversityQuery(k=7),
                     DiversityQuery(k=3)]),
    ]


def _assert_same(a, b):
    assert a.indices.tolist() == b.indices.tolist()
    assert a.local_indices.tolist() == b.local_indices.tolist()
    assert a.diversity == b.diversity  # exact float equality
    assert a.epoch == b.epoch
    assert a.tenant == b.tenant
    assert not a.degraded and not a.shed


def test_concurrent_multitenant_parity(rng):
    """Coalesced answers are bit-identical to the direct per-call path
    across tenants, engines, hints, and k buckets."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, coalesce=CoalesceConfig(window_s=0.02))
    fe.register_tenant("uniform", spec=MatroidSpec("uniform"))
    calls = _mixed_calls(fe)
    # direct baseline, single-threaded (same epoch throughout)
    baseline = [
        fe._query_batch_direct(list(qs), tenant=fe.tenants.get(t))
        for t, qs in calls
    ]
    for _round in range(3):
        results = [None] * len(calls)
        barrier = threading.Barrier(len(calls))

        def worker(i, t, qs):
            barrier.wait()
            results[i] = fe.query_batch(qs, tenant=t)

        threads = [
            threading.Thread(target=worker, args=(i, t, qs))
            for i, (t, qs) in enumerate(calls)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for got, want in zip(results, baseline):
            for a, b in zip(got, want):
                _assert_same(a, b)
    # the window actually coalesced concurrent callers (>= 2 in a group
    # at least once across rounds; the barrier makes this overwhelmingly
    # likely, but thread scheduling may let a first caller slip through
    # solo — hence >=, not ==)
    assert reg.counter("serve.coalesce.coalesced").value >= 2
    fe.close()
    rt.close()


def test_forced_engine_parity_under_concurrency(rng):
    """engine= forced legs (host reference and jit) coalesce without
    changing a single bit of the answers."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, coalesce=CoalesceConfig(window_s=0.02))
    qs = [DiversityQuery(k=3), DiversityQuery(k=5)]
    for engine in ("host", "jit_sum"):
        want = fe._query_batch_direct(list(qs), tenant=None, engine=engine)
        results = [None] * 6
        barrier = threading.Barrier(6)

        def worker(i):
            barrier.wait()
            results[i] = fe.query_batch(qs, engine=engine)

        threads = [
            threading.Thread(target=worker, args=(i,)) for i in range(6)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for got in results:
            for a, b in zip(got, want):
                _assert_same(a, b)
                assert a.engine == b.engine  # forced engine honored
    fe.close()
    rt.close()


def test_solo_caller_bypasses_window(rng):
    """A single-threaded caller never pays the window: the coalescer is
    bypassed entirely (solo counter), no dispatcher groups form."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, coalesce=CoalesceConfig(window_s=5.0))
    t0 = time.perf_counter()
    for _ in range(3):
        fe.query(DiversityQuery(k=4))
    assert time.perf_counter() - t0 < 5.0  # nowhere near window_s
    assert reg.counter("serve.coalesce.solo").value == 3
    assert reg.counter("serve.coalesce.coalesced").value == 0
    assert fe.coalescer.backlog == 0
    fe.close()
    rt.close()


def test_deadline_bounds_window_wait():
    """No caller's time parked in the window may exceed
    deadline_window_frac of its budget, whatever window_s says."""

    class _Tenant:
        name = "default"

    class _FakeFrontend:
        def __init__(self):
            self.registry = obs.MetricsRegistry()
            self.dispatched = []

        def active_calls(self):
            return 1_000_000  # never triggers the early close

        def _solve_coalesced(self, calls):
            now = time.perf_counter()
            for c in calls:
                c.results = now
                self.dispatched.append(c)

    fe = _FakeFrontend()
    # adaptive=False: the fixed 10 s window is what the deadline cap
    # must beat (the adaptive controller would collapse it on its own)
    co = Coalescer(fe, CoalesceConfig(window_s=10.0, adaptive=False))
    try:
        t0 = time.perf_counter()
        dispatched_at = co.submit(
            _Tenant(), [DiversityQuery(k=2)], engine="auto",
            min_epoch=None, deadline_s=0.2,
        )
        waited = dispatched_at - t0
        # budget 0.2 x frac 0.25 = 50 ms max in-window, not 10 s
        assert waited < 0.15
    finally:
        co.close()


def test_deadline_degrade_shed_through_coalescer(rng):
    """Deadline admission composes with coalescing: concurrent deadline
    callers each get per-caller degrade/shed, and none waits past its
    budget inside the window."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, coalesce=CoalesceConfig(window_s=0.05))
    # warm the greedy engine so its compile doesn't eat the budgets
    fe.query(DiversityQuery(k=4, variant="star", engine_hint="jit_greedy"))
    # overload every engine's history for this tenant
    for eng in (
        "host_exhaustive", "jit_greedy", "jit_sum", "host_local_search"
    ):
        reg.histogram(
            "serve.solve.latency_s", tenant="default", engine=eng,
        ).observe(30.0)
    deadline_s = 0.5
    outcomes = [None] * 6
    elapsed = [None] * 6
    barrier = threading.Barrier(6)

    def worker(i):
        barrier.wait()
        t0 = time.perf_counter()
        outcomes[i] = fe.query(
            DiversityQuery(k=4, variant="star"), deadline_s=deadline_s
        )
        elapsed[i] = time.perf_counter() - t0

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for r, dt in zip(outcomes, elapsed):
        assert r.shed and r.engine == "shed"  # nothing fits a 0.5s budget
        assert len(r.indices) == 0
        assert dt < deadline_s + 0.25  # never parked past the deadline
    # shedding is an answer, not an error: the stack stays healthy
    ok = fe.query(DiversityQuery(k=5))
    assert not ok.shed and len(ok.indices) == 5
    fe.close()
    rt.close()


def test_min_epoch_not_merged_across_values(rng):
    """Calls with different min_epoch must not share an epoch acquire:
    a reader-of-its-own-writes never gets an older group's snapshot."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, coalesce=CoalesceConfig(window_s=0.05))
    e0 = fe.flush()
    P2 = make_clustered_points(np.random.default_rng(7), n=64)
    cats2 = np.random.default_rng(7).integers(0, 4, (64, 1)).astype(np.int32)
    rt.submit(P2, cats2)
    e1 = fe.flush()
    assert e1 > e0
    results = [None, None]
    barrier = threading.Barrier(2)

    def worker(i, min_epoch):
        barrier.wait()
        results[i] = fe.query(
            DiversityQuery(k=4), min_epoch=min_epoch
        )

    threads = [
        threading.Thread(target=worker, args=(0, None)),
        threading.Thread(target=worker, args=(1, e1)),
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert results[1].epoch >= e1
    assert results[0].epoch >= e0
    fe.close()
    rt.close()


# --------------------------------------------------------------------------
# accounting (satellite: per-tenant traffic + queue depth in stats)
# --------------------------------------------------------------------------


def test_stats_tenant_traffic_and_coalesce_sections(rng):
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, coalesce=CoalesceConfig(window_s=0.02))
    fe.register_tenant("uniform", spec=MatroidSpec("uniform"))
    fe.query_batch([DiversityQuery(k=3)] * 4)
    fe.query(DiversityQuery(k=4), tenant="uniform")
    st = fe.stats()
    tt = st["tenant_traffic"]
    assert tt["default"]["requests"] == 1
    assert tt["default"]["queries"] == 4
    assert tt["uniform"]["requests"] == 1
    assert tt["uniform"]["queries"] == 1
    assert tt["default"]["in_flight"] == 0.0
    assert tt["default"]["qps"] > 0.0
    # second snapshot with no traffic in between: interval qps drops to 0
    st2 = fe.stats()
    assert st2["tenant_traffic"]["default"]["qps"] == 0.0
    assert st["coalesce"]["queue_depth"] == 0
    assert st["active_calls"] == 0
    # auto routing decisions are logged with their estimates
    assert st["cost_model"]["decisions"]
    assert all("estimates" in d for d in st["cost_model"]["decisions"])
    fe.close()
    rt.close()


def test_frontend_close_idempotent_and_coalescer_refuses_after(rng):
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg, n=80, tau=12)
    fe.query(DiversityQuery(k=3))
    co = fe.coalescer
    fe.close()
    fe.close()  # idempotent
    with pytest.raises(RuntimeError):
        co.submit(
            fe.default_tenant, [DiversityQuery(k=3)], engine="auto",
            min_epoch=None, deadline_s=None,
        )
    rt.close()


# --------------------------------------------------------------------------
# PR 10: cross-tenant stacked solves through the frontend
# --------------------------------------------------------------------------


def test_cross_tenant_stacked_parity_through_frontend(rng):
    """A mixed multi-tenant concurrent window executes as stacked
    cross-tenant launches and every answer stays bit-identical to the
    direct per-tenant path. dispatchers=1 keeps window assembly
    deterministic; the stacking happens in the shared dispatch stage."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(
        rng, reg,
        coalesce=CoalesceConfig(window_s=0.02, dispatchers=1),
    )
    fe.register_tenant("uniform", spec=MatroidSpec("uniform"))
    fe.register_tenant("uniform2", spec=MatroidSpec("uniform"))
    fe.register_tenant(
        "part2", spec=MatroidSpec("partition", num_categories=4, gamma=1)
    )
    calls = [
        ("default", [DiversityQuery(k=2), DiversityQuery(k=5)]),
        ("uniform", [DiversityQuery(k=8)]),
        ("uniform2", [DiversityQuery(k=3), DiversityQuery(k=4)]),
        ("part2", [DiversityQuery(k=4, caps=(1, 1, 1, 1))]),
        ("default", [DiversityQuery(k=3,
                                    allowed_cats=frozenset({0, 1, 2}))]),
        ("uniform", [DiversityQuery(k=4, variant="star",
                                    engine_hint="jit_greedy")]),
    ]
    baseline = [
        fe._query_batch_direct(list(qs), tenant=fe.tenants.get(t))
        for t, qs in calls
    ]
    for _round in range(3):
        results = [None] * len(calls)
        barrier = threading.Barrier(len(calls))

        def worker(i, t, qs):
            barrier.wait()
            results[i] = fe.query_batch(qs, tenant=t)

        threads = [
            threading.Thread(target=worker, args=(i, t, qs))
            for i, (t, qs) in enumerate(calls)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for got, want in zip(results, baseline):
            for a, b in zip(got, want):
                _assert_same(a, b)
    # the barrier makes a >= 2-tenant window overwhelmingly likely in at
    # least one of the rounds: the stacked path must actually have run
    assert reg.counter("serve.coalesce.stacked_solves").value >= 1
    assert reg.counter("serve.coalesce.stacked_rows").value >= 2
    st = fe.stats()["coalesce"]
    assert st["stacked_solves"] >= 1
    fe.close()
    rt.close()


# --------------------------------------------------------------------------
# PR 10: adaptive window controller
# --------------------------------------------------------------------------


def _ticking_window(cfg):
    clk = [0.0]
    return clk, AdaptiveWindow(cfg, clock=lambda: clk[0])


def test_adaptive_window_widens_under_queue_growth():
    cfg = CoalesceConfig(
        window_s=3e-4, window_min_s=1e-4, window_max_s=2e-3
    )
    clk, w = _ticking_window(cfg)
    # steady 10 kHz arrivals: well past the collapse threshold
    for _ in range(50):
        clk[0] += 1e-4
        w.observe_arrival()
    w.observe_solve(5e-4)
    base = w.current(backlog=0)
    assert base == pytest.approx(5e-4, rel=1e-6)  # Little target = S
    wide = w.current(backlog=16)
    assert wide > base  # standing queue -> widen toward max batch
    assert wide <= cfg.window_max_s
    assert w.current(backlog=10_000) == cfg.window_max_s  # clamped
    # the controller is observable: trace carries (t, window) history
    snap = w.snapshot()
    assert snap["rate_hz"] == pytest.approx(1e4, rel=0.2)
    assert len(snap["trace"]) >= 3
    assert snap["trace"][-1][1] == cfg.window_max_s


def test_adaptive_window_collapses_when_idle():
    cfg = CoalesceConfig(window_min_s=1e-4, window_max_s=2e-3)
    clk, w = _ticking_window(cfg)
    # cold start: no arrival history means no companion expected
    assert w.current(backlog=0) == 0.0
    for _ in range(50):
        clk[0] += 1e-4
        w.observe_arrival()
    assert w.current(backlog=0) > 0.0  # busy: window open
    clk[0] += 10.0  # silence decays the rate even though the EMA is hot
    assert w.current(backlog=0) == 0.0  # idle again: solo-bypass regime
    # sparse arrivals (1 Hz) can't fill a 2 ms window either
    clk2, w2 = _ticking_window(cfg)
    for _ in range(10):
        clk2[0] += 1.0
        w2.observe_arrival()
    assert w2.current(backlog=0) == 0.0


def test_adaptive_window_fixed_mode_and_bad_observations():
    cfg = CoalesceConfig(window_s=7e-4, adaptive=False)
    clk, w = _ticking_window(cfg)
    assert w.current(backlog=0) == 7e-4
    assert w.current(backlog=1_000) == 7e-4  # fixed means fixed
    w.observe_solve(float("nan"))  # refused quietly
    w.observe_solve(-1.0)
    assert w.snapshot()["solve_est_s"] is None


# --------------------------------------------------------------------------
# PR 10: dispatcher pool — FIFO, close/drain, failover re-dispatch
# --------------------------------------------------------------------------


class _T:
    def __init__(self, name):
        self.name = name


class _PoolFakeFrontend:
    """Records execution order; optionally blocks every solve until
    ``release`` is set (to pin calls in shard queues)."""

    def __init__(self, block=False):
        self.registry = obs.MetricsRegistry()
        self.order = []
        self.mu = threading.Lock()
        self.release = threading.Event()
        if not block:
            self.release.set()

    def active_calls(self):
        return 1_000_000  # never triggers the early close

    def _record(self, calls):
        self.release.wait(timeout=10.0)
        with self.mu:
            for c in calls:
                self.order.extend(c.queries)
                c.results = list(c.queries)

    def _solve_coalesced(self, calls):
        self._record(calls)

    def _solve_coalesced_stacked(self, subs):
        for sub in subs:
            self._record(sub)


def _shard_distinct_names(n_shards, n_names):
    """Tenant names guaranteed to cover ``n_shards`` distinct shards."""
    names, seen = [], set()
    i = 0
    while len(names) < n_names:
        name = f"tn{i}"
        i += 1
        shard = zlib.crc32(name.encode()) % n_shards
        if len(seen) < n_shards and shard in seen and \
                n_names - len(names) <= n_shards - len(seen):
            continue  # still need unseen shards: skip duplicates
        seen.add(shard)
        names.append(name)
    assert len(seen) == n_shards
    return names


def test_per_tenant_fifo_under_dispatcher_pool():
    """Per-tenant submission order survives the pool: same tenant lands
    on the same shard, windows assemble FIFO, and the shared stage's
    busy set forbids two executors on one tenant at a time."""
    fe = _PoolFakeFrontend()
    co = Coalescer(
        fe, CoalesceConfig(window_s=0.01, adaptive=False, dispatchers=3)
    )
    try:
        names = _shard_distinct_names(3, 3)
        tenants = {n: _T(n) for n in names}
        threads = []
        for i in range(6):
            for n in names:
                th = threading.Thread(
                    target=co.submit,
                    args=(tenants[n], [f"{n}:{i}"]),
                    kwargs=dict(
                        engine="auto", min_epoch=None, deadline_s=None
                    ),
                )
                th.start()
                threads.append(th)
                time.sleep(0.005)  # deterministic per-tenant enq order
        for th in threads:
            th.join(timeout=20.0)
            assert not th.is_alive()
        for n in names:
            got = [q for q in fe.order if q.startswith(f"{n}:")]
            assert got == [f"{n}:{i}" for i in range(6)], (n, got)
    finally:
        co.close()


def test_close_fails_queued_calls_on_every_shard_loudly():
    """close() with dispatchers mid-solve: in-flight groups complete,
    queued calls on every shard fail with the close error, none hang,
    and a second close is a no-op."""
    fe = _PoolFakeFrontend(block=True)
    co = Coalescer(
        fe, CoalesceConfig(window_s=0.02, adaptive=False, dispatchers=3)
    )
    names = _shard_distinct_names(3, 6)
    tenants = [_T(n) for n in names]
    outcomes = {}
    omu = threading.Lock()

    def call(t, tag):
        try:
            r = co.submit(
                t, [tag], engine="auto", min_epoch=None, deadline_s=None
            )
            with omu:
                outcomes[tag] = ("ok", r)
        except RuntimeError as e:
            with omu:
                outcomes[tag] = ("err", str(e))

    first = [
        threading.Thread(target=call, args=(t, f"first-{t.name}"))
        for t in tenants
    ]
    for th in first:
        th.start()
    time.sleep(0.4)  # windows closed; every dispatcher blocked in-solve
    second = [
        threading.Thread(target=call, args=(t, f"second-{t.name}"))
        for t in tenants
    ]
    for th in second:
        th.start()
    time.sleep(0.3)  # second wave parked behind the blocked dispatchers
    closer = threading.Thread(target=co.close)
    closer.start()
    time.sleep(0.05)
    fe.release.set()  # let the in-flight groups finish
    closer.join(timeout=15.0)
    assert not closer.is_alive()
    for th in first + second:
        th.join(timeout=15.0)
        assert not th.is_alive()  # none hang
    assert len(outcomes) == 12
    for t in tenants:
        assert outcomes[f"first-{t.name}"][0] == "ok"
        kind, detail = outcomes[f"second-{t.name}"]
        assert kind == "err" and "closed" in detail, (t.name, detail)
    co.close()  # idempotent with everything already torn down


def test_failover_redispatch_drains_all_dispatchers(rng):
    """ReplicaSet-style failover across a pool: drain() hands back the
    queued calls of EVERY shard un-failed, and adopt_pending on the
    promoted frontend re-dispatches the multi-tenant set as one stacked
    wave, releasing all blocked callers with real answers."""
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(rng, reg)
    names = _shard_distinct_names(2, 2)
    for n in names:
        fe.register_tenant(n, spec=MatroidSpec("uniform"))
    fake = _PoolFakeFrontend(block=True)
    co = Coalescer(
        fake, CoalesceConfig(window_s=0.02, adaptive=False, dispatchers=2)
    )
    results = {}
    rmu = threading.Lock()

    def call(name, tag, k):
        # forced jit_sum: the cost model would route a tiny 2-row wave
        # to a host engine, which has no stacked path — the point here
        # is pinning the adoption wave through the stacked launch
        r = co.submit(
            fe.tenants.get(name), [DiversityQuery(k=k)],
            engine="jit_sum", min_epoch=None, deadline_s=None,
        )
        with rmu:
            results[tag] = r
    first = [
        threading.Thread(target=call, args=(n, f"first-{n}", 3))
        for n in names
    ]
    for th in first:
        th.start()
    time.sleep(0.4)  # both dispatchers blocked mid-solve
    second = [
        threading.Thread(target=call, args=(n, f"second-{n}", 4))
        for n in names
    ]
    for th in second:
        th.start()
    time.sleep(0.3)  # one queued call per shard
    drained = co.drain()
    assert sorted(c.tenant.name for c in drained) == sorted(names)
    assert co.backlog == 0
    stacked_before = reg.counter("serve.coalesce.stacked_solves").value
    released = fe.adopt_pending(drained)
    assert released == len(drained)
    # same-epoch uniform lanes: adoption ran them as one stacked wave
    assert reg.counter(
        "serve.coalesce.stacked_solves"
    ).value > stacked_before
    fake.release.set()
    for th in first + second:
        th.join(timeout=15.0)
        assert not th.is_alive()
    for n in names:
        got = results[f"second-{n}"]
        want = fe._query_batch_direct(
            [DiversityQuery(k=4)], tenant=fe.tenants.get(n),
            engine="jit_sum",
        )
        _assert_same(got[0], want[0])
    co.close()
    fe.close()
    rt.close()


def test_pool_stats_aggregate_across_dispatchers(rng):
    reg = obs.MetricsRegistry()
    rt, fe = _frontend(
        rng, reg,
        coalesce=CoalesceConfig(window_s=0.02, dispatchers=2),
    )
    fe.register_tenant("uniform", spec=MatroidSpec("uniform"))
    barrier = threading.Barrier(4)

    def worker(t):
        barrier.wait()
        fe.query_batch([DiversityQuery(k=3)], tenant=t)

    threads = [
        threading.Thread(
            target=worker, args=("default" if i % 2 else "uniform",)
        )
        for i in range(4)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    st = fe.stats()["coalesce"]
    assert st["dispatchers"] == 2
    assert set(st["per_dispatcher"]) == {"d0", "d1"}
    # the pool-wide aggregates are the sum of the per-dispatcher series
    assert st["groups"] == sum(
        d["groups"] for d in st["per_dispatcher"].values()
    )
    assert st["queue_depth"] == 0
    assert reg.gauge("serve.coalesce.backlog").value == 0
    assert st["adaptive"] is True
    assert "trace" in st["window"]
    fe.close()
    rt.close()
