"""Table-1 objective implementations: exact solvers vs brute force, jnp vs
host, Lemma-1 bookkeeping."""
import itertools

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.diversity import (
    VARIANTS,
    _bipartition_exact,
    _tsp_held_karp,
    diversity,
    f_of_k,
    farness_lower_bound,
    jnp_diversity,
)
from repro.core.geometry import pairwise_matrix


def _rand_D(rng, k):
    pts = rng.normal(size=(k, 3))
    D = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1))
    np.fill_diagonal(D, 0.0)
    return D


def test_f_of_k():
    assert f_of_k("sum", 5) == 10
    assert f_of_k("star", 5) == 4
    assert f_of_k("tree", 5) == 4
    assert f_of_k("cycle", 5) == 5
    assert f_of_k("bipartition", 5) == 6


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 7), st.integers(0, 1000))
def test_tsp_held_karp_vs_bruteforce(k, seed):
    D = _rand_D(np.random.default_rng(seed), k)
    hk = _tsp_held_karp(D)
    best = min(
        sum(D[p[i], p[(i + 1) % k]] for i in range(k))
        for p in itertools.permutations(range(k))
    )
    assert abs(hk - best) < 1e-9


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 8), st.integers(0, 1000))
def test_bipartition_vs_bruteforce(k, seed):
    D = _rand_D(np.random.default_rng(seed), k)
    ex = _bipartition_exact(D)
    half = k // 2
    best = np.inf
    for q in itertools.combinations(range(k), half):
        mask = np.zeros(k, bool)
        mask[list(q)] = True
        best = min(best, D[mask][:, ~mask].sum())
    assert abs(ex - best) < 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 9), st.integers(0, 1000))
def test_jnp_matches_host(k, seed):
    D = _rand_D(np.random.default_rng(seed), k)
    for v in ("sum", "star", "tree"):
        a = diversity(D, v)
        b = float(jnp_diversity(jnp.asarray(D, jnp.float32), v))
        assert abs(a - b) / max(a, 1e-9) < 1e-4, v


def test_tree_is_mst():
    from scipy.sparse.csgraph import minimum_spanning_tree

    rng = np.random.default_rng(3)
    D = _rand_D(rng, 12)
    ours = diversity(D, "tree")
    ref = minimum_spanning_tree(D).sum()
    assert abs(ours - ref) < 1e-8


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(0, 500))
def test_lemma1_lower_bounds_hold(k, seed):
    """rho_{S,k} >= bound(Delta): on UNIFORM matroids the optimum over all
    k-subsets must satisfy Lemma 1 (which holds for any matroid)."""
    rng = np.random.default_rng(seed)
    n = 10
    pts = rng.normal(size=(n, 3))
    D = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1))
    delta = D.max()
    for v in VARIANTS:
        best = max(
            diversity(D[np.ix_(c, c)], v)
            for c in itertools.combinations(range(n), k)
        )
        rho = best / f_of_k(v, k)
        lo = farness_lower_bound(delta, k, v)
        assert rho >= lo - 1e-9, (v, rho, lo)
