"""Cross-tenant stacked solve suite (PR 10 acceptance).

The parity contract: ``solve_stacked`` over T tenant lanes returns, for
every row of every lane, EXACTLY the indices and value the per-tenant
``jit_sum.solve_batch`` dispatch returns — bit-identical, not merely
close. The stacked kernel is a ``lax.scan`` over lanes whose body is
the unmodified per-tenant row solver with an unmapped ``(m, m)`` D, so
each matmul runs at the same shape and accumulation order as the
per-tenant launch (a gather-form outer vmap was measurably NOT safe:
batched matmuls accumulate differently and flip greedy argmax decisions
on tie-heavy data).

Also here: stack-eligibility refusals (transversal/general lanes, host
engines), shape-mismatch rejection, and the cost-model satellite —
``estimate_stacked`` prices the summed rows of a stacked launch and the
decision ring records ``stacked=True``.
"""
import numpy as np
import pytest

from repro.core.matroid import MatroidSpec, make_host_matroid
from repro.core.solvers import (
    JIT_SUM,
    CostModel,
    SolveContext,
    SolveSpec,
    counts_stack_eligible,
    get_engine,
    partition_by_engine,
    solve_stacked,
)


def _ctx(kind, m, *, h=4, seed=0, dtype=np.float32):
    r = np.random.default_rng(seed)
    pts = r.random((m, 3))
    D = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1)).astype(dtype)
    np.fill_diagonal(D, 0.0)
    if kind == "uniform":
        spec = MatroidSpec("uniform")
        return SolveContext(
            D=D, spec=spec, cats=None, caps=None,
            matroid_fn=lambda s: make_host_matroid(spec, None, None, m, s.k),
        )
    cats = r.integers(0, h, (m, 1)).astype(np.int32)
    caps = np.full(h, 3, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return SolveContext(
        D=D, spec=spec, cats=cats, caps=caps,
        matroid_fn=lambda s: make_host_matroid(spec, cats, caps, m, s.k),
    )


def _mixed_lanes(m=40, n_lanes=4, seed=3):
    """Lanes mixing uniform/partition matroids, per-row k, per-row caps
    overrides, and candidate masks — every knob the stacked kernel pads."""
    rng = np.random.default_rng(seed)
    kinds = ["uniform", "partition"] * (n_lanes // 2 + 1)
    lanes = []
    for t in range(n_lanes):
        ctx = _ctx(kinds[t], m, seed=100 + t)
        specs = []
        for _ in range(int(rng.integers(1, 6))):
            kw = {"k": int(rng.integers(2, 7))}
            if kinds[t] == "partition" and rng.random() < 0.4:
                kw["caps"] = np.full(4, 2, np.int32)
            if rng.random() < 0.4:
                allow = np.ones(m, bool)
                allow[rng.choice(m, 5, replace=False)] = False
                kw["allow"] = allow
            specs.append(SolveSpec(**kw))
        lanes.append((ctx, specs))
    return lanes


def _assert_lane_parity(lanes, stacked):
    for t, (ctx, specs) in enumerate(lanes):
        ref = JIT_SUM.solve_batch(ctx, specs)
        for i, (a, b) in enumerate(zip(stacked[t], ref)):
            assert a.local_indices.tolist() == b.local_indices.tolist(), (
                t, i, a.local_indices, b.local_indices,
            )
            assert a.value == b.value  # exact float equality
            assert a.engine == b.engine == "jit_sum"


def test_stacked_bit_identical_to_per_tenant_dispatch():
    lanes = _mixed_lanes(n_lanes=4)
    for ctx, specs in lanes:
        for s in specs:
            assert counts_stack_eligible(JIT_SUM, ctx, s)
    _assert_lane_parity(lanes, solve_stacked(lanes))


def test_stacked_parity_off_pow2_lane_count():
    """T=3 pads the lane axis to 4: padding lanes (zero D, k=0 rows)
    must not perturb the real lanes."""
    lanes = _mixed_lanes(n_lanes=3, seed=11)
    _assert_lane_parity(lanes, solve_stacked(lanes))


def test_stacked_parity_uneven_lane_widths():
    """Lanes of 1 and 7 rows share one launch: the row axis pads to the
    widest lane's pow-2 bucket, narrower lanes ride their padding rows."""
    m = 32
    a = _ctx("uniform", m, seed=21)
    b = _ctx("partition", m, seed=22)
    lanes = [
        (a, [SolveSpec(k=4)]),
        (b, [SolveSpec(k=int(k)) for k in (2, 3, 4, 5, 6, 2, 3)]),
    ]
    _assert_lane_parity(lanes, solve_stacked(lanes))


def test_stacked_empty_and_single_lane():
    assert solve_stacked([]) == []
    ctx = _ctx("uniform", 24, seed=31)
    lanes = [(ctx, [SolveSpec(k=3), SolveSpec(k=5)])]
    _assert_lane_parity(lanes, solve_stacked(lanes))


def test_engine_stacked_path_is_the_driver():
    """The registry engine's ``solve_batch_stacked`` hook is the same
    code path ``solve_stacked`` exposes (what the frontend calls)."""
    lanes = _mixed_lanes(n_lanes=2, seed=41)
    _assert_lane_parity(lanes, JIT_SUM.solve_batch_stacked(lanes))


# --------------------------------------------------------------------------
# eligibility + shape guards
# --------------------------------------------------------------------------


def test_transversal_and_general_lanes_refused():
    m = 24
    cats = np.full((m, 2), -1, np.int32)
    cats[:, 0] = np.arange(m) % 4
    spec = MatroidSpec("transversal", num_categories=4, gamma=2)
    ctx = SolveContext(
        D=_ctx("uniform", m).D, spec=spec, cats=cats, caps=None,
        matroid_fn=lambda s: None,
    )
    assert not counts_stack_eligible(JIT_SUM, ctx, SolveSpec(k=3))
    assert not JIT_SUM.stack_eligible(ctx, SolveSpec(k=3))


def test_host_engines_have_no_stacked_path():
    ctx = _ctx("uniform", 24)
    host = get_engine("host_local_search")
    assert not host.stack_eligible(ctx, SolveSpec(k=3))
    with pytest.raises(NotImplementedError):
        host.solve_batch_stacked([(ctx, [SolveSpec(k=3)])])


def test_mismatched_lanes_rejected():
    a = _ctx("uniform", 24, seed=51)
    b = _ctx("uniform", 32, seed=52)
    with pytest.raises(ValueError, match="coreset size"):
        solve_stacked([(a, [SolveSpec(k=3)]), (b, [SolveSpec(k=3)])])
    c = _ctx("uniform", 24, seed=53, dtype=np.float64)
    with pytest.raises(ValueError, match="dtype"):
        solve_stacked([(a, [SolveSpec(k=3)]), (c, [SolveSpec(k=3)])])


# --------------------------------------------------------------------------
# cost model (satellite): stacked pricing + decision-ring flag
# --------------------------------------------------------------------------


def test_estimate_stacked_sums_rows():
    cm = CostModel()
    parts = [(4, 3), (2, 6), (1, 2)]
    assert cm.estimate_stacked("jit_sum", parts, 32) == pytest.approx(
        cm.estimate("jit_sum", B=7, kmax=6, m=32)
    )
    # one launch for the stack beats one launch per entry: that is the
    # whole point of stacking (dispatch amortized T times)
    per_entry = sum(
        cm.estimate("jit_sum", B=b, kmax=k, m=32) for b, k in parts
    )
    assert cm.estimate_stacked("jit_sum", parts, 32) < per_entry


def test_decision_ring_records_stacked_flag():
    cm = CostModel()
    cm.record_decision(
        engine="jit_sum", candidates={"jit_sum": 1e-3}, B=4, kmax=3, m=32,
        stacked=True,
    )
    cm.record_decision(
        engine="jit_sum", candidates={"jit_sum": 1e-3}, B=4, kmax=3, m=32,
    )
    d_stacked, d_plain = cm.decisions()[-2:]
    assert d_stacked["stacked"] is True
    assert d_plain["stacked"] is False


def test_partition_by_engine_stacked_flag_reaches_ring():
    ctx = _ctx("uniform", 24, seed=61)
    cm = CostModel()
    partition_by_engine(
        ctx, [SolveSpec(k=3)] * 8, cost_model=cm, stacked=True
    )
    assert cm.decisions()[-1]["stacked"] is True
