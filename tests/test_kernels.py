"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in python on CPU) + the recurrent SSD ground truth.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


PDIST_SHAPES = [
    (8, 8, 4), (33, 17, 7), (128, 64, 32), (200, 300, 25), (5, 1000, 3),
]


@pytest.mark.parametrize("n,m,d", PDIST_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pdist_kernel(n, m, d, dtype):
    rng = np.random.default_rng(n * 1000 + m)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype)
    y = jnp.asarray(rng.normal(size=(m, d)), dtype)
    a = ops.pairwise_sqdist(x, y, force="ref")
    b = ops.pairwise_sqdist(x, y, force="interpret")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol,
                               atol=tol)


PRECHECK_SHAPES = [(8, 5, 4), (37, 17, 7), (128, 33, 100), (200, 129, 25)]


@pytest.mark.parametrize("B,T,d", PRECHECK_SHAPES)
@pytest.mark.parametrize("mode", ["matmul", "interpret"])
def test_center_precheck_modes_vs_exact(B, T, d, mode):
    """The fused top-3 precheck op: matmul-form jnp (CPU default) and the
    Pallas kernel (interpret) against the exact broadcast oracle. The
    indices must agree whenever the gaps exceed the reported margin — the
    exact contract the blocked scan's exact-refinement fallback relies on."""
    rng = np.random.default_rng(B * 100 + T)
    x = jnp.asarray(rng.normal(size=(B, d)) * 3, jnp.float32)
    c = jnp.asarray(rng.normal(size=(T, d)) * 3, jnp.float32)
    cv = jnp.asarray(rng.random(T) > 0.2)
    dmin_r, z_r, sec_r, z2_r, third_r, m_r = ops.center_precheck(
        x, c, cv, force="ref"
    )
    assert float(m_r) == 0.0
    dmin, z, sec, z2, third, margin = ops.center_precheck(
        x, c, cv, force=mode
    )
    margin = np.broadcast_to(np.asarray(margin), (B,))
    for a, b in ((dmin_r, dmin), (sec_r, sec), (third_r, third)):
        a, b = np.asarray(a), np.asarray(b)
        fin = a < 1e30
        np.testing.assert_allclose(a[fin], b[fin], rtol=1e-4, atol=1e-4)
    # candidate indices certain whenever the next-nearest gap clears the
    # margin (the scan falls back to the exact step otherwise)
    safe_z = (np.asarray(sec_r) - np.asarray(dmin_r)) > 2 * margin
    assert np.array_equal(np.asarray(z)[safe_z], np.asarray(z_r)[safe_z])
    safe_pair = (np.asarray(third_r) - np.asarray(dmin_r)) > 2 * margin
    pair = np.sort(np.stack([np.asarray(z), np.asarray(z2)]), axis=0)
    pair_r = np.sort(np.stack([np.asarray(z_r), np.asarray(z2_r)]), axis=0)
    assert np.array_equal(pair[:, safe_pair], pair_r[:, safe_pair])


def test_center_precheck_all_invalid_centers():
    """No valid centers: every distance is float32 max, indices default to
    the argmin tie rule (first column) on every path."""
    x = jnp.asarray(np.ones((4, 3)), jnp.float32)
    c = jnp.asarray(np.zeros((5, 3)), jnp.float32)
    cv = jnp.zeros((5,), bool)
    for mode in ("ref", "matmul", "interpret"):
        dmin, z, sec, z2, third, _m = ops.center_precheck(x, c, cv,
                                                          force=mode)
        assert np.all(np.asarray(dmin) >= np.float32(3.4e38))
        assert np.array_equal(np.asarray(z), np.zeros(4, np.int32))


@pytest.mark.parametrize("n,d", [(16, 4), (100, 25), (1025, 7), (64, 128)])
def test_gmm_step_kernel(n, d):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    md = jnp.asarray(rng.uniform(0.5, 3.0, size=(n,)), jnp.float32)
    valid = jnp.asarray(rng.random(n) > 0.1)
    r_ref = ops.gmm_update(x, z, md, valid, force="ref")
    r_pl = ops.gmm_update(x, z, md, valid, force="interpret")
    np.testing.assert_allclose(
        np.asarray(r_ref[0]), np.asarray(r_pl[0]), rtol=1e-5, atol=1e-5
    )
    assert int(r_ref[1]) == int(r_pl[1])
    np.testing.assert_allclose(float(r_ref[2]), float(r_pl[2]), rtol=1e-5)


SSD_SHAPES = [
    (2, 16, 8, 4), (3, 32, 16, 8), (1, 64, 32, 16), (4, 8, 64, 32),
]


@pytest.mark.parametrize("g,q,p,n", SSD_SHAPES)
def test_ssd_kernel_vs_ref(g, q, p, n):
    rng = np.random.default_rng(g * 100 + q)
    xb = jnp.asarray(rng.normal(size=(g, q, p)), jnp.float32)
    la = jnp.asarray(-rng.uniform(0.01, 0.4, size=(g, q)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(g, q, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(g, q, n)), jnp.float32)
    y1, s1, dfs1, td1 = ops.ssd_intra_chunk(xb, la, B, C, force="ref")
    y2, s2, dfs2, td2 = ops.ssd_intra_chunk(xb, la, B, C, force="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunked_matches_recurrent_scan():
    """Chunked/kernel math == step-by-step recurrence (the real oracle)."""
    rng = np.random.default_rng(0)
    l, p, n = 48, 8, 6
    xb = jnp.asarray(rng.normal(size=(l, p)), jnp.float32)
    la = jnp.asarray(-rng.uniform(0.01, 0.3, size=(l,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(l, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(l, n)), jnp.float32)
    ys, s_fin = ref.ssd_reference_scan(xb, la, B, C)
    # chunked: 3 chunks of 16 with state carry
    q = 16
    s = jnp.zeros((n, p))
    outs = []
    for c in range(l // q):
        sl = slice(c * q, (c + 1) * q)
        yi, st, dfs, td = ref.ssd_intra_chunk(xb[sl], la[sl], B[sl], C[sl])
        y_off = (C[sl] @ s) * dfs[:, None]  # (q, p)
        outs.append(yi + y_off)
        s = td * s + st
    y_chunked = jnp.concatenate(outs)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(y_chunked),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(s), rtol=1e-4,
                               atol=1e-4)


def test_models_ssd_matches_recurrence():
    """models/mamba.ssd_chunked (batched einsum form) == recurrent oracle."""
    from repro.models.mamba import ssd_chunked

    rng = np.random.default_rng(1)
    b, l, h, p, n = 2, 32, 3, 8, 5
    xb = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    la = jnp.asarray(-rng.uniform(0.01, 0.3, size=(b, l, h)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    y, s_fin = ssd_chunked(xb, la, B, C, chunk=8)
    for bi in range(b):
        for hi in range(h):
            ys, sf = ref.ssd_reference_scan(
                xb[bi, :, hi], la[bi, :, hi], B[bi], C[bi]
            )
            np.testing.assert_allclose(
                np.asarray(y[bi, :, hi]), np.asarray(ys), rtol=2e-4, atol=2e-4
            )
            np.testing.assert_allclose(
                np.asarray(s_fin[bi, hi]), np.asarray(sf).T, rtol=2e-4,
                atol=2e-4,
            )


FLASH_SHAPES = [
    (4, 64, 64, 16, True), (2, 48, 80, 32, False), (3, 33, 33, 8, True),
    (1, 128, 128, 64, True), (2, 96, 32, 16, False),
]


@pytest.mark.parametrize("bh,sq,skv,hd,causal", FLASH_SHAPES)
def test_flash_fwd_kernel(bh, sq, skv, hd, causal):
    rng = np.random.default_rng(bh * sq)
    q = jnp.asarray(rng.normal(size=(bh, sq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, skv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, skv, hd)), jnp.float32)
    a = ops.flash_attention_fwd(q, k, v, causal=causal, force="ref")
    b = ops.flash_attention_fwd(q, k, v, causal=causal, q_block=16,
                                kv_block=32, force="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_flash_fwd_kernel_matches_model_attention():
    """Kernel == models/attention.py flash path (heads pre-flattened)."""
    from repro.models.common import blockwise_attention

    rng = np.random.default_rng(7)
    B, S, H, hd = 2, 64, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    want = blockwise_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    got = ops.flash_attention_fwd(qf, kf, vf, causal=True, q_block=16,
                                  kv_block=16, force="interpret")
    got = got.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,sq,skv,hd,causal", [
    (2, 64, 64, 16, True), (1, 48, 80, 32, False), (2, 33, 33, 8, True),
])
def test_flash_bwd_kernels(bh, sq, skv, hd, causal):
    """dq/dk/dv Pallas kernels == dense-softmax VJP."""
    from repro.kernels.flash import flash_attention_bwd

    rng = np.random.default_rng(bh + sq)
    q = jnp.asarray(rng.normal(size=(bh, sq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, skv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, skv, hd)), jnp.float32)

    def dense(q, k, v):
        s = jnp.einsum("bqh,bkh->bqk", q, k) / np.sqrt(hd)
        if causal:
            m = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None]
            s = jnp.where(m[None], s, -1e30)
        p = jax.nn.softmax(s, -1)
        return jnp.einsum("bqk,bkh->bqh", p, v)

    o = dense(q, k, v)
    s = jnp.einsum("bqh,bkh->bqk", q, k) / np.sqrt(hd)
    if causal:
        m = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None]
        s = jnp.where(m[None], s, -1e30)
    lse = jax.nn.logsumexp(s, -1)
    do = jnp.asarray(rng.normal(size=o.shape), jnp.float32)
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, q_block=16, kv_block=32,
        interpret=True,
    )
    g = jax.vjp(dense, q, k, v)[1](do)
    for a, b in zip((dq, dk, dv), g):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=2e-3)
