"""Branchless-scan parity: the masked-update step (``step_impl=
"branchless"``, the default) is bit-identical to the historical cond-ladder
Alg.-2 step (``step_impl="reference"``) — same centers, delegates, src_idx,
R, overflow — across matroid kinds, scan variants, block sizes, batch
splits, and shard counts, including the transversal add+shrink path and the
restructure merge.

The reference step IS the PR-2/PR-3 per-point scan, kept verbatim in
``core.streaming._make_step_reference``; these tests are the contract that
lets the branchless rewrite (and the fused precheck + exact-refinement
margin machinery under it) claim "same algorithm, faster under vmap".
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import make_clustered_points
from repro.core.matroid import MatroidSpec
from repro.core.streaming import (
    ingest_batch,
    ingest_batch_sharded,
    ingest_batch_sharded_mapped,
    init_sharded_states,
    init_stream_state,
)

BLOCKS = [1, 16, 64]
KINDS = ["uniform", "partition", "transversal"]
VARIANTS = ["radius", "diameter"]


def _instance(kind, seed, n):
    rng = np.random.default_rng(seed)
    P = make_clustered_points(rng, n=n, d=4, centers=4, spread=0.08)
    if kind == "uniform":
        cats = np.zeros((n, 1), np.int32)
        return P, cats, None, MatroidSpec("uniform"), 3
    if kind == "partition":
        h = 3
        cats = rng.integers(0, h, (n, 1)).astype(np.int32)
        caps = np.full(h, 2, np.int32)
        return P, cats, caps, MatroidSpec(
            "partition", num_categories=h, gamma=1
        ), 3
    h, gamma = 3, 2
    cats = np.full((n, gamma), -1, np.int32)
    cats[:, 0] = rng.integers(0, h, n)
    extra = rng.random(n) < 0.5
    cats[extra, 1] = rng.integers(0, h, extra.sum())
    # k=2 with dense clusters: delegate adds trigger the greedy-matching
    # shrink, so the parity covers the transversal shrink path too
    return P, cats, None, MatroidSpec(
        "transversal", num_categories=h, gamma=gamma
    ), 2


def _ingest(P, cats, caps, spec, k, tau, *, variant, block_size, step_impl,
            splits=None):
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    n = P.shape[0]
    splits = splits or [n]
    st = init_stream_state(P.shape[1], cats.shape[1], spec, k, tau)
    off = 0
    for b in splits:
        st = ingest_batch(
            st, jnp.asarray(P[off:off + b]), jnp.asarray(cats[off:off + b]),
            jnp.ones((b,), bool), spec, caps_j, k, tau, base_index=off,
            variant=variant, block_size=block_size, step_impl=step_impl,
        )
        off += b
    assert off == n
    return st


def _assert_states_equal(a, b, label):
    for f in a._fields:
        assert np.array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        ), f"{label}: field {f} diverged"


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("kind", KINDS)
def test_branchless_equals_reference(kind, variant):
    """One-shot ingestion, every block size, both step impls -> one state."""
    n, tau = 120, 8
    P, cats, caps, spec, k = _instance(kind, seed=0, n=n)
    ref = _ingest(P, cats, caps, spec, k, tau, variant=variant,
                  block_size=1, step_impl="reference")
    for bs in BLOCKS:
        st = _ingest(P, cats, caps, spec, k, tau, variant=variant,
                     block_size=bs, step_impl="branchless")
        _assert_states_equal(ref, st, f"{kind}/{variant} block={bs}")


@pytest.mark.parametrize("kind", KINDS)
def test_branchless_equals_reference_split_resume(kind):
    """Ragged batch splits resume mid-block identically under both impls."""
    n, tau = 120, 8
    P, cats, caps, spec, k = _instance(kind, seed=1, n=n)
    ref = _ingest(P, cats, caps, spec, k, tau, variant="radius",
                  block_size=1, step_impl="reference", splits=[n])
    st = _ingest(P, cats, caps, spec, k, tau, variant="radius",
                 block_size=16, step_impl="branchless", splits=[37, 30, 53])
    _assert_states_equal(ref, st, f"{kind} split resume")


@pytest.mark.parametrize("num_shards", [2, 4])
@pytest.mark.parametrize("kind", KINDS)
def test_branchless_equals_reference_sharded(kind, num_shards):
    """The vmapped sharded drive produces bit-identical per-shard states
    under both step impls (the very case the branchless step exists for:
    a vmapped cond ladder pays select-both-branches, a vmapped masked
    update does not — but they must agree bit for bit)."""
    n, tau, bs = 96, 8, 16
    P, cats, caps, spec, k = _instance(kind, seed=2, n=n)
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    S = num_shards
    d, gamma = P.shape[1], cats.shape[1]
    mm = -(-n // S)
    Pb = np.zeros((S, mm, d), np.float32)
    Cb = np.full((S, mm, gamma), -1, np.int32)
    Vb = np.zeros((S, mm), bool)
    Sb = np.full((S, mm), -1, np.int32)
    for s in range(S):
        rows = np.arange(s, n, S)
        r = len(rows)
        Pb[s, :r] = P[rows]
        Cb[s, :r] = cats[rows]
        Vb[s, :r] = True
        Sb[s, :r] = rows
    args = (jnp.asarray(Pb), jnp.asarray(Cb), jnp.asarray(Vb),
            jnp.asarray(Sb), spec, caps_j, k, tau)
    sts0 = init_sharded_states(S, d, gamma, spec, k, tau)
    a = ingest_batch_sharded(sts0, *args, block_size=bs,
                             step_impl="branchless")
    b = ingest_batch_sharded(sts0, *args, block_size=bs,
                             step_impl="reference")
    _assert_states_equal(a, b, f"{kind} sharded x{S}")


@pytest.mark.parametrize("kind", KINDS)
def test_shard_map_drive_matches_vmap(kind):
    """The shard_map-over-mesh drive is the same scan under a different
    parallel drive: bit-identical stacked states (whatever the local
    device count — a 1-device mesh degenerates to the vmap path)."""
    n, tau, bs, S = 96, 8, 16, 4
    P, cats, caps, spec, k = _instance(kind, seed=3, n=n)
    caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
    d, gamma = P.shape[1], cats.shape[1]
    mm = -(-n // S)
    Pb = np.zeros((S, mm, d), np.float32)
    Cb = np.full((S, mm, gamma), -1, np.int32)
    Vb = np.zeros((S, mm), bool)
    Sb = np.full((S, mm), -1, np.int32)
    for s in range(S):
        rows = np.arange(s, n, S)
        r = len(rows)
        Pb[s, :r] = P[rows]
        Cb[s, :r] = cats[rows]
        Vb[s, :r] = True
        Sb[s, :r] = rows
    args = (jnp.asarray(Pb), jnp.asarray(Cb), jnp.asarray(Vb),
            jnp.asarray(Sb), spec, caps_j, k, tau)
    sts0 = init_sharded_states(S, d, gamma, spec, k, tau)
    a = ingest_batch_sharded(sts0, *args, block_size=bs)
    b = ingest_batch_sharded_mapped(sts0, *args, block_size=bs)
    _assert_states_equal(a, b, f"{kind} shard_map vs vmap")


def test_reference_impl_rejects_unknown():
    P, cats, caps, spec, k = _instance("uniform", seed=4, n=8)
    with pytest.raises(ValueError, match="step_impl"):
        _ingest(P, cats, caps, spec, k, 4, variant="radius",
                block_size=1, step_impl="nope")


@pytest.mark.parametrize("kind", ["partition", "transversal"])
def test_out_of_range_labels_stay_bit_identical(kind):
    """Labels outside [0, num_categories) — negative or too large — cannot
    be classified by the precheck's count tables (a gather would clamp
    where the step compares exactly); they must fall back to the exact
    replay so blocked == per-point holds for arbitrary label input."""
    rng = np.random.default_rng(7)
    n, tau = 90, 8
    P = make_clustered_points(rng, n=n, d=4, centers=3, spread=0.08)
    if kind == "partition":
        cats = rng.integers(0, 3, (n, 1)).astype(np.int32)
        cats[::7, 0] = -1  # hostile: negative label
        cats[::11, 0] = 5  # hostile: label >= num_categories
        caps = np.full(3, 2, np.int32)
        spec = MatroidSpec("partition", num_categories=3, gamma=1)
        k = 3
    else:
        cats = np.full((n, 2), -1, np.int32)
        cats[:, 0] = rng.integers(0, 3, n)
        cats[::7, 1] = 9  # hostile: label >= num_categories
        caps = None
        spec = MatroidSpec("transversal", num_categories=3, gamma=2)
        k = 2
    ref = _ingest(P, cats, caps, spec, k, tau, variant="radius",
                  block_size=1, step_impl="reference")
    for bs in (16, 64):
        st = _ingest(P, cats, caps, spec, k, tau, variant="radius",
                     block_size=bs, step_impl="branchless")
        _assert_states_equal(ref, st, f"{kind} hostile labels block={bs}")


def test_diameter_restructure_parity():
    """A widening stream forces the diameter-variant R update + filter +
    merge; the branchless _cond_once machinery must match the reference
    cond exactly through the restructure."""
    rng = np.random.default_rng(5)
    n = 100
    # exponentially growing spread => repeated d1 > 2R triggers
    P = (rng.normal(size=(n, 3)) * np.geomspace(0.01, 50.0, n)[:, None]
         ).astype(np.float32)
    cats = rng.integers(0, 3, (n, 1)).astype(np.int32)
    caps = np.full(3, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=3, gamma=1)
    ref = _ingest(P, cats, caps, spec, 3, 8, variant="diameter",
                  block_size=1, step_impl="reference")
    for bs in (1, 16):
        st = _ingest(P, cats, caps, spec, 3, 8, variant="diameter",
                     block_size=bs, step_impl="branchless")
        _assert_states_equal(ref, st, f"diameter restructure block={bs}")
