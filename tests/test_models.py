"""Per-architecture smoke tests (reduced configs): forward + one train step
on CPU, output shapes, finite losses; decode-vs-forward consistency."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import LM
from repro.train.optimizer import AdamWConfig
from repro.train.train_state import StepConfig, init_train_state, make_train_step


def _inputs(cfg, lm, B=2, S=32, seed=1):
    rng = jax.random.PRNGKey(seed)
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    img = None
    if cfg.family == "vlm":
        img = 0.1 * jax.random.normal(
            rng, (B, cfg.n_img_tokens, cfg.d_model), lm.dtype
        )
    return toks, img


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_loss(arch):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    toks, img = _inputs(cfg, lm)
    logits, aux, _ = lm.forward(params, toks, img, remat=False)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    loss, metrics = lm.loss(params, toks, img)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    state = init_train_state(lm, jax.random.PRNGKey(0), opt_cfg)
    step = make_train_step(lm, opt_cfg, StepConfig())
    toks, img = _inputs(cfg, lm)
    batch = {"tokens": toks}
    if img is not None:
        batch["img"] = img
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    toks, img = _inputs(cfg, lm, B, S)
    logits_full, _, _ = lm.forward(params, toks, img, remat=False)
    _, caches = lm.prefill(params, toks[:, : S - 1], img)

    def pad_leaf(x):
        if (
            x.ndim >= 4
            and x.shape[-3] == S - 1
            and x.shape[-2] == max(cfg.n_kv, 1)
            and x.shape[-1] == cfg.hd
        ):
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, 1)
            return jnp.pad(x, pad)
        return x

    caches = jax.tree.map(pad_leaf, caches)
    logits_dec, _ = lm.decode_step(
        params, toks[:, S - 1 : S], caches, jnp.int32(S - 1), img
    )
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec, np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 0.05, err


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_init_caches_structure_matches_prefill(arch):
    """init_caches (the dry-run cache spec source) must structurally match
    what prefill actually emits."""
    cfg = get_config(arch).reduced()
    lm = LM(cfg)
    params = lm.abstract_params()
    S = 16
    toks = jax.ShapeDtypeStruct((2, S), jnp.int32)
    img = (
        jax.ShapeDtypeStruct((2, cfg.n_img_tokens, cfg.d_model), lm.dtype)
        if cfg.family == "vlm" else None
    )
    _, caches = jax.eval_shape(lambda p, t: lm.prefill(p, t, img and jnp.zeros(img.shape, img.dtype)), params, toks) \
        if img is None else jax.eval_shape(lambda p, t, i: lm.prefill(p, t, i), params, toks, img)
    want = jax.eval_shape(lambda: lm.init_caches(2, S))
    t1 = jax.tree.structure(caches)
    t2 = jax.tree.structure(want)
    assert t1 == t2, (t1, t2)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(want)):
        assert a.shape == b.shape, (a.shape, b.shape)


def test_microbatch_grads_match_full_batch():
    """M=4 grad accumulation == single full-batch step (same update)."""
    cfg = get_config("smollm-135m").reduced()
    lm = LM(cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    toks = jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, cfg.vocab)
    outs = []
    for M in (1, 4):
        state = init_train_state(lm, jax.random.PRNGKey(0), opt_cfg)
        step = make_train_step(lm, opt_cfg, StepConfig(microbatches=M))
        s2, m = jax.jit(step)(state, {"tokens": toks})
        outs.append((s2, float(m["loss"])))
    assert abs(outs[0][1] - outs[1][1]) < 1e-3
    for a, b in zip(jax.tree.leaves(outs[0][0]["params"]),
                    jax.tree.leaves(outs[1][0]["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-4,
        )
