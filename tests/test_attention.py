"""Flash attention (custom VJP) vs dense reference: values and gradients."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.common import blockwise_attention, decode_attention


def _dense(q, k, v, causal):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qr = q.reshape(B, Sq, KV, rep, hd)
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qr, k) / np.sqrt(hd)
    if causal:
        m = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bgrqk,bkgh->bqgrh", p, v).reshape(B, Sq, H, hd)


CASES = [
    # B, Sq, Skv, H, KV, hd, causal, skip
    (2, 64, 64, 4, 2, 16, True, False),
    (2, 64, 64, 4, 2, 16, True, True),
    (1, 96, 96, 8, 8, 8, True, False),
    (2, 48, 80, 4, 4, 8, False, False),
    (1, 33, 33, 2, 1, 16, True, False),
    (1, 40, 72, 6, 3, 8, False, False),
]


@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd,causal,skip", CASES)
def test_flash_fwd_bwd(B, Sq, Skv, H, KV, hd, causal, skip):
    rng = np.random.default_rng(B * Sq + Skv)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Skv, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Skv, KV, hd)), jnp.float32)

    def loss_fa(q, k, v):
        o = blockwise_attention(
            q, k, v, causal=causal, q_block=16, kv_block=32,
            skip_masked_blocks=skip,
        )
        return jnp.sum(jnp.sin(o))

    def loss_dense(q, k, v):
        return jnp.sum(jnp.sin(_dense(q, k, v, causal)))

    v1, g1 = jax.value_and_grad(loss_fa, argnums=(0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(v1 - v2)) < 1e-2
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2,
                                   atol=2e-3)


def test_block_size_invariance():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    outs = [
        blockwise_attention(q, k, v, causal=True, q_block=bq, kv_block=bk)
        for bq, bk in [(8, 8), (16, 64), (64, 16), (64, 64)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_dense():
    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    pos = 20  # attend to <= 20 only
    out = decode_attention(q, kc, vc, jnp.int32(pos))
    ref = _dense(q, kc[:, : pos + 1], vc[:, : pos + 1], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)
