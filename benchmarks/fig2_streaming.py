"""Paper Fig. 2: StreamCoreset — coreset size (tau) vs quality vs time,
single pass over the full dataset.

Paper scale: full Wikipedia/Songs, tau in {8..256}. Container scale:
n=20000, tau in {8,16,32,64,128}.
"""
from __future__ import annotations

import numpy as np

from repro.core import solve_dmmc

from .common import Timer, csv_line, songs_like, wikipedia_like


def run(n=20000, k=16, quick=False):
    rows = []
    taus = (8, 32) if quick else (8, 16, 32, 64, 128)
    for name, (P, cats, caps, spec) in [
        ("songs", songs_like(n)), ("wikipedia", wikipedia_like(n)),
    ]:
        for tau in taus:
            with Timer() as t:
                sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                                 setting="streaming", metric="cosine")
            rows.append(dict(dataset=name, tau=tau, time_s=t.s,
                             diversity=sol.diversity,
                             coreset=sol.coreset_size))
    return rows


def main(quick=False):
    rows = run(quick=quick)
    best = {}
    for r in rows:
        best[r["dataset"]] = max(best.get(r["dataset"], 0), r["diversity"])
    return [
        csv_line(
            f"fig2_{r['dataset']}/tau={r['tau']}", r["time_s"] * 1e6,
            f"diversity_ratio={r['diversity']/best[r['dataset']]:.4f};"
            f"coreset={r['coreset']}",
        )
        for r in rows
    ]


if __name__ == "__main__":
    print("\n".join(main()))
