"""Thm 1/2 size-bound table: measured |T| vs the O(k tau) / O(k^2 tau)
worst-case capacities across (k, tau) — the paper's observation that real
coresets are far below the conservative bounds (§3.1 remark)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.coreset import default_capacity, seq_coreset

from .common import csv_line, songs_like, wikipedia_like
from .common import Timer


def run(n=8000):
    rows = []
    for name, (P, cats, caps, spec) in [
        ("songs", songs_like(n)), ("wikipedia", wikipedia_like(n)),
    ]:
        caps_j = None if caps is None else jnp.asarray(caps)
        for k in (4, 16):
            for tau in (16, 64):
                with Timer() as t:
                    cs, _res, ovf = seq_coreset(
                        jnp.asarray(P), jnp.asarray(cats),
                        jnp.ones((n,), bool), spec, caps_j, k, tau,
                    )
                    size = int(cs.size())
                cap = default_capacity(spec, k, tau)
                rows.append(dict(dataset=name, k=k, tau=tau, size=size,
                                 bound=cap, time_s=t.s,
                                 overflow=int(ovf)))
    return rows


def main(quick=False):
    return [
        csv_line(
            f"coreset_size_{r['dataset']}/k={r['k']}/tau={r['tau']}",
            r["time_s"] * 1e6,
            f"size={r['size']};bound={r['bound']};"
            f"fill={r['size']/r['bound']:.3f};overflow={r['overflow']}",
        )
        for r in run()
    ]


if __name__ == "__main__":
    print("\n".join(main()))
