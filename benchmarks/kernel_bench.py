"""Kernel microbench: us_per_call for the GMM/pdist/SSD hot paths.

On this CPU container the numbers time the jnp reference path (the Pallas
kernels target TPU and run here only under interpret=True, which measures
python, not hardware). Interpret-mode correctness is covered by tests.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

from .common import csv_line


def _time(f, *args, reps=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def main(quick=False):
    rng = np.random.default_rng(0)
    out = []
    n, m, d = (20000, 256, 25) if not quick else (2000, 64, 25)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    us = _time(lambda a, b: ops.pairwise_sqdist(a, b, force="ref"), x, y)
    flops = 2 * n * m * d
    out.append(csv_line("kernel_pdist_ref", us,
                        f"gflops={flops/us/1e3:.2f}"))
    md = jnp.full((n,), 1e9, jnp.float32)
    v = jnp.ones((n,), bool)
    us = _time(
        lambda a, z, c, w: ops.gmm_update(a, z, c, w, force="ref"),
        x, y[0], md, v,
    )
    out.append(csv_line("kernel_gmm_update_ref", us,
                        f"bytes_per_s={(n*d*4+n*8)/us*1e6/1e9:.2f}GB"))
    g, q, p, nn = (64, 128, 64, 64) if not quick else (8, 32, 16, 16)
    xb = jnp.asarray(rng.normal(size=(g, q, p)), jnp.float32)
    la = jnp.asarray(-rng.uniform(0.01, 0.3, size=(g, q)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(g, q, nn)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(g, q, nn)), jnp.float32)
    us = _time(
        lambda *a: ops.ssd_intra_chunk(*a, force="ref"), xb, la, B, C
    )
    fl = g * (2 * q * q * nn + 2 * q * q * p + 2 * q * nn * p)
    out.append(csv_line("kernel_ssd_intra_ref", us,
                        f"gflops={fl/us/1e3:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(main()))
