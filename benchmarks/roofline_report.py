"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell JSON
records produced by launch/dryrun.py."""
from __future__ import annotations

import glob
import json
import os


def load(out_dir="experiments/dryrun", tag="base"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs, mesh="single"):
    rows = []
    head = (
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "bound s | MODEL/HLO | peak GiB |"
    )
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in recs:
        if r.get("mesh") != mesh or r.get("skipped"):
            continue
        t = r["terms_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4g} | "
            f"{t['memory_s']:.4g} | {t['collective_s']:.4g} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['roofline_bound_s']:.4g} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['memory']['peak_estimate_gib']:.2f} |"
        )
    return "\n".join(rows)


def main(quick=False):
    recs = load()
    lines = []
    for mesh in ("single", "multi"):
        n = sum(1 for r in recs if r.get("mesh") == mesh and not r.get("skipped"))
        lines.append(f"roofline_cells_{mesh},{n},")
    return lines


if __name__ == "__main__":
    recs = load()
    print("## single-pod (16x16 = 256 chips)\n")
    print(fmt_table(recs, "single"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(fmt_table(recs, "multi"))
