"""Benchmark harness: one entry per paper table/figure + kernel microbench.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
    PYTHONPATH=src python -m benchmarks.run --check

``--check`` is the serving-perf regression gate: it reruns
``serve_bench --quick`` and ``frontend_load --quick`` and exits 1 if
``ingest_points_per_s`` / ``batched_qps`` regressed more than 20%
against the committed ``BENCH_serve.json``, or any query-path gate
fails against ``BENCH_frontend.json`` (coalescing speedup, tail ratio,
deadline violations — see ``frontend_load``'s docstring).

Prints ``name,us_per_call,derived`` CSV (paper analogues documented in each
module; DESIGN.md §9 maps benchmarks -> paper figures).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--check", action="store_true",
                    help="rerun serve_bench --quick + frontend_load "
                         "--quick and fail on regressions vs the "
                         "committed BENCH_serve.json / "
                         "BENCH_frontend.json")
    args = ap.parse_args()

    if args.check:
        from . import frontend_load, serve_bench

        rc = serve_bench.check()
        rc = frontend_load.check() or rc
        sys.exit(rc)

    from . import (
        coreset_sizes,
        fig1_seq_vs_amt,
        fig2_streaming,
        fig3_mapreduce,
        frontend_load,
        kernel_bench,
        roofline_report,
        serve_bench,
        variants_quality,
    )

    suites = [
        ("kernels", kernel_bench.main),
        ("variants", variants_quality.main),
        ("coreset_sizes", coreset_sizes.main),
        ("fig1", fig1_seq_vs_amt.main),
        ("fig2", fig2_streaming.main),
        ("fig3", fig3_mapreduce.main),
        ("serve", serve_bench.main),
        ("frontend_load", frontend_load.main),
        ("roofline", roofline_report.main),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        try:
            for line in fn(quick=args.quick):
                print(line, flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
