"""Paper Fig. 3: MRCoreset scalability with parallelism l = 1, 2, 4, 8
(each l runs in a subprocess with that many forced host devices, mirroring
the paper's 1..16-machine Spark sweep), vs SeqCoreset and StreamCoreset at
the same tau.

Container scale: n=20000, tau=64, k=8.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import csv_line

_CHILD = """
import json, numpy as np, jax
import sys
sys.path.insert(0, {src!r})
from benchmarks.common import songs_like, wikipedia_like, Timer
from repro.core import solve_dmmc
from repro.launch.mesh import make_mesh

n, k, tau, l, ds = {n}, {k}, {tau}, {l}, {ds!r}
P, cats, caps, spec = (songs_like if ds == "songs" else wikipedia_like)(n)
mesh = make_mesh((l,), ("data",))
with Timer() as t:
    sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                     setting="mapreduce", mesh=mesh, metric="cosine")
# per-shard construction latency: one reducer's work (n/l points,
# tau/l centers) — the wall-clock a real l-machine round takes (this
# container has ONE core, so the mapreduce timing above measures
# aggregate work, not parallel latency)
sol1 = solve_dmmc(P[: n // l], k, spec, cats=cats[: n // l], caps=caps,
                  tau=max(1, tau // l), setting="sequential",
                  metric="cosine")
with Timer() as t1:
    sol1 = solve_dmmc(P[: n // l], k, spec, cats=cats[: n // l],
                      caps=caps, tau=max(1, tau // l),
                      setting="sequential", metric="cosine")
print(json.dumps(dict(time_s=t.s, diversity=sol.diversity,
                      coreset=sol.coreset_size,
                      coreset_s=sol.timings["coreset_s"],
                      per_shard_s=sol1.timings["coreset_s"],
                      solver_s=sol.timings["solver_s"])))
"""


def run(n=20000, k=8, tau=64, quick=False):
    src = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    ells = (1, 4) if quick else (1, 2, 4, 8)
    for ds in ("songs", "wikipedia"):
        for l in ells:
            code = _CHILD.format(src=src, n=n, k=k, tau=tau, l=l, ds=ds)
            env = dict(os.environ)
            env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={l}"
            env["PYTHONPATH"] = os.path.join(src, "src")
            r = subprocess.run([sys.executable, "-c", code],
                               capture_output=True, text=True, env=env,
                               timeout=1800)
            assert r.returncode == 0, r.stderr[-2000:]
            rec = json.loads(r.stdout.strip().splitlines()[-1])
            rec.update(dataset=ds, l=l)
            rows.append(rec)
    return rows


def main(quick=False):
    rows = run(quick=quick)
    best = {}
    for r in rows:
        best[r["dataset"]] = max(best.get(r["dataset"], 0), r["diversity"])
    return [
        csv_line(
            f"fig3_{r['dataset']}/l={r['l']}", r["time_s"] * 1e6,
            f"diversity_ratio={r['diversity']/best[r['dataset']]:.4f};"
            f"coreset_s={r['coreset_s']:.2f};"
            f"per_shard_s={r['per_shard_s']:.2f};"
            f"solver_s={r['solver_s']:.2f}",
        )
        for r in rows
    ]


if __name__ == "__main__":
    print("\n".join(main()))
