"""Paper Fig. 1: time-vs-diversity, SeqCoreset (tau sweep) vs AMT local
search on the full input — sequential setting.

Paper scale: 5000-point samples of Wikipedia/Songs, tau in {8..256},
k in {rank/4, rank}. Container scale (1 CPU core): n=3000, tau in
{8,16,32,64}, k in {8, 22}; AMT gamma in {0, 0.2}.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import local_search_sum, make_host_matroid, solve_dmmc
from repro.core.geometry import dists, normalize_for_metric

from .common import Timer, csv_line, songs_like, wikipedia_like


def run(n=8000, k=8, quick=False):
    rows = []
    if quick:
        n = 2000
    datasets = [("songs", songs_like(n)), ("wikipedia", wikipedia_like(n))]
    taus = (8, 32) if quick else (8, 16, 32, 64)
    gammas = (0.2,) if quick else (0.0, 0.2)
    for name, (P, cats, caps, spec) in datasets:
        # warm the jit caches so coreset timings measure the algorithm,
        # not trace/compile (the paper's timings are steady-state too)
        solve_dmmc(P[:256], k, spec, cats=cats[:256], caps=caps, tau=8,
                   setting="sequential", metric="cosine")
        Pn = np.asarray(normalize_for_metric(jnp.asarray(P), "cosine"))
        matroid = make_host_matroid(spec, cats, caps, len(P), k)
        # AMT baseline over the FULL input
        D = np.asarray(dists(jnp.asarray(Pn), jnp.asarray(Pn)))
        for g in gammas:
            with Timer() as t:
                _, val, swaps = local_search_sum(
                    D, matroid, k, range(n), gamma=g
                )
            rows.append(dict(dataset=name, algo=f"AMT(g={g})", tau=None,
                             time_s=t.s, diversity=val))
        del D
        for tau in taus:
            with Timer() as t:
                sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                                 setting="sequential", metric="cosine")
            rows.append(dict(dataset=name, algo="SeqCoreset", tau=tau,
                             time_s=t.s, diversity=sol.diversity,
                             coreset=sol.coreset_size,
                             coreset_s=sol.timings["coreset_s"],
                             solver_s=sol.timings["solver_s"]))
    return rows


def main(quick=False):
    rows = run(quick=quick)
    out = []
    best = {}
    for r in rows:
        best.setdefault(r["dataset"], 0.0)
        best[r["dataset"]] = max(best[r["dataset"]], r["diversity"])
    for r in rows:
        ratio = r["diversity"] / best[r["dataset"]]
        tag = f"{r['dataset']}/{r['algo']}" + (
            f"/tau={r['tau']}" if r["tau"] else ""
        )
        out.append(csv_line(
            f"fig1_{tag}", r["time_s"] * 1e6,
            f"diversity_ratio={ratio:.4f}"
        ))
    return out


if __name__ == "__main__":
    print("\n".join(main()))
