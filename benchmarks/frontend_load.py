"""Frontend load benchmark: closed-loop concurrent clients against the
query frontend, coalesced vs per-call, mixed tenants.

    PYTHONPATH=src python -m benchmarks.frontend_load [--quick] [--json]

``--json`` writes a ``BENCH_frontend.json`` artifact (repo root), the
query-path companion to ``BENCH_serve.json``: it records aggregate QPS,
p50/p99 latency and deadline outcomes at 1/4/16 concurrent mixed-tenant
clients for two arms over the SAME published stream —

* **coalesced**: the default ``QueryFrontend`` (adaptive micro-batch
  window, tenant-sharded dispatcher pool, cost-model routing);
  concurrent callers merge into pow-2-bucketed vmapped solves, stacked
  across tenants into one device dispatch where the engine allows;
* **per-call**: an identical frontend with ``CoalesceConfig(enabled=
  False)`` — every call runs the historical direct path alone.

The coalesced arm runs the serving DEFAULTS (Little's-law adaptive
window, ``dispatchers = min(4, cpu)``): the bench measures what ships,
and the artifact embeds the controller's window-size-over-time trace
(``window_trace``) so its dynamics — solo-collapse at 1 client, widening
under the 16-client burst — are inspectable from the CI artifact.

Methodology mirrors ``serve_bench``: both arms are driven *interleaved*
round-by-round (same host weather, so their ratio is robust to scheduler
noise), after a warmup that pays every jit compile at the measured
pow-2 (B, k) buckets and calibrates both arms' cost models, so the
measurement window is steady state (recompiles there would poison p99
and the cost model alike). QPS is the best round (the stable estimator
on a noisy shared host); the tail gate ``p99 <= 2 x p50`` and the
deadline gate use the min over rounds, like the serve bench's deadline
burst — one scheduler burst cannot fail the gate, a real regression
shifts every round.

Clients are closed-loop threads: each issues 1-2-query batches (k
alternating across two pow-2 buckets) on one of four tenants fanned out
from the single stream (default / cosine / uniform / uniform-cosine),
half the calls carrying a generous ``deadline_s`` — the bench asserts
the window never holds a call past its deadline (violations gated 0).

``benchmarks.run --check`` reruns the quick configuration and gates:

* the *committed* artifact must carry ``speedup_16 >= 2.0`` (coalescing
  must never be re-baselined as a no-win — that is the tentpole);
* the *committed* artifact must carry ``speedup_4 > 1.0``: moderate
  concurrency paid for the window before PR 10 (~0.8x); with stacked
  cross-tenant dispatch and the adaptive window it must be a win, and
  may never be re-baselined back into a loss;
* the re-measured ``speedup_16`` must stay >= 1.0 (machine-relative
  ratio, enforced everywhere: merged dispatch may never be slower than
  16 solo dispatches);
* ``p99_p50_ratio_4 <= 2.0`` (min over rounds, coalesced arm at 4
  clients): the window must not fatten the tail at moderate load;
* ``deadline_violations == 0`` (min over rounds) and zero sheds of
  in-budget calls;
* at 16 clients the coalescer must have actually merged calls
  (``coalesced_calls > 0`` — machine-independent routing gate);
* absolute ``coalesced_qps_16`` floor vs the committed value, relaxed
  to report-only when the environment (backend/device/arch) differs.

Every check run drops its fresh measurement at
``BENCH_frontend.check.json`` (CI uploads it as a workflow artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import platform as _platform
import sys
import threading
import time

import numpy as np

from .common import csv_line, songs_like

LEVELS = (1, 4, 16)
DEADLINE_S = 5.0  # generous: warm solves are ms-scale, violations gate 0
K_BUCKETS = (3, 5)  # pow-2 k buckets 4 and 8
WARM_BATCHES = (1, 2, 4, 8, 16, 32)  # covers every merged pow-2 B bucket

_JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_frontend.json",
)


def _build(n: int, k: int, tau: int):
    """One published stream + two frontend arms (coalesced / per-call)
    with identical 4-tenant fan-out over it."""
    from repro.core.matroid import MatroidSpec
    from repro.serve.diversity import (
        CoalesceConfig,
        QueryFrontend,
        StreamRuntime,
    )

    P, cats, caps, spec = songs_like(n)
    rt = StreamRuntime(spec, k, tau=tau, caps=caps)
    rt.ingest(P, cats)
    arms = {
        # serving defaults on purpose: adaptive window + dispatcher pool
        "coalesced": QueryFrontend(rt, coalesce=CoalesceConfig()),
        "percall": QueryFrontend(rt, coalesce=CoalesceConfig(enabled=False)),
    }
    uspec = MatroidSpec("uniform")
    for fe in arms.values():
        fe.register_tenant("cosine", metric="cosine")
        fe.register_tenant("uniform", spec=uspec)
        fe.register_tenant("uniform-cos", spec=uspec, metric="cosine")
    names = ["default", "cosine", "uniform", "uniform-cos"]
    return rt, arms, names


def _warm(fe, names) -> None:
    """Pay every compile + calibrate the cost model before measuring.

    Engine-pinned passes compile the jit cells at every pow-2 (B, k)
    bucket a merged group can reach (16 clients x 2 queries max) for
    both matroid views; the repeated auto passes run post-compile so
    ``CostModel.observe`` records honest steady-state latencies (the
    frontend skips observations for solves that compiled anything).
    """
    from repro.serve.diversity import DiversityQuery

    for name in names:  # build each tenant's cache entry once
        fe.query_batch([DiversityQuery(k=max(K_BUCKETS))], tenant=name)
    for tenant in ("default", "uniform"):  # one per matroid view
        for kq in K_BUCKETS:
            for b in WARM_BATCHES:
                qs = [DiversityQuery(k=kq)] * b
                for eng in ("jit_sum", "host"):
                    fe.query_batch(qs, tenant=tenant, engine=eng)
                fe.query_batch(qs, tenant=tenant)  # calibrate auto cells
                fe.query_batch(qs, tenant=tenant)


def _run_level(fe, names, level: int, iters: int) -> dict:
    """One closed-loop round: ``level`` client threads x ``iters`` calls.

    Mixed shapes on purpose — B alternates 1/2 and k across two pow-2
    buckets per client, so a merged group spans sub-batches exactly like
    real mixed traffic (and the parity suite's shapes)."""
    from repro.serve.diversity import DiversityQuery

    lock = threading.Lock()
    lats: list[float] = []
    viol = sheds = total_q = 0
    barrier = threading.Barrier(level + 1)

    def client(i: int) -> None:
        nonlocal viol, sheds, total_q
        my_lats, my_viol, my_sheds, my_q = [], 0, 0, 0
        barrier.wait()
        for it in range(iters):
            b = 1 + (it + i) % 2
            qs = [DiversityQuery(k=K_BUCKETS[(it + i + j) % 2])
                  for j in range(b)]
            dl = DEADLINE_S if it % 2 == 0 else None
            t0 = time.perf_counter()
            res = fe.query_batch(qs, tenant=names[i % len(names)],
                                 deadline_s=dl)
            dt = time.perf_counter() - t0
            my_lats.append(dt)
            my_q += len(res)
            if dl is not None and dt > dl:
                my_viol += 1
            my_sheds += sum(1 for r in res if r.engine == "shed")
        with lock:
            lats.extend(my_lats)
            viol += my_viol
            sheds += my_sheds
            total_q += my_q

    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(level)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    arr = np.asarray(lats)
    return dict(
        qps=total_q / wall,
        p50_s=float(np.percentile(arr, 50)),
        p99_s=float(np.percentile(arr, 99)),
        violations=viol,
        sheds=sheds,
        wall_s=wall,
    )


def _bench(quick: bool) -> dict:
    import jax

    n = 2000 if quick else 6000
    k, tau = max(K_BUCKETS), 24
    calls_per_round = 64 if quick else 128
    rounds = 3 if quick else 5

    rt, arms, names = _build(n, k, tau)
    for fe in arms.values():  # coalesced first pays the process jit cache
        _warm(fe, names)

    # interleaved rounds, arm order alternating so neither arm always
    # rides the colder half of a scheduler burst
    per: dict[str, dict[int, list[dict]]] = {
        arm: {lv: [] for lv in LEVELS} for arm in arms
    }
    order = list(arms)
    for r in range(rounds):
        for lv in LEVELS:
            iters = max(2, calls_per_round // lv)
            for arm in (order if r % 2 == 0 else order[::-1]):
                per[arm][lv].append(_run_level(arms[arm], names, lv, iters))

    results: dict[str, dict] = {}
    for arm, by_level in per.items():
        results[arm] = {}
        for lv, rows in by_level.items():
            results[arm][str(lv)] = dict(
                qps=float(max(x["qps"] for x in rows)),
                p50_s=float(min(x["p50_s"] for x in rows)),
                p99_s=float(min(x["p99_s"] for x in rows)),
                p99_p50_ratio=float(
                    min(x["p99_s"] / x["p50_s"] for x in rows)),
                violations=int(min(x["violations"] for x in rows)),
                sheds=int(sum(x["sheds"] for x in rows)),
                rounds=[{k_: float(v) if isinstance(v, float) else v
                         for k_, v in x.items()} for x in rows],
            )
    speedup = {
        str(lv): results["coalesced"][str(lv)]["qps"]
        / results["percall"][str(lv)]["qps"]
        for lv in LEVELS
    }
    co_stats = arms["coalesced"].stats()
    co = co_stats.get("coalesce") or {}
    cm = co_stats.get("cost_model") or {}
    win = co.get("window") or {}
    trace = win.get("trace") or []
    t0_trace = trace[0][0] if trace else 0.0
    co_cfg = arms["coalesced"].coalescer.config
    dev = jax.devices()[0]
    out = dict(
        n=n, k=k, tau=tau,
        calls_per_round=calls_per_round,
        rounds=rounds,
        levels=list(LEVELS),
        k_buckets=list(K_BUCKETS),
        queries_per_call=[1, 2],
        tenant_count=len(names),
        deadline_s=DEADLINE_S,
        window=dict(
            adaptive=bool(co_cfg.adaptive),
            seed_us=float(co_cfg.window_s * 1e6),
            min_us=float(co_cfg.window_min_s * 1e6),
            max_us=float(co_cfg.window_max_s * 1e6),
        ),
        dispatchers=int(co.get("dispatchers", 0)),
        results=results,
        speedup={lv: float(s) for lv, s in speedup.items()},
        speedup_4=float(speedup["4"]),
        speedup_16=float(speedup["16"]),
        p99_p50_ratio_4=float(
            results["coalesced"]["4"]["p99_p50_ratio"]),
        deadline_violations=int(
            min(results[arm][str(lv)]["violations"]
                for arm in results for lv in LEVELS)),
        sheds=int(sum(results[arm][str(lv)]["sheds"]
                      for arm in results for lv in LEVELS)),
        coalesced_calls=int(co.get("coalesced_calls", 0)),
        coalesce_groups=int(co.get("groups", 0)),
        stacked_solves=int(co.get("stacked_solves", 0)),
        stacked_rows=int(co.get("stacked_rows", 0)),
        solo_calls=int(
            arms["coalesced"].registry.counter("serve.coalesce.solo").value),
        # the adaptive controller's window-size-over-time series
        # (seconds since first evaluation, window seconds) — uploaded
        # with the artifact so window dynamics are reviewable in CI
        window_trace=[
            [float(t - t0_trace), float(w)] for t, w in trace
        ],
        window_rate_hz=float(win.get("rate_hz") or 0.0),
        window_solve_est_s=win.get("solve_est_s"),
        cost_model_decisions=cm.get("decisions", [])[-8:],
        tenant_traffic=co_stats.get("tenant_traffic"),
        device_count=int(jax.device_count()),
        backend=str(jax.default_backend()),
        device_kind=str(getattr(dev, "device_kind", dev.platform)),
        machine=f"{_platform.system()}-{_platform.machine()}",
        host=_platform.node(),
    )
    for fe in arms.values():
        fe.close()
    rt.close()
    return out


def check(tolerance: float = 0.2, quick: bool = True) -> int:
    """Rerun the quick load bench and compare against the committed
    artifact; returns a process exit code (1 on failure). See the module
    docstring for the gate list."""
    if not os.path.exists(_JSON_PATH):
        print(f"check: no committed {_JSON_PATH}; nothing to compare")
        return 0
    with open(_JSON_PATH) as f:
        old = json.load(f)
    new = _bench(quick)
    with open(_JSON_PATH.replace(".json", ".check.json"), "w") as f:
        json.dump(new, f, indent=2)
    rc = 0
    # config drift always fails: a changed workload invalidates the
    # committed baseline, re-baseline with `frontend_load --quick --json`
    for key in ("n", "k", "tau", "calls_per_round", "levels", "k_buckets",
                "tenant_count", "window"):
        if key in old and old[key] != new[key]:
            print(f"check: CONFIG CHANGED: {key} "
                  f"(committed {old[key]!r} vs here {new[key]!r}); "
                  f"re-baseline with `frontend_load --quick --json`")
            rc = 1
    same_env = True
    for key in ("backend", "device_kind", "machine"):
        if key in old and old[key] != new[key]:
            print(f"check: note: {key} differs "
                  f"(committed {old[key]!r} vs here {new[key]!r})")
            same_env = False
    # the tentpole's headline number: the committed artifact must show
    # coalescing >= 2x at 16 clients, and the re-run must never measure
    # the merged path as slower than 16 solo dispatches (machine-relative
    # ratio, gated everywhere)
    committed = old.get("speedup_16", 0.0)
    ok = committed >= 2.0
    print(f"check: speedup_16 committed = {committed:.2f} (floor 2.00) -> "
          f"{'OK' if ok else 'BASELINE REGRESSION'}")
    if not ok:
        rc = 1
    # PR 10: moderate concurrency must be a win too — stacked
    # cross-tenant dispatch and the adaptive window bought speedup_4
    # above parity, and no re-baseline may give that back
    committed4 = old.get("speedup_4", 0.0)
    ok = committed4 > 1.0
    print(f"check: speedup_4 committed = {committed4:.2f} (floor 1.00, "
          f"strict) -> {'OK' if ok else 'BASELINE REGRESSION'}")
    if not ok:
        rc = 1
    print(f"check: speedup_4 here = {new['speedup_4']:.2f} "
          f"(report-only; stacked_solves={new['stacked_solves']}, "
          f"window_trace={len(new['window_trace'])} samples)")
    ok = new["speedup_16"] >= 1.0
    print(f"check: speedup_16 here = {new['speedup_16']:.2f} "
          f"(floor 1.00) -> {'OK' if ok else 'COALESCING REGRESSION'}")
    if not ok:
        rc = 1
    ratio = new["p99_p50_ratio_4"]
    ok = ratio <= 2.0
    print(f"check: p99_p50_ratio_4 = {ratio:.2f} (min over rounds, "
          f"ceiling 2.00) -> {'OK' if ok else 'TAIL REGRESSION'}")
    if not ok:
        rc = 1
    dv = new["deadline_violations"]
    ok = dv == 0
    print(f"check: deadline_violations = {dv} (min over rounds, must "
          f"be 0) -> {'OK' if ok else 'DEADLINE REGRESSION'}")
    if not ok:
        rc = 1
    if new["sheds"]:  # generous budgets: any shed is a routing bug
        print(f"check: sheds = {new['sheds']} (expected 0) -> "
              f"SHED REGRESSION")
        rc = 1
    ok = new["coalesced_calls"] > 0
    print(f"check: coalesced_calls = {new['coalesced_calls']} over "
          f"{new['coalesce_groups']} groups (solo "
          f"{new['solo_calls']}) -> {'OK' if ok else 'WINDOW DEAD'}")
    if not ok:
        rc = 1
    metric = "coalesced qps @16"
    old_q = old.get("results", {}).get("coalesced", {}).get("16", {})
    if "qps" in old_q:
        floor = old_q["qps"] * (1.0 - tolerance)
        got = new["results"]["coalesced"]["16"]["qps"]
        ok = got >= floor
        verdict = "OK" if ok else (
            "REGRESSION" if same_env
            else "BELOW FLOOR (env differs, not gated)"
        )
        print(f"check: {metric}: committed {old_q['qps']:.0f}, "
              f"now {got:.0f}, floor {floor:.0f} -> {verdict}")
        if not ok and same_env:
            rc = 1
    return rc


def main(quick: bool = False, emit_json: bool = False):
    r = _bench(quick)
    if emit_json:
        with open(_JSON_PATH, "w") as f:
            json.dump(r, f, indent=2)
    for lv in LEVELS:
        c = r["results"]["coalesced"][str(lv)]
        p = r["results"]["percall"][str(lv)]
        yield csv_line(
            f"frontend_load_{lv}c", 1e6 / c["qps"],
            f"qps={c['qps']:.0f} percall_qps={p['qps']:.0f} "
            f"speedup={r['speedup'][str(lv)]:.2f}x "
            f"p50={c['p50_s'] * 1e3:.2f}ms p99={c['p99_s'] * 1e3:.2f}ms")
    yield csv_line(
        "frontend_load_summary", 0.0,
        f"speedup4={r['speedup_4']:.2f}x "
        f"speedup16={r['speedup_16']:.2f}x "
        f"tail4={r['p99_p50_ratio_4']:.2f} "
        f"violations={r['deadline_violations']} "
        f"coalesced={r['coalesced_calls']}/{r['coalesce_groups']}groups "
        f"stacked={r['stacked_solves']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()
    if args.check:
        sys.exit(check(quick=True))
    for line in main(quick=args.quick, emit_json=args.json):
        print(line, flush=True)
