"""Shared benchmark substrate: paper-like synthetic datasets + timing.

The paper's testbeds (Table 2) are Wikipedia (n=5.9M, GloVe-25d, transversal
matroid over 100 LDA topics, metric cosine distance) and Songs (n=238k,
sparse bags-of-words, partition matroid over 16 genres). This container has
no network and one CPU core, so we reproduce the *structure* at reduced n
(documented per benchmark) with matched dimensionality/matroid shape:

  wikipedia_like(n): 25-d vectors with low intrinsic dimension, 100 topics,
                     gamma<=3 topics/page (transversal, rank 100)
  songs_like(n):     100-d sparse-ish vectors, 16 genres with skewed sizes,
                     per-genre caps proportional to frequency (partition,
                     rank 89-ish)
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.matroid import MatroidSpec


def wikipedia_like(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    h, gamma = 100, 3
    # low doubling dimension: points near a 4-d manifold in 25-d
    basis = rng.normal(size=(4, 25))
    topic_centers = rng.normal(size=(h, 4))
    topic_of = rng.integers(0, h, n)
    P = topic_centers[topic_of] @ basis + 0.6 * rng.normal(size=(n, 25))
    cats = np.full((n, gamma), -1, np.int32)
    cats[:, 0] = topic_of
    extra1 = rng.random(n) < 0.4
    cats[extra1, 1] = rng.integers(0, h, extra1.sum())
    extra2 = rng.random(n) < 0.1
    cats[extra2, 2] = rng.integers(0, h, extra2.sum())
    spec = MatroidSpec("transversal", num_categories=h, gamma=gamma)
    return P.astype(np.float32), cats, None, spec


def songs_like(n: int, seed: int = 0):
    rng = np.random.default_rng(seed + 1)
    h = 16
    sizes = rng.dirichlet(np.ones(h) * 0.5)
    genre = rng.choice(h, n, p=sizes)
    basis = rng.normal(size=(5, 100))
    centers = rng.normal(size=(h, 5)) * 2
    P = centers[genre] @ basis + 1.2 * rng.normal(size=(n, 100))
    counts = np.bincount(genre, minlength=h)
    caps = np.maximum(1, (counts / counts.sum() * 89)).astype(np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    return P.astype(np.float32), genre[:, None].astype(np.int32), caps, spec


def songs_multilabel(n: int, seed: int = 0):
    """Songs-like points with a *transversal* matroid: up to gamma=2 genre
    labels per song over h=16 genres (the serve_bench workload for the
    transversal-capable batched solver; Wikipedia's h=100 topic matroid has
    the same structure at a size this container's CPU can sweep)."""
    rng = np.random.default_rng(seed + 2)
    h, gamma = 16, 2
    sizes = rng.dirichlet(np.ones(h) * 0.5)
    genre = rng.choice(h, n, p=sizes)
    basis = rng.normal(size=(5, 100))
    centers = rng.normal(size=(h, 5)) * 2
    P = centers[genre] @ basis + 1.2 * rng.normal(size=(n, 100))
    cats = np.full((n, gamma), -1, np.int32)
    cats[:, 0] = genre
    extra = rng.random(n) < 0.35
    cats[extra, 1] = rng.integers(0, h, extra.sum())
    spec = MatroidSpec("transversal", num_categories=h, gamma=gamma)
    return P.astype(np.float32), cats, None, spec


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
