"""(1-eps) guarantee across all five Table-1 objectives: coreset-restricted
exhaustive optimum vs full-input exhaustive optimum on small instances —
the paper's §4.4 'first feasible algorithms' claim, validated exactly."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import make_host_matroid
from repro.core.coreset import seq_coreset_host
from repro.core.diversity import VARIANTS
from repro.core.exhaustive import exhaustive_best
from repro.core.geometry import dists
from repro.core.matroid import MatroidSpec

from .common import Timer, csv_line


def run(n=60, k=4, eps=0.5, seed=0):
    rng = np.random.default_rng(seed)
    h = 3
    # tightly clustered (low doubling dimension) so the radius-target GMM
    # stops with a coreset << n — the regime the paper targets
    centers = rng.normal(size=(6, 6)) * 3.0
    asg = rng.integers(0, 6, n)
    P = (centers[asg] + 0.01 * rng.normal(size=(n, 6))).astype(np.float32)
    cats = rng.integers(0, h, (n, 1)).astype(np.int32)
    caps = np.full(h, 2, np.int32)
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    matroid = make_host_matroid(spec, cats, caps, n, k)
    D = np.asarray(dists(jnp.asarray(P), jnp.asarray(P)))
    sel, info = seq_coreset_host(P, cats, spec, caps, k, eps=eps)
    rows = []
    for v in VARIANTS:
        with Timer() as t:
            _, opt, c1 = exhaustive_best(D, matroid, k, range(n), v)
            _, got, c2 = exhaustive_best(D, matroid, k, sel, v)
        assert c1 and c2
        rows.append(dict(variant=v, ratio=got / opt, time_s=t.s,
                         coreset=len(sel), eps=eps))
    return rows


def main(quick=False):
    return [
        csv_line(
            f"variant_{r['variant']}", r["time_s"] * 1e6,
            f"ratio={r['ratio']:.4f};guarantee={1-r['eps']:.2f};"
            f"coreset={r['coreset']}",
        )
        for r in run(n=40 if quick else 60)
    ]


if __name__ == "__main__":
    print("\n".join(main()))
