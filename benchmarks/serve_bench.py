"""Serving benchmark: ingest throughput (blocked + sharded, per placement),
cold-vs-warmed query latency, batched QPS for the online diversity service.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--json]
                                                    [--shards N]

``--json`` writes a ``BENCH_serve.json`` artifact (repo root) so the perf
trajectory is tracked across PRs; the artifact records the platform/device
and the block/shard configuration so trajectories are comparable across
machines. ``benchmarks.run --check`` reruns the quick configuration and
fails on >20% regressions of ``ingest_points_per_s`` / ``batched_qps`` /
``sharded_speedup`` against the committed artifact.

Ingest methodology: one long-lived service per configuration, all driven
through the same stream *interleaved* (both see the same host weather, so
their ratio is robust to scheduler noise), for ``WARM_ROUNDS`` full passes
(jit compiled, shard coresets saturated) plus measured continuation
rounds. Steady-state throughput is the best per-batch time of the measured
rounds — the only stable estimator of a single-digit-ms window on a noisy
shared host, and the honest serving number for a service at equilibrium
(the transient covers a vanishing fraction of an unbounded stream).
``sharded_speedup`` = sharded (auto placement) / unsharded steady-state
pps; per-placement numbers are recorded in ``ingest_pps_by_placement``.
``num_shards`` defaults to ``min(8, max(2, devices, cpus))`` — derived,
not hardcoded, so artifacts are comparable across machines — and
``--check`` reruns with the *committed* shard count.

Query latency: ``first_query_cold_s`` is the first query ever issued in
the process (pays trace+compile+pdist — the number ``warmup()`` exists to
absorb); ``first_query_warmed_s`` is the first query of a service that
called ``warmup()`` first; ``warmup_s`` is that warmup's wall time (in a
cold process it absorbs the full compile; here later warmups reuse the
process jit cache, which is exactly the serving story). "Cold" solve is
the full offline driver (``solve_dmmc``: rebuild coreset + pdist + solve).

Per solver-registry cell the bench records batched QPS
(``batched_qps_by_engine``) and the engine mix of representative auto
batches (``engine_mix``); ``--check`` additionally fails when a dispatch
regression routes transversal or star/tree batches back to 100% host.

Mixed workload (``mixed_workload``): the epoch-snapshot serving runtime
under contention — a background ``submit`` worker continuously ingesting
while the main thread queries published epochs (idle vs contended p50/p95
latency, ingest pps sustained during the query window) plus 4-tenant
cache fan-out from the single stream (per-tenant cached QPS vs the
single-tenant baseline). ``--check`` gates the two machine-relative
ratios everywhere: ``contention_p95_ratio <= 2.0`` and
``multi_tenant_min_ratio >= 0.8``.

Fault tolerance (``fault_tolerance``): crash-recovery wall time and WAL
replay throughput (durable stream killed without close, restored via
checkpoint + WAL-tail replay, parity asserted against the live
fingerprint), a seeded chaos ingest (worker crashes + transient errors +
a poisoned batch, stream must keep flowing), and a 4x-saturation
deadline burst (exact queries offered at 4x their measured capacity with
``deadline_s`` — every request must complete, degrade, or shed inside
the budget; misses are gated via the min-over-rounds methodology).
``--check`` gates ``replay_parity``, ``recovery_s <= 60``,
``replay_pps > 0``, ``stream_continued``, ``deadline_violations == 0``
and ``goodput >= 0.5``; the post-crash replay checkpoint + restore
report land in ``BENCH_fault_recovery/`` (CI uploads it).

Replication (``replication``): a ``ReplicaSet`` (primary + WAL-shipped
hot standby) driven through the stream with a seeded primary kill
planted mid-ingest — the write path promotes the standby inline
(replaying the acked WAL tail) and the run records ``failover_s``,
``failover_parity`` (post-failover fingerprint bit-identical to a
single-runtime replay — zero acked batches lost), per-batch replication
lag (the ``serve.replication.lag_batches`` histogram) and an
``IntegrityAuditor`` pass over the surviving set. ``--check`` gates
``failover_parity``, ``failover_s <= 5``, a populated lag histogram and
``audit_violations == 0``; the failover report lands in
``BENCH_failover/`` (CI uploads it).

Observability (``repro.obs``): every run embeds the full metrics snapshot
in the artifact (``metrics``), the recompile census keyed by compile
region (``recompiles_by_key``), the warmed-window recompile count
(``steady_state_recompiles`` — gated ``== 0`` by ``--check``: a measured
round that compiles anything is not steady state), the enabled-vs-disabled
registry cost (``obs_overhead`` — interleaved floors, target <= 3%), and
drops a Chrome ``trace_event`` artifact (``BENCH_serve.trace.json``, open
at chrome://tracing or ui.perfetto.dev) whose spans cover the full
submit -> worker_ingest -> publish -> query -> solve path.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform as _platform
import sys
import time

import numpy as np

from .common import Timer, csv_line, songs_like, songs_multilabel

BLOCK_SIZE = 128
MAX_SHARDS = 8
INGEST_DUTY = 0.1  # mixed-workload stream arrival rate vs ingest capacity
WARM_ROUNDS = 2
MEASURE_ROUNDS = 3

_JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json",
)


def default_num_shards() -> int:
    """min(8, max(2, jax devices, host cpus)): enough shards to exercise
    the sharded drives everywhere, never more than the historical 8, and
    scaled to the machine instead of hardcoded (single-core runners got a
    meaningless 8-shard config before)."""
    import jax

    avail = max(jax.device_count(), os.cpu_count() or 1)
    return max(2, min(MAX_SHARDS, avail))


def _steady_ingest(
    factories: dict, P, cats, n: int, batch: int, steady_watch=None
) -> tuple[dict, dict]:
    """Interleaved steady-state ingest floors: returns
    ``({config: points/s}, {config: the service that produced it})``.

    Every service consumes the same stream; each round drives one full
    pass through *every* service before the next round starts, so all
    configs face the same host conditions and the recorded ratios are
    meaningful. The first WARM_ROUNDS passes compile and saturate (their
    times are discarded); the floor is min per-batch time afterwards.

    ``steady_watch`` (an ``obs.RecompileWatch``) is reset at the
    warm/measure boundary, so after return it holds exactly the XLA
    compiles triggered *inside* the measured rounds — the
    ``steady_state_recompiles == 0`` gate: a measured round that compiles
    anything is not measuring steady state (and the watch's by-key census
    names the bucketed shape that failed to hold).
    """
    svcs = {name: mk() for name, mk in factories.items()}
    best: dict = {name: [] for name in factories}
    for r in range(WARM_ROUNDS + MEASURE_ROUNDS):
        if r == WARM_ROUNDS and steady_watch is not None:
            steady_watch.reset()
        for off in range(0, n, batch):
            m = min(batch, n - off)
            # batch-granular interleave: every config ingests the same
            # batch back-to-back, so a host-noise burst hits all configs
            # rather than biasing whichever one it landed on
            for name, svc in svcs.items():
                with Timer() as t:
                    svc.ingest(P[off:off + m], cats[off:off + m])
                if r >= WARM_ROUNDS:
                    best[name].append(t.s / m)
    return (
        {name: 1.0 / float(np.min(v)) for name, v in best.items()},
        svcs,
    )


def _mixed_workload(P, cats, caps, spec, k: int, tau: int, quick: bool,
                    ingest_pps: float) -> dict:
    """Concurrent ingest + query section: one ``StreamRuntime`` ingesting
    asynchronously (background ``submit`` worker, epoch publication) while
    the main thread queries a ``QueryFrontend`` over it, plus >= 4-tenant
    cache fan-out from the single stream.

    The feeder offers the stream at ``INGEST_DUTY`` of the measured
    steady-state ingest throughput (recorded as ``ingest_target_pps``) —
    the serving scenario is a query service *with a live arrival rate*,
    not an offline bulk load. At 100% duty a host with two cores measures
    pure compute saturation (every XLA call wants every core), which says
    nothing about the architecture; at a real arrival rate the gate pins
    what the epoch-snapshot split is for: queries keep answering from
    published epochs while the scan runs, instead of blocking on device
    state behind it.

    Records p50/p95 warm query latency idle vs under active ingestion
    (``contention_p95_ratio`` — gated <= 2.0 by ``--check``: serving must
    not stall behind the scan), the ingest pps sustained *while* queries
    were answered, and per-tenant cached QPS (``multi_tenant_min_ratio``
    — gated >= 0.8: another tenant's entry must cost what the first one's
    does). Both gates are machine-relative ratios, enforced everywhere.
    """
    import threading

    from repro.core.matroid import MatroidSpec
    from repro.serve.diversity import (
        DiversityQuery,
        QueryFrontend,
        StreamRuntime,
    )

    n = P.shape[0]
    batch = 256  # smaller than bulk ingest: bounds per-call HOL blocking
    target_pps = INGEST_DUTY * ingest_pps
    rt = StreamRuntime(spec, k, tau=tau, caps=caps, block_size=BLOCK_SIZE)
    fe = QueryFrontend(rt)
    rt.ingest(P, cats)
    q = DiversityQuery(k=k)
    fe.query(q)  # build the default entry + compile the solver shape
    # pre-compile the contended ingest shape and the worker/publish path
    # so the measurement window sees steady state, not first-trace
    rt.ingest(P[:batch], cats[:batch])
    rt.submit(P[:batch], cats[:batch])
    rt.flush()

    def lat_run(m: int) -> np.ndarray:
        ls = np.empty(m)
        for i in range(m):
            t0 = time.perf_counter()
            fe.query(q)
            ls[i] = time.perf_counter() - t0
        return ls

    reps = 100 if quick else 250
    rounds = 4
    lat_run(reps // 4)  # saturate before measuring

    def feeder(stop):
        # re-stream the catalog at target_pps until the window closes
        interval = batch / target_pps
        off = 0
        next_t = time.perf_counter()
        while not stop.is_set():
            m = min(batch, n - off)
            try:
                rt.submit(P[off:off + m], cats[off:off + m])
            except RuntimeError:
                return
            off = (off + m) % n
            next_t += interval
            dt = next_t - time.perf_counter()
            if dt > 0:
                time.sleep(dt)
            else:  # fell behind (backpressure): don't burst to catch up
                next_t = time.perf_counter()

    # interleaved idle/contended rounds (the same methodology as the
    # ingest floors: both phases of a round share the host weather, and
    # the gated ratio is the min over rounds — one scheduler burst cannot
    # fail the gate, a real serving regression shifts every round)
    idle_all, cont_all, ratios, ingested, window = [], [], [], 0, 0.0
    for _ in range(rounds):
        idle = lat_run(reps)
        stop = threading.Event()
        th = threading.Thread(target=feeder, args=(stop,), daemon=True)
        offered0 = rt.n_offered
        th.start()
        t0 = time.perf_counter()
        contended = lat_run(reps)
        window += time.perf_counter() - t0
        ingested += rt.n_offered - offered0  # what the worker really took
        stop.set()
        th.join()
        rt.flush()
        idle_all.append(idle)
        cont_all.append(contended)
        ratios.append(
            float(np.percentile(contended, 95) / np.percentile(idle, 95))
        )
    idle = np.concatenate(idle_all)
    contended = np.concatenate(cont_all)

    # ---- multi-tenant fan-out: 4 keys, one stream, per-tenant QPS ----
    uspec = MatroidSpec("uniform")
    fe.register_tenant("cosine", metric="cosine")
    fe.register_tenant("uniform", spec=uspec)
    fe.register_tenant("uniform-cos", spec=uspec, metric="cosine")
    tenant_names = ["default", "cosine", "uniform", "uniform-cos"]
    qs = [DiversityQuery(k=2 + i % 7) for i in range(32)]

    for name in tenant_names:
        fe.query_batch(qs, tenant=name)  # build entries + warm the shape
    best = {name: np.inf for name in tenant_names}
    for _ in range(6):
        # tenant-interleaved rounds: every tenant measured back-to-back
        # under the same host weather, so the gated ratio (min tenant /
        # the default tenant, both best-of-rounds) compares cache fan-out
        # cost, not scheduler noise
        for name in tenant_names:
            with Timer() as t:
                got = fe.query_batch(qs, tenant=name)
            best[name] = min(best[name], t.s / len(got))
    per_tenant = {name: 1.0 / b for name, b in best.items()}
    single_tenant_qps = per_tenant["default"]
    min_ratio = min(per_tenant.values()) / single_tenant_qps
    stats = fe.stats()
    rt.close()
    idle_p95 = float(np.percentile(idle, 95))
    cont_p95 = float(np.percentile(contended, 95))
    return dict(
        idle_p50_s=float(np.percentile(idle, 50)),
        idle_p95_s=idle_p95,
        contended_p50_s=float(np.percentile(contended, 50)),
        contended_p95_s=cont_p95,
        contention_p95_ratio=float(np.min(ratios)),
        contention_p95_ratios=[float(x) for x in ratios],
        ingest_duty=float(INGEST_DUTY),
        ingest_target_pps=float(target_pps),
        contended_ingest_pps=float(ingested / window),
        query_reps=int(reps),
        tenant_count=len(tenant_names),
        single_tenant_qps=float(single_tenant_qps),
        tenant_qps={k_: float(v) for k_, v in per_tenant.items()},
        multi_tenant_min_ratio=float(min_ratio),
        epochs_published=int(stats["epochs_published"]),
        snapshot_materializations=int(stats["snapshot_materializations"]),
        cache=stats["cache"],
    )


def _fault_tolerance(P, cats, caps, spec, k: int, tau: int,
                     quick: bool) -> dict:
    """Fault-tolerance section: recovery, chaos ingest, deadline burst.

    *Recovery*: a durable stream (WAL + cadence checkpoints) is killed
    without ``close()`` and rebuilt with ``StreamRuntime.restore`` —
    recorded are the recovery wall time, the WAL-tail replay throughput,
    and ``replay_parity`` (restored fingerprint == the dead runtime's).
    The newest checkpoint plus the restore report are copied to
    ``BENCH_fault_recovery/`` so CI preserves the post-crash state.

    *Chaos*: a seeded ``FaultPlan`` injects worker crashes (supervisor
    restarts), transient ingest errors (retried away) and one
    twice-failing batch (quarantined); ``stream_continued`` asserts the
    stream kept flowing and lost exactly the poisoned points.

    *Deadline*: exact star/tree queries offered with a per-batch
    ``deadline_s`` of 1/4 their measured exact wall — a 4x-saturation
    burst. The admission layer must degrade (or shed) every batch into
    the budget; ``deadline_violations`` is the min over rounds of
    per-round deadline misses (one scheduler burst cannot fail the gate,
    unbounded queuing misses in every round) and ``goodput`` is the
    answered (non-shed) fraction.
    """
    import shutil
    import tempfile

    from repro import obs
    from repro.serve.diversity import (
        DiversityQuery,
        DurabilityConfig,
        FaultPlan,
        FaultPolicy,
        FaultRule,
        QueryFrontend,
        StreamRuntime,
    )

    n = P.shape[0]
    reg = obs.default_registry()

    # ---- recovery: kill a durable stream, restore, measure ----------
    tmp = tempfile.mkdtemp(prefix="bench-fault-")
    batch = 256
    dur = DurabilityConfig(dir=tmp, checkpoint_every=4, keep=3)
    rt = StreamRuntime(spec, k, tau=tau, caps=caps,
                       block_size=BLOCK_SIZE, durability=dur)
    for off in range(0, n, batch):
        rt.submit(P[off:off + batch], cats[off:off + batch])
    rt.flush()
    live_fp = rt.latest().fingerprint
    # the "kill": no close(), no parting checkpoint — restore must
    # replay the WAL tail beyond the newest cadence checkpoint
    with Timer() as t_rec:
        back = StreamRuntime.restore(tmp)
    rep = back.restore_report
    parity = back.latest().fingerprint == live_fp
    replay_pps = (
        rep["replayed_points"] / rep["restore_s"]
        if rep["restore_s"] > 0 else 0.0
    )
    # preserve the post-crash replay state as a CI artifact
    art_dir = os.path.join(os.path.dirname(_JSON_PATH),
                           "BENCH_fault_recovery")
    shutil.rmtree(art_dir, ignore_errors=True)
    os.makedirs(art_dir, exist_ok=True)
    back.checkpoint(force=True)
    from repro.serve.diversity import latest_checkpoint
    newest = latest_checkpoint(tmp)
    if newest:
        shutil.copy2(newest, art_dir)
    with open(os.path.join(art_dir, "recovery.json"), "w") as f:
        json.dump(dict(rep, replay_parity=bool(parity),
                       recovery_wall_s=float(t_rec.s)), f, indent=2,
                  default=str)
    back.close()
    rt.close()
    shutil.rmtree(tmp, ignore_errors=True)
    recovery = dict(
        n_ingested=int(n),
        recovery_s=float(t_rec.s),
        replayed_batches=int(rep["replayed_batches"]),
        replayed_points=int(rep["replayed_points"]),
        replay_pps=float(replay_pps),
        replay_parity=bool(parity),
        artifact="BENCH_fault_recovery/",
    )

    # ---- chaos ingest: crashes + retries + one poisoned batch -------
    cbatch = 128
    plan = FaultPlan(7, [
        FaultRule(site="worker.loop", kind="crash", after=2, every=3,
                  times=2),
        FaultRule(site="worker.ingest", kind="error", after=5, every=4,
                  times=4),
        # two consecutive fires exhaust max_retries=1: one poisoned batch
        FaultRule(site="worker.ingest", kind="error", after=24, times=2),
    ])
    rt = StreamRuntime(
        spec, k, tau=tau, caps=caps, block_size=BLOCK_SIZE,
        faults=plan,
        fault_policy=FaultPolicy(max_retries=1, backoff_s=0.01,
                                 on_failure="quarantine",
                                 max_worker_restarts=5),
    )
    c0 = reg.counter("serve.worker.crashes").value
    r0 = reg.counter("serve.worker.restarts").value
    t0 = reg.counter("serve.worker.retries").value
    for off in range(0, n, cbatch):
        rt.submit(P[off:off + cbatch], cats[off:off + cbatch])
    rt.flush()  # quarantine keeps the stream alive: must not raise
    lost = sum(int(b.points.shape[0]) for b in rt.poison)
    chaos = dict(
        crashes=int(reg.counter("serve.worker.crashes").value - c0),
        restarts=int(reg.counter("serve.worker.restarts").value - r0),
        retries=int(reg.counter("serve.worker.retries").value - t0),
        poisoned=len(rt.poison),
        poisoned_points=int(lost),
        stream_continued=bool(rt.n_offered == n - lost and lost > 0),
    )
    rt.close()

    # ---- deadline burst: 4x saturation, degrade-or-shed inside budget
    rt = StreamRuntime(spec, k, tau=tau, caps=caps, block_size=BLOCK_SIZE)
    fe = QueryFrontend(rt)
    rt.ingest(P, cats)
    # a dedicated tenant: its latency histograms (the admission
    # predictor) train on THIS section's warm calls only — the earlier
    # sections' compile-inclusive observations would skew every engine's
    # p95 toward seconds and turn the whole burst into sheds
    tenant = "burst"
    fe.register_tenant(tenant)
    qs_exact = [
        DiversityQuery(k=3, variant="tree" if i % 2 else "star")
        for i in range(6)
    ]
    qs_greedy = [
        dataclasses.replace(q, engine_hint="jit_greedy") for q in qs_exact
    ]
    fe.query_batch(qs_exact, tenant=tenant)  # warm + feed the predictor
    fe.query_batch(qs_greedy, tenant=tenant)
    walls_e, walls_g = [], []
    for _ in range(3):
        with Timer() as te:
            fe.query_batch(qs_exact, tenant=tenant)
        walls_e.append(te.s)
    # enough warm greedy observations that the predictor's p95 rank
    # clears the one compile-inclusive first call (rank ceil(.95n) < n
    # needs n >= 20) — the burst must see the steady-state greedy cost
    for _ in range(20):
        with Timer() as tg:
            fe.query_batch(qs_greedy, tenant=tenant)
        walls_g.append(tg.s)
    L_exact, L_greedy = float(np.min(walls_e)), float(np.min(walls_g))
    # 4x saturation: the budget is a quarter of what exact serving needs
    # (floored so the degraded engine genuinely fits inside it)
    deadline_s = max(L_exact / 4.0, 2.5 * L_greedy, 0.02)
    rounds, per_round = 4, 6
    miss_c = reg.counter("serve.query.deadline_miss", tenant=tenant)
    # materialize the outcome counters up front so the embedded metrics
    # snapshot always carries all three series, zeros included
    reg.counter("serve.query.shed", tenant=tenant)
    reg.counter("serve.query.degraded", tenant=tenant)
    outcomes = {"ok": 0, "degraded": 0, "shed": 0}
    misses = []
    for _ in range(rounds):
        m0 = miss_c.value
        for _ in range(per_round):
            for r in fe.query_batch(qs_exact, tenant=tenant,
                                    deadline_s=deadline_s):
                key = ("shed" if r.shed
                       else "degraded" if r.degraded else "ok")
                outcomes[key] += 1
        misses.append(miss_c.value - m0)
    rt.close()
    total = sum(outcomes.values())
    deadline = dict(
        deadline_s=float(deadline_s),
        exact_batch_s=L_exact,
        greedy_batch_s=L_greedy,
        saturation=4.0,
        queries=int(total),
        ok_fraction=outcomes["ok"] / total,
        degraded_fraction=outcomes["degraded"] / total,
        shed_fraction=outcomes["shed"] / total,
        goodput=(outcomes["ok"] + outcomes["degraded"]) / total,
        deadline_violations=int(min(misses)),
        deadline_misses_by_round=[int(m) for m in misses],
    )
    return dict(recovery=recovery, chaos=chaos, deadline=deadline)


def _replication(P, cats, caps, spec, k: int, tau: int,
                 quick: bool) -> dict:
    """Replication section: WAL-shipped hot standby + primary-kill
    failover + online integrity audit.

    A ``ReplicaSet`` (primary + 1 standby, each with its own WAL) is
    driven through the full stream with a seeded worker crash planted
    mid-ingest on the primary. The write path detects the dead primary,
    promotes the standby (replaying the acked WAL tail first) and
    retries inline — recorded are the failover wall time
    (``failover_s``), acked-batch accounting, and ``failover_parity``:
    the post-failover fingerprint must be bit-identical to a
    single-runtime replay of the same stream (zero acked batches lost,
    the §3 composability argument made operational). Per-batch
    ``observe_lag`` calls populate the
    ``serve.replication.lag_batches`` histogram. An
    ``IntegrityAuditor`` pass over the surviving set closes the run:
    coverage radius vs tau, matroid independence of every delegate
    set, cached pdist spot-checks — ``audit_violations`` must be 0.
    The failover report lands in ``BENCH_failover/`` (CI uploads it).
    """
    import shutil
    import tempfile

    from repro import obs
    from repro.serve.diversity import (
        DiversityQuery,
        FaultPlan,
        FaultPolicy,
        FaultRule,
        IntegrityAuditor,
        ReplicaSet,
        StreamRuntime,
    )

    n = P.shape[0]
    reg = obs.default_registry()
    batch = 256
    n_batches = (n + batch - 1) // batch
    kill_after = max(2, n_batches // 2)
    tmp = tempfile.mkdtemp(prefix="bench-repl-")
    plan = FaultPlan(11, [
        FaultRule(site="worker.loop", kind="crash", after=kill_after,
                  times=1),
    ])
    rs = ReplicaSet.create(
        spec, k, dir=os.path.join(tmp, "replicas"), caps=caps, tau=tau,
        block_size=BLOCK_SIZE, registry=reg, faults=plan,
        fault_policy=FaultPolicy(max_worker_restarts=0),
    )
    lag_obs, max_lag = 0, 0
    for off in range(0, n, batch):
        rs.submit(P[off:off + batch], cats[off:off + batch])
        lags = rs.observe_lag()
        lag_obs += len(lags)
        if lags:
            max_lag = max(max_lag, max(lags.values()))
    rs.flush()
    st = rs.stats()
    lf = rs.last_failover or {}
    # bit-identical parity against a single runtime folding the same
    # stream: the promoted standby replayed WAL records, never points
    ref = StreamRuntime(spec, k, tau=tau, caps=caps,
                        block_size=BLOCK_SIZE)
    for off in range(0, n, batch):
        ref.ingest(P[off:off + batch], cats[off:off + batch])
    ref_fp = ref.refresh(force=True).fingerprint
    ref.close()
    prt = rs.primary.runtime
    parity = bool(prt.n_offered == n and prt.fingerprint == ref_fp)
    # the promoted stack keeps serving: one query through the set
    res = rs.query(DiversityQuery(k=k))
    # online integrity audit over the surviving replicas
    auditor = IntegrityAuditor(rs, registry=reg)
    reports = auditor.audit_once()
    audit = dict(
        checks=int(auditor.total_checks),
        violations=int(auditor.total_violations),
        reports=[
            dict(replica=r.replica, checks=int(r.checks),
                 violations=list(r.violations))
            for r in reports
        ],
    )
    # preserve the failover report as a CI artifact
    art_dir = os.path.join(os.path.dirname(_JSON_PATH), "BENCH_failover")
    shutil.rmtree(art_dir, ignore_errors=True)
    os.makedirs(art_dir, exist_ok=True)
    with open(os.path.join(art_dir, "failover.json"), "w") as f:
        json.dump(dict(
            last_failover=lf, stats=st, failover_parity=parity,
            audit=audit, query_diversity=float(res.diversity),
        ), f, indent=2, default=str)
    rs.close()
    shutil.rmtree(tmp, ignore_errors=True)
    return dict(
        n_ingested=int(n),
        n_standbys=1,
        failovers=int(st["failovers"]),
        failover_s=float(lf.get("duration_s", -1.0)),
        promoted=lf.get("promoted"),
        retired=lf.get("retired"),
        acked_seq=int(st["acked_seq"]),
        acked_batches=int(st["acked_batches"]),
        failover_parity=parity,
        lag_observations=int(lag_obs),
        max_lag_batches=int(max_lag),
        reseeds=int(st["reseeds"]),
        audit_checks=audit["checks"],
        audit_violations=audit["violations"],
        artifact="BENCH_failover/",
    )


def _bench(quick: bool, num_shards: int | None = None) -> dict:
    import jax

    from repro import obs
    from repro.core import solve_dmmc
    from repro.serve.diversity import DiversityQuery, DiversityService

    # observability: start every bench run from zeroed metrics and an
    # empty trace buffer so the embedded snapshot/trace describe THIS run
    obs.reset()
    census = obs.recompile_watch()  # never reset: the full-run census
    steady = obs.RecompileWatch()  # windowed: warmed measurement gates
    steady_total = 0  # compiles observed inside warmed measured windows

    n = 4000 if quick else 20000
    k, tau, batch = 8, 32, 512
    P, cats, caps, spec = songs_like(n)
    if num_shards is None:
        num_shards = default_num_shards()
    S = int(num_shards)

    def mk(**kw):
        return lambda: DiversityService(
            spec, k, tau=tau, caps=caps, block_size=BLOCK_SIZE, **kw
        )

    factories = {
        "unsharded": mk(),
        "sharded_auto": mk(num_shards=S),
        "sharded_vmap": mk(num_shards=S, placement="vmap"),
        "sharded_shard_map": mk(num_shards=S, placement="shard_map"),
        "sharded_pipeline": mk(num_shards=S, placement="pipeline"),
    }
    pps, svcs = _steady_ingest(factories, P, cats, n, batch,
                               steady_watch=steady)
    steady_total += steady.total()
    svc = svcs["unsharded"]
    svc_sh = svcs["sharded_auto"]
    ingest_pps = pps["unsharded"]
    sharded_pps = pps["sharded_auto"]
    sharded_speedup = sharded_pps / ingest_pps

    # true process-cold first query: pays the full trace+compile+pdist —
    # measured before ANYTHING else in the process solves (the offline
    # driver below shares solver/pdist jits and would partially warm it)
    with Timer() as t_first:
        res = svc.query(DiversityQuery(k=k))
    # cold: offline driver from raw points (coreset + pdist + solve)
    with Timer() as t_cold:
        sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                         setting="streaming")
    # warmup absorbs that cost: a fresh service over the same stream calls
    # warmup() before its first query (in a cold process the warmup wall
    # equals the compile it absorbs; in this process it reuses the jit
    # cache — exactly what a pre-warmed serving fleet sees)
    svc_w = factories["unsharded"]()
    svc_w.ingest(P, cats)
    with Timer() as t_wup:
        svc_w.warmup(ks=(k,), query_batch_sizes=(1, 32))
    with Timer() as t_firstw:
        svc_w.query(DiversityQuery(k=k))
    sharded_res = svc_sh.query(DiversityQuery(k=k))

    # warm single-query latency on the cached matrix (median of reps)
    reps = 9 if quick else 20
    steady.reset()  # warm window: the shape/matrix are already compiled
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = svc.query(DiversityQuery(k=k))
        lat.append(time.perf_counter() - t0)
    steady_total += steady.total()
    warm_s = float(np.median(lat))
    assert res.from_cache and svc.cache.stats.builds == 1

    # batched heterogeneous queries (32) against one cache entry
    qs = [
        DiversityQuery(
            k=2 + i % 7,
            caps=None if i % 2 else tuple(np.maximum(1, caps // 2).tolist()),
            allowed_cats=None if i % 3 else frozenset(range(8)),
        )
        for i in range(32)
    ]
    svc.query_batch(qs)  # compile the vmapped solver for this shape
    steady.reset()
    b_lat = []
    for _ in range(reps):
        with Timer() as t_b:
            out = svc.query_batch(qs)
        b_lat.append(t_b.s)
    steady_total += steady.total()
    assert svc.cache.stats.builds == 1, "batched path rebuilt the matrix"
    qps = len(out) / float(np.min(b_lat))

    # ---- per-engine batched QPS + eligibility mix (solver registry) ----
    def _batch_qps(svc_, qs_, engine_="auto", reps_=3):
        nonlocal steady_total
        svc_.query_batch(qs_, engine=engine_)  # compile/warm this shape
        steady.reset()  # the warm call above absorbed any compile
        lats = []
        for _ in range(reps_):
            with Timer() as t_:
                got = svc_.query_batch(qs_, engine=engine_)
            lats.append(t_.s)
        steady_total += steady.total()
        return len(got) / float(np.min(lats)), got

    def _mix(results) -> dict:
        counts: dict[str, int] = {}
        for r_ in results:
            counts[r_.engine] = counts.get(r_.engine, 0) + 1
        return {e: c / len(results) for e, c in sorted(counts.items())}

    # sum under partition: the historical fast cell
    qs_sum = [DiversityQuery(k=2 + i % 7) for i in range(32)]
    qps_part_jit, _ = _batch_qps(svc, qs_sum, "jit_sum", reps)
    qps_part_host, _ = _batch_qps(svc, qs_sum, "host")
    # star/tree under partition: exact host vs opt-in vmapped greedy
    qs_st = [
        DiversityQuery(k=3, variant="tree" if i % 2 else "star")
        for i in range(8)
    ]
    qs_st_hint = [
        dataclasses.replace(q, engine_hint="jit_greedy") for q in qs_st
    ]
    qps_st_greedy, out_st = _batch_qps(svc, qs_st_hint, "auto", reps)
    qps_st_host, _ = _batch_qps(svc, qs_st, "host")
    # sum under transversal: the new jit cell (was 100% host before the
    # solver-engine refactor)
    n_tv = max(1000, n // 4)
    Ptv, cats_tv, _, spec_tv = songs_multilabel(n_tv)
    svc_tv = DiversityService(spec_tv, k, tau=tau, block_size=BLOCK_SIZE)
    svc_tv.ingest(Ptv, cats_tv)
    qs_tv = [DiversityQuery(k=2 + i % 4) for i in range(32)]
    qps_tv_jit, out_tv = _batch_qps(svc_tv, qs_tv, "auto", reps)
    qps_tv_host, _ = _batch_qps(svc_tv, qs_tv, "host")
    res_tv = svc_tv.query(DiversityQuery(k=k))

    batched_qps_by_engine = dict(
        partition_sum_jit_sum=float(qps_part_jit),
        partition_sum_host=float(qps_part_host),
        partition_startree_jit_greedy=float(qps_st_greedy),
        partition_startree_host=float(qps_st_host),
        transversal_sum_auto=float(qps_tv_jit),
        transversal_sum_host=float(qps_tv_host),
    )
    # a heterogeneous auto batch: the registry partitions it per query
    out_mixed = svc.query_batch(qs_sum[:24] + qs_st)
    engine_mix = dict(
        partition_auto=_mix(out_mixed),
        transversal_auto=_mix(out_tv),
        startree_hint=_mix(out_st),
    )

    # ---- obs overhead A/B: enabled vs disabled, interleaved floors ----
    # same methodology as every other ratio here: alternate the registry
    # switch per rep so both arms share the host weather, gate on floors.
    # The service is saturated (5 full stream passes), so re-ingesting a
    # seen batch is the steady-state no-op and the cache entry stays warm.
    ob_reps = 40 if quick else 60
    ing_ab = {True: [], False: []}
    qry_ab = {True: [], False: []}
    arm_order = (True, False)
    for target, ab in ((svc.ingest, ing_ab), (None, qry_ab)):
        for _ in range(ob_reps):
            arm_order = arm_order[::-1]  # alternate: no ordering bias
            for enabled in arm_order:
                obs.set_enabled(enabled)
                with Timer() as t_ab:
                    if target is not None:
                        target(P[:batch], cats[:batch])
                    else:
                        svc.query_batch(qs)
                ab[enabled].append(t_ab.s)
    obs.set_enabled(True)
    obs_overhead = dict(
        # (enabled floor / disabled floor) - 1: the fraction of warmed
        # ingest / batched-query wall the metrics+span layer costs
        ingest_overhead=float(
            np.min(ing_ab[True]) / np.min(ing_ab[False]) - 1.0
        ),
        batched_qps_overhead=float(
            np.min(qry_ab[True]) / np.min(qry_ab[False]) - 1.0
        ),
        reps=int(ob_reps),
    )

    # fault tolerance: recovery, chaos ingest, deadline burst (before the
    # mixed workload so the trace ring still ends on the full span story)
    fault = _fault_tolerance(P, cats, caps, spec, k, tau, quick)

    # replication: hot standby, primary-kill failover, integrity audit
    repl = _replication(P, cats, caps, spec, k, tau, quick)

    # concurrent ingest+query + multi-tenant fan-out (its own runtime so
    # the contention window doesn't perturb the services measured above)
    mixed = _mixed_workload(P, cats, caps, spec, k, tau, quick,
                            ingest_pps)

    # drop the Chrome trace artifact LAST: the mixed-workload section is
    # the one that produces every span kind (submit -> worker_ingest ->
    # publish on the ingest side, query_batch -> ... -> solve ->
    # device_sync on the read side), and the ring buffer keeps the newest
    # spans under overload
    trace_path = _JSON_PATH.replace(".json", ".trace.json")
    obs.dump_trace(trace_path)
    steady.close()

    speedup = t_cold.s / warm_s
    dev = jax.devices()[0]
    return dict(
        n=n, k=k, tau=tau,
        coreset_size=int(res.coreset_size),
        ingest_points_per_s=float(ingest_pps),
        ingest_points_per_s_sharded=float(sharded_pps),
        sharded_speedup=float(sharded_speedup),
        # the vmap drive's ratio, gated separately: on CPU the auto
        # placement (pipeline) shares the unsharded executable, so its
        # ratio alone would never catch a regression of the branchless
        # vmapped scan itself (the 0.22x failure mode this PR fixed)
        sharded_speedup_vmap=float(pps["sharded_vmap"] / ingest_pps),
        sharded_placement=svc_sh.placement,
        # every placement measured by its own dedicated service — the auto
        # service's number lives in ingest_points_per_s_sharded, never
        # overwriting a placement's entry
        ingest_pps_by_placement={
            "vmap": float(pps["sharded_vmap"]),
            "shard_map": float(pps["sharded_shard_map"]),
            "pipeline": float(pps["sharded_pipeline"]),
        },
        cold_solve_s=float(t_cold.s),
        first_query_cold_s=float(t_first.s),
        warmup_s=float(t_wup.s),
        first_query_warmed_s=float(t_firstw.s),
        warm_query_s=warm_s,
        warm_speedup_vs_cold=float(speedup),
        batched_qps=float(qps),
        batch_size=len(out),
        batched_qps_by_engine=batched_qps_by_engine,
        engine_mix=engine_mix,
        mixed_workload=mixed,
        fault_tolerance=fault,
        replication=repl,
        transversal_n=int(n_tv),
        transversal_coreset_size=int(res_tv.coreset_size),
        offline_diversity=float(sol.diversity),
        warm_diversity=float(res.diversity),
        sharded_diversity=float(sharded_res.diversity),
        sharded_coreset_size=int(sharded_res.coreset_size),
        pdist_builds=int(svc.cache.stats.builds),
        cache_hits=int(svc.cache.stats.hits),
        # observability artifacts: the full metrics snapshot of this run,
        # the recompile census keyed by compile region (bucketed shape),
        # and the warmed-window recompile count gated == 0 by --check
        metrics=obs.metrics_snapshot(),
        recompiles_by_key=census.by_key(),
        steady_state_recompiles=int(steady_total),
        obs_overhead=obs_overhead,
        trace_path=os.path.basename(trace_path),
        ingest_batch=batch,
        block_size=BLOCK_SIZE,
        num_shards=S,
        num_shards_derived=int(default_num_shards()),
        device_count=int(jax.device_count()),
        backend=str(jax.default_backend()),
        device_kind=str(getattr(dev, "device_kind", dev.platform)),
        machine=f"{_platform.system()}-{_platform.machine()}",
        host=_platform.node(),  # distinguishes physical machines whose
                                # backend/device_kind/arch all read the same
    )


def check(tolerance: float = 0.2, quick: bool = True) -> int:
    """Rerun the quick bench and compare against the committed artifact.

    Returns a process exit code: 1 on failure. Gates:

    * config drift (n/k/tau, batch/block constants) always fails, forcing
      a re-baseline; ``num_shards`` is re-run at the *committed* value so
      shard-count-derived machines stay comparable;
    * ``ingest_points_per_s`` / ``batched_qps`` floors (committed value
      minus ``tolerance``) — downgraded to report-only when the
      environment (backend/device/arch) differs from the artifact's;
    * ``sharded_speedup``: the committed artifact must carry >= 1.0
      (sharding must never be recorded as a slowdown again — it shipped
      at 0.22x once), and the re-measured ratio must stay above
      ``1.0 - tolerance``. The ratio is machine-relative, so this gate is
      NOT downgraded on environment changes; the tolerance absorbs
      measurement noise around parity on single-core hosts, where equal
      work is the physical floor;
    * engine-routing mix (machine-independent) as before;
    * mixed-workload ratios (machine-relative, gated everywhere):
      ``contention_p95_ratio <= 2.0`` and
      ``multi_tenant_min_ratio >= 0.8`` over >= 4 tenants; a missing
      ``mixed_workload`` section fails outright.

    Every check run also drops its fresh measurement at
    ``BENCH_serve.check.json`` (CI uploads it as a workflow artifact).
    """
    if not os.path.exists(_JSON_PATH):
        print(f"check: no committed {_JSON_PATH}; nothing to compare")
        return 0
    with open(_JSON_PATH) as f:
        old = json.load(f)
    new = _bench(quick, num_shards=old.get("num_shards"))
    # drop the fresh measurement beside the committed artifact: CI uploads
    # it as a workflow artifact so every run's numbers are inspectable
    with open(_JSON_PATH.replace(".json", ".check.json"), "w") as f:
        json.dump(new, f, indent=2)
    # config keys only ever change via a code edit — that must fail the
    # gate (forcing a re-baseline with --json), not silently disable it
    rc = 0
    for key in ("n", "k", "tau", "ingest_batch", "block_size", "num_shards"):
        if key in old and old[key] != new[key]:
            print(f"check: CONFIG CHANGED: {key} "
                  f"(committed {old[key]!r} vs here {new[key]!r}); "
                  f"re-baseline with `serve_bench --quick --json`")
            rc = 1
    # environment keys relax the absolute-throughput gates: those aren't
    # comparable across backends/arch classes. "host" is recorded for
    # provenance but never un-gates (CI container hostnames are ephemeral).
    same_env = True
    for key in ("backend", "device_kind", "machine"):
        if key in old and old[key] != new[key]:
            print(f"check: note: {key} differs "
                  f"(committed {old[key]!r} vs here {new[key]!r})")
            same_env = False
    if old.get("host") != new["host"]:
        print(f"check: note: host differs (committed {old.get('host')!r} vs "
              f"here {new['host']!r}); re-baseline with "
              f"`serve_bench --quick --json` if this machine is slower")
    for metric in ("ingest_points_per_s", "batched_qps"):
        if metric not in old:
            print(f"check: {metric}: no committed value, skipping")
            continue
        floor = old[metric] * (1.0 - tolerance)
        ok = new[metric] >= floor
        verdict = "OK" if ok else (
            "REGRESSION" if same_env else "BELOW FLOOR (env differs, not gated)"
        )
        print(f"check: {metric}: committed {old[metric]:.0f}, "
              f"now {new[metric]:.0f}, floor {floor:.0f} -> {verdict}")
        if not ok and same_env:
            rc = 1
    # sharded_speedup: a machine-relative ratio, gated everywhere
    if "sharded_speedup" in old:
        committed = old["sharded_speedup"]
        if committed < 1.0:
            print(f"check: sharded_speedup: committed artifact carries "
                  f"{committed:.2f} < 1.0 -> BASELINE REGRESSION "
                  f"(sharded ingest must not be re-baselined as a slowdown)")
            rc = 1
        floor = 1.0 - tolerance
        ok = new["sharded_speedup"] >= floor
        print(f"check: sharded_speedup: committed {committed:.2f}, "
              f"now {new['sharded_speedup']:.2f}, floor {floor:.2f} -> "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            rc = 1
    # the vmap drive's ratio: on CPU the auto placement runs the unsharded
    # executable per batch, so only this gate protects the branchless
    # vmapped scan from sliding back toward the historical 0.22x
    if "sharded_speedup_vmap" in old:
        floor = old["sharded_speedup_vmap"] * (1.0 - tolerance)
        ok = new["sharded_speedup_vmap"] >= floor
        print(f"check: sharded_speedup_vmap: committed "
              f"{old['sharded_speedup_vmap']:.2f}, "
              f"now {new['sharded_speedup_vmap']:.2f}, floor {floor:.2f} "
              f"-> {'OK' if ok else 'REGRESSION'}")
        if not ok:
            rc = 1
    # mixed-workload gates (machine-relative ratios, enforced everywhere):
    # queries served during active ingestion must stay within 2x the idle
    # warm p95 (the epoch-snapshot decoupling contract), and every tenant
    # fanned out from the single stream must serve cached QPS within 20%
    # of the single-tenant baseline (fan-out is cache-shaped, not
    # stream-shaped)
    mw = new.get("mixed_workload", {})
    if mw:
        ratio = mw["contention_p95_ratio"]
        ok = ratio <= 2.0
        print(f"check: mixed contention_p95_ratio = {ratio:.2f} "
              f"(idle p95 {mw['idle_p95_s'] * 1e3:.2f}ms, contended p95 "
              f"{mw['contended_p95_s'] * 1e3:.2f}ms, ceiling 2.00) -> "
              f"{'OK' if ok else 'CONTENTION REGRESSION'}")
        if not ok:
            rc = 1
        mtr = mw["multi_tenant_min_ratio"]
        ok = mtr >= 0.8 and mw["tenant_count"] >= 4
        print(f"check: mixed multi_tenant_min_ratio = {mtr:.2f} over "
              f"{mw['tenant_count']} tenants (floor 0.80, >= 4 tenants) "
              f"-> {'OK' if ok else 'FANOUT REGRESSION'}")
        if not ok:
            rc = 1
    else:  # the section must exist: its absence is itself a regression
        print("check: mixed_workload section missing -> REGRESSION")
        rc = 1
    # fault-tolerance gates (machine-relative / boolean, enforced
    # everywhere): restore must rebuild the exact stream within bounded
    # time, the chaos ingest must survive its injected faults, and the
    # 4x-saturation deadline burst must answer inside the budget
    ft = new.get("fault_tolerance", {})
    if ft:
        rec = ft["recovery"]
        ok = (rec["replay_parity"] and rec["recovery_s"] <= 60.0
              and rec["replay_pps"] > 0)
        print(f"check: fault recovery: parity={rec['replay_parity']}, "
              f"recovery {rec['recovery_s']:.2f}s (ceiling 60), replay "
              f"{rec['replay_pps']:.0f} pps over "
              f"{rec['replayed_batches']} batches -> "
              f"{'OK' if ok else 'RECOVERY REGRESSION'}")
        if not ok:
            rc = 1
        ch = ft["chaos"]
        ok = ch["stream_continued"] and ch["crashes"] >= 1
        print(f"check: fault chaos: crashes {ch['crashes']}, restarts "
              f"{ch['restarts']}, retries {ch['retries']}, poisoned "
              f"{ch['poisoned']}, stream_continued="
              f"{ch['stream_continued']} -> "
              f"{'OK' if ok else 'SUPERVISION REGRESSION'}")
        if not ok:
            rc = 1
        dl = ft["deadline"]
        ok = dl["deadline_violations"] == 0 and dl["goodput"] >= 0.5
        print(f"check: fault deadline: {dl['saturation']:.0f}x burst, "
              f"budget {dl['deadline_s'] * 1e3:.0f}ms, goodput "
              f"{dl['goodput']:.2f} (floor 0.50), violations "
              f"{dl['deadline_violations']} (min over rounds, must be 0) "
              f"-> {'OK' if ok else 'DEADLINE REGRESSION'}")
        if not ok:
            rc = 1
    else:
        print("check: fault_tolerance section missing -> REGRESSION")
        rc = 1
    # replication gates (machine-relative / boolean, enforced
    # everywhere): a mid-ingest primary kill must promote the standby
    # within bounded time with a bit-identical stream and zero acked
    # batches lost, the lag histogram must carry observations, and the
    # integrity audit of the surviving set must be clean
    rp = new.get("replication", {})
    if rp:
        ok = (rp["failover_parity"] and rp["failovers"] >= 1
              and 0.0 <= rp["failover_s"] <= 5.0)
        print(f"check: replication failover: failovers={rp['failovers']}, "
              f"{rp['failover_s']:.2f}s (ceiling 5), "
              f"parity={rp['failover_parity']}, "
              f"promoted={rp.get('promoted')}, acked "
              f"{rp['acked_batches']} batches -> "
              f"{'OK' if ok else 'FAILOVER REGRESSION'}")
        if not ok:
            rc = 1
        ok = rp["lag_observations"] > 0
        print(f"check: replication lag histogram: "
              f"{rp['lag_observations']} observations, max lag "
              f"{rp['max_lag_batches']} batches -> "
              f"{'OK' if ok else 'LAG HISTOGRAM EMPTY'}")
        if not ok:
            rc = 1
        ok = rp["audit_violations"] == 0 and rp["audit_checks"] > 0
        print(f"check: replication audit: {rp['audit_checks']} checks, "
              f"{rp['audit_violations']} violations (must be 0) -> "
              f"{'OK' if ok else 'INTEGRITY REGRESSION'}")
        if not ok:
            rc = 1
    else:
        print("check: replication section missing -> REGRESSION")
        rc = 1
    # steady-state recompile gate (machine-independent, gated everywhere):
    # the warmed measurement windows must compile NOTHING — a recompile
    # there means a jit cache key (bucketed shape, static arg) failed to
    # hold, silently turning a microsecond path into a multi-second one
    ssr = new.get("steady_state_recompiles")
    ok = ssr == 0
    print(f"check: steady_state_recompiles = {ssr} -> "
          f"{'OK' if ok else 'RECOMPILE REGRESSION'}")
    if not ok:
        rc = 1
        for key, cnt in sorted(new.get("recompiles_by_key", {}).items()):
            print(f"check:   compile census: {key} x{cnt}")
    # metrics-presence gate: the embedded snapshot must carry the serving
    # story — nonzero ingest and query histograms, per-engine solve series
    met = new.get("metrics", {})

    def _hist_count(prefix: str) -> int:
        return sum(
            d.get("count") or 0
            for key, d in met.items() if key.startswith(prefix)
        )

    ing_obs = _hist_count("serve.ingest.latency_s")
    qry_obs = _hist_count("serve.query.latency_s")
    solve_engines = sorted(
        key for key, d in met.items()
        if key.startswith("serve.solve.latency_s")
        and "engine=" in key and (d.get("count") or 0) > 0
    )
    ok = ing_obs > 0 and qry_obs > 0 and bool(solve_engines)
    print(f"check: metrics snapshot: ingest observations {ing_obs}, "
          f"query observations {qry_obs}, per-engine solve series "
          f"{len(solve_engines)} -> "
          f"{'OK' if ok else 'METRICS MISSING'}")
    if not ok:
        rc = 1
    ov = new.get("obs_overhead", {})
    if ov:  # report-only: the ratio is noisy on shared hosts
        print(f"check: obs_overhead: ingest "
              f"{ov['ingest_overhead']:+.1%}, batched "
              f"{ov['batched_qps_overhead']:+.1%} (target <= 3%)")
    # eligibility-mix gate (machine-independent): the jit engines must keep
    # covering their (variant x matroid) cells — a dispatch regression that
    # silently routes transversal or star/tree batches back to 100% host
    # fails even when absolute throughput is not comparable
    mix = new.get("engine_mix", {})
    for workload, engine_name in (
        ("partition_auto", "jit_sum"),
        ("transversal_auto", "jit_sum"),
        ("startree_hint", "jit_greedy"),
    ):
        frac = mix.get(workload, {}).get(engine_name, 0.0)
        ok = frac > 0.0
        print(f"check: engine_mix[{workload}][{engine_name}] = {frac:.2f} "
              f"-> {'OK' if ok else 'ROUTING REGRESSION'}")
        if not ok:
            rc = 1
    return rc


def main(quick: bool = False, emit_json: bool = False,
         num_shards: int | None = None):
    r = _bench(quick, num_shards=num_shards)
    if emit_json:
        with open(_JSON_PATH, "w") as f:
            json.dump(r, f, indent=2)
    yield csv_line("serve_ingest", 1e6 / r["ingest_points_per_s"],
                   f"pps={r['ingest_points_per_s']:.0f} "
                   f"block={r['block_size']}")
    yield csv_line("serve_ingest_sharded",
                   1e6 / r["ingest_points_per_s_sharded"],
                   f"pps={r['ingest_points_per_s_sharded']:.0f} "
                   f"shards={r['num_shards']} "
                   f"speedup={r['sharded_speedup']:.2f}x "
                   f"placement={r['sharded_placement']}")
    for pl, pv in r["ingest_pps_by_placement"].items():
        yield csv_line(f"serve_ingest_sharded_{pl}", 1e6 / pv,
                       f"pps={pv:.0f}")
    yield csv_line("serve_cold_solve", r["cold_solve_s"] * 1e6,
                   f"n={r['n']}")
    yield csv_line("serve_first_query_cold", r["first_query_cold_s"] * 1e6,
                   "trace+compile+pdist")
    yield csv_line("serve_first_query_warmed",
                   r["first_query_warmed_s"] * 1e6,
                   f"warmup={r['warmup_s']:.2f}s")
    yield csv_line("serve_warm_query", r["warm_query_s"] * 1e6,
                   f"speedup={r['warm_speedup_vs_cold']:.1f}x")
    yield csv_line("serve_batched", 1e6 / r["batched_qps"],
                   f"qps={r['batched_qps']:.0f} batch={r['batch_size']}")
    for cell, cqps in r["batched_qps_by_engine"].items():
        yield csv_line(f"serve_batched_{cell}", 1e6 / cqps,
                       f"qps={cqps:.0f}")
    for workload, mix in r["engine_mix"].items():
        pretty = " ".join(f"{e}={frac:.2f}" for e, frac in mix.items())
        yield csv_line(f"serve_mix_{workload}", 0.0, pretty)
    mw = r["mixed_workload"]
    yield csv_line("serve_query_idle_p95", mw["idle_p95_s"] * 1e6,
                   f"p50={mw['idle_p50_s'] * 1e6:.0f}us")
    yield csv_line("serve_query_contended_p95", mw["contended_p95_s"] * 1e6,
                   f"p50={mw['contended_p50_s'] * 1e6:.0f}us "
                   f"ratio={mw['contention_p95_ratio']:.2f}x "
                   f"ingest_pps={mw['contended_ingest_pps']:.0f}")
    for name, tqps in mw["tenant_qps"].items():
        yield csv_line(f"serve_tenant_{name}", 1e6 / tqps,
                       f"qps={tqps:.0f} "
                       f"min_ratio={mw['multi_tenant_min_ratio']:.2f}")
    ft = r["fault_tolerance"]
    yield csv_line("serve_recovery", ft["recovery"]["recovery_s"] * 1e6,
                   f"replay_pps={ft['recovery']['replay_pps']:.0f} "
                   f"parity={ft['recovery']['replay_parity']} "
                   f"batches={ft['recovery']['replayed_batches']}")
    yield csv_line("serve_chaos", 0.0,
                   f"crashes={ft['chaos']['crashes']} "
                   f"retries={ft['chaos']['retries']} "
                   f"poisoned={ft['chaos']['poisoned']} "
                   f"continued={ft['chaos']['stream_continued']}")
    yield csv_line("serve_deadline", ft["deadline"]["deadline_s"] * 1e6,
                   f"goodput={ft['deadline']['goodput']:.2f} "
                   f"degraded={ft['deadline']['degraded_fraction']:.2f} "
                   f"shed={ft['deadline']['shed_fraction']:.2f} "
                   f"violations={ft['deadline']['deadline_violations']}")
    rp = r["replication"]
    yield csv_line("serve_failover", rp["failover_s"] * 1e6,
                   f"failovers={rp['failovers']} "
                   f"parity={rp['failover_parity']} "
                   f"promoted={rp['promoted']} "
                   f"acked={rp['acked_batches']}")
    yield csv_line("serve_replication_lag", 0.0,
                   f"max_lag={rp['max_lag_batches']} "
                   f"observations={rp['lag_observations']} "
                   f"reseeds={rp['reseeds']}")
    yield csv_line("serve_audit", 0.0,
                   f"checks={rp['audit_checks']} "
                   f"violations={rp['audit_violations']}")
    yield csv_line("serve_obs_overhead", 0.0,
                   f"ingest={r['obs_overhead']['ingest_overhead']:+.1%} "
                   f"batched={r['obs_overhead']['batched_qps_overhead']:+.1%} "
                   f"steady_recompiles={r['steady_state_recompiles']}")
    if mw["contention_p95_ratio"] > 2.0:
        yield csv_line("serve_CONTENTION_ABOVE_2X", 0.0,
                       f"{mw['contention_p95_ratio']:.2f}x")
    if r["warm_speedup_vs_cold"] < 5.0:
        yield csv_line("serve_SPEEDUP_BELOW_5X", 0.0,
                       f"{r['warm_speedup_vs_cold']:.2f}x")
    if r["sharded_speedup"] < 1.0:
        yield csv_line("serve_SHARDED_BELOW_1X", 0.0,
                       f"{r['sharded_speedup']:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count for the sharded configs "
                         "(default: derived from devices/cpus)")
    ap.add_argument("--check", action="store_true",
                    help="compare a fresh --quick run against the committed "
                         "BENCH_serve.json; exit 1 on >20%% regression")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    print("name,us_per_call,derived")
    for line in main(quick=args.quick, emit_json=args.json,
                     num_shards=args.shards):
        print(line, flush=True)
