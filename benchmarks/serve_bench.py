"""Serving benchmark: ingest throughput, cached-vs-cold query latency,
batched QPS for the online diversity service.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--json]

``--json`` writes a ``BENCH_serve.json`` artifact (repo root) so the perf
trajectory is tracked across PRs. Also wired into ``benchmarks.run``.

Workload: songs-like partition instance (Table 2 structure). "Cold" is the
full offline driver (``solve_dmmc`` streaming: rebuild coreset + pdist +
solve); "warm" answers on the service's cached coreset distance matrix. The
acceptance bar for this subsystem is warm >= 5x faster than cold.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import Timer, csv_line, songs_like


def _bench(quick: bool) -> dict:
    from repro.core import solve_dmmc
    from repro.serve.diversity import DiversityQuery, DiversityService

    n = 4000 if quick else 20000
    k, tau, batch = 8, 32, 512
    P, cats, caps, spec = songs_like(n)

    svc = DiversityService(spec, k, tau=tau, caps=caps)
    # first tiny batch pays the jit compile; time steady-state ingestion
    svc.ingest(P[:batch], cats[:batch])
    with Timer() as t_ing:
        for off in range(batch, n, batch):
            svc.ingest(P[off:off + batch], cats[off:off + batch])
    ingest_pps = (n - batch) / t_ing.s

    # cold: offline driver from raw points (coreset + pdist + solve)
    with Timer() as t_cold:
        sol = solve_dmmc(P, k, spec, cats=cats, caps=caps, tau=tau,
                         setting="streaming")
    # warm single-query latency on the cached matrix (median of reps)
    svc.query(DiversityQuery(k=k))  # builds + caches the matrix
    reps = 5 if quick else 20
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = svc.query(DiversityQuery(k=k))
        lat.append(time.perf_counter() - t0)
    warm_s = float(np.median(lat))
    assert res.from_cache and svc.cache.stats.builds == 1

    # batched heterogeneous queries (32) against one cache entry
    qs = [
        DiversityQuery(
            k=2 + i % 7,
            caps=None if i % 2 else tuple(np.maximum(1, caps // 2).tolist()),
            allowed_cats=None if i % 3 else frozenset(range(8)),
        )
        for i in range(32)
    ]
    svc.query_batch(qs)  # compile the vmapped solver for this shape
    with Timer() as t_b:
        out = svc.query_batch(qs)
    assert svc.cache.stats.builds == 1, "batched path rebuilt the matrix"
    qps = len(out) / t_b.s

    speedup = t_cold.s / warm_s
    return dict(
        n=n, k=k, tau=tau,
        coreset_size=int(res.coreset_size),
        ingest_points_per_s=float(ingest_pps),
        cold_solve_s=float(t_cold.s),
        warm_query_s=warm_s,
        warm_speedup_vs_cold=float(speedup),
        batched_qps=float(qps),
        batch_size=len(out),
        offline_diversity=float(sol.diversity),
        warm_diversity=float(res.diversity),
        pdist_builds=int(svc.cache.stats.builds),
        cache_hits=int(svc.cache.stats.hits),
    )


def main(quick: bool = False, emit_json: bool = False):
    r = _bench(quick)
    if emit_json:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
        with open(path, "w") as f:
            json.dump(r, f, indent=2)
    yield csv_line("serve_ingest", 1e6 / r["ingest_points_per_s"],
                   f"pps={r['ingest_points_per_s']:.0f}")
    yield csv_line("serve_cold_solve", r["cold_solve_s"] * 1e6,
                   f"n={r['n']}")
    yield csv_line("serve_warm_query", r["warm_query_s"] * 1e6,
                   f"speedup={r['warm_speedup_vs_cold']:.1f}x")
    yield csv_line("serve_batched", 1e6 / r["batched_qps"],
                   f"qps={r['batched_qps']:.0f} batch={r['batch_size']}")
    if r["warm_speedup_vs_cold"] < 5.0:
        yield csv_line("serve_SPEEDUP_BELOW_5X", 0.0,
                       f"{r['warm_speedup_vs_cold']:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(quick=args.quick, emit_json=args.json):
        print(line, flush=True)
