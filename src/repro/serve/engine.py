"""Batched serving engine: prefill + greedy decode against padded caches."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.model import LM


def pad_caches(lm: LM, caches, cur_len: int, target_len: int):
    """Grow attention KV caches from cur_len to target_len along the seq axis
    (mamba/conv/cross-image caches are length-independent and pass through).
    """
    cfg = lm.cfg
    kv = max(cfg.n_kv, 1)

    def pad_leaf(x):
        if (
            x.ndim >= 4
            and x.shape[-3] == cur_len
            and x.shape[-2] == kv
            and x.shape[-1] == cfg.hd
        ):
            pad = [(0, 0)] * x.ndim
            pad[-3] = (0, target_len - cur_len)
            return jnp.pad(x, pad)
        return x

    return jax.tree.map(pad_leaf, caches)


class Engine:
    def __init__(self, lm: LM, params, max_len: int):
        self.lm = lm
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(lm.prefill)
        self._decode = jax.jit(lm.decode_step)

    def generate(
        self,
        tokens: jnp.ndarray,  # (B, P) prompt
        steps: int,
        img: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        B, P = tokens.shape
        assert P + steps <= self.max_len
        logits, caches = self._prefill(self.params, tokens, img)
        caches = pad_caches(self.lm, caches, P, self.max_len)
        out = [jnp.argmax(logits, -1).astype(jnp.int32)]
        for i in range(steps - 1):
            tok = out[-1][:, None]
            logits, caches = self._decode(
                self.params, tok, caches, jnp.int32(P + i), img
            )
            out.append(jnp.argmax(logits, -1).astype(jnp.int32))
        return jnp.stack(out, axis=1)  # (B, steps)
