"""Stateful online diversity service — now a thin façade over the layered
serving runtime (``StreamRuntime`` + ``QueryFrontend``).

Serving state is exactly what the paper says to keep (§4.4, §5.2): the
resumable streaming-scan state (``core.streaming.StreamState``) and the
small (1-eps)-coreset it induces. The layers split along the write/read
seam:

  StreamRuntime   owns the scan state across all placement drives
                  (vmap/shard_map/pipeline), resumes the jit'd branchless
                  blocked Alg.-2 scan per batch (donated buffers), tracks
                  the coreset fingerprint with an O(1) device sync, and
                  *publishes immutable epoch snapshots* — the coreset
                  materialized once per epoch, not per call. Its async
                  ``submit`` entry point decouples ingestion from the
                  query path entirely (background worker + epoch cadence);
  QueryFrontend   answers queries from published epochs only: per-tenant
                  ``(MatroidSpec, tau, metric)``-keyed ``DistanceCache``
                  entries over the shared stream, ``core.solvers``
                  registry dispatch (``engine="auto"`` partitions batches
                  across the fastest eligible host-parity engines), and
                  the ``min_epoch``/``flush()`` freshness contract.

``DiversityService`` wires one runtime to one frontend with one default
tenant and keeps the historical single-tenant API bit-for-bit: ``ingest``
is the runtime's synchronous path, ``query``/``query_batch`` resolve the
newest epoch (publishing pending synchronous ingests first, so the
sequential flow always sees its own writes), ``snapshot()`` returns the
published epoch's buffers — an epoch-aware no-op when nothing changed.
Multi-tenant and async serving are one attribute away:

    svc = DiversityService(spec, k=10, tau=64, caps=caps)
    svc.runtime.submit(batch, cats)              # non-blocking ingestion
    svc.frontend.register_tenant("cos", metric="cosine")
    svc.frontend.query(q, tenant="cos")          # same stream, own cache
    e = svc.frontend.flush()                     # freshness barrier
    svc.frontend.query(q, min_epoch=e)           # read your own writes
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from ...core import geometry
from ...core.matroid import MatroidSpec
from .cache import DistanceCache
from .frontend import QueryFrontend
from .query import DiversityQuery, QueryResult
from .runtime import EpochSnapshot, IngestReport, StreamRuntime

__all__ = [
    "DiversityService", "IngestReport", "EpochSnapshot",
]


class DiversityService:
    """Online DMMC: incremental coreset ingestion + cached batched queries
    (single-tenant façade over ``StreamRuntime`` + ``QueryFrontend``)."""

    def __init__(
        self,
        spec: MatroidSpec,
        k: int,
        *,
        tau: int,
        metric: geometry.Metric = "euclidean",
        caps: Optional[np.ndarray] = None,
        slot_cap: Optional[int] = None,
        variant: str = "radius",
        eps: float = 0.5,
        c_const: int = 32,
        oracle=None,
        cache: Optional[DistanceCache] = None,
        num_shards: int = 1,
        block_size: int = 128,
        placement: str = "auto",
        registry=None,
        durability=None,
        fault_policy=None,
        faults=None,
        cost_model=None,
        coalesce=None,
    ):
        self._wire(
            StreamRuntime(
                spec, k,
                tau=tau, metric=metric, caps=caps, slot_cap=slot_cap,
                variant=variant, eps=eps, c_const=c_const, oracle=oracle,
                num_shards=num_shards, block_size=block_size,
                placement=placement, registry=registry,
                durability=durability, fault_policy=fault_policy,
                faults=faults,
            ),
            cache=cache,
            registry=registry,
            cost_model=cost_model,
            coalesce=coalesce,
        )

    def _wire(self, runtime: StreamRuntime, *, cache=None, registry=None,
              cost_model=None, coalesce=None):
        self.runtime = runtime
        self.frontend = QueryFrontend(
            runtime, cache=cache, registry=registry,
            cost_model=cost_model, coalesce=coalesce,
        )
        self.cache = self.frontend.cache
        self.cache_key = self.frontend.default_tenant.key
        self.spec = runtime.spec
        self.k = runtime.k
        self.tau = runtime.tau
        self.metric = runtime.metric
        self.caps = runtime.caps
        self.slot_cap = runtime.slot_cap
        self.stream_variant = runtime.stream_variant
        self.eps = runtime.eps
        self.c_const = runtime.c_const
        self.oracle = runtime.oracle
        self.num_shards = runtime.num_shards
        self.block_size = runtime.block_size
        self.placement = runtime.placement
        return self

    @classmethod
    def from_runtime(
        cls, runtime: StreamRuntime, *, cache=None, registry=None,
        cost_model=None, coalesce=None,
    ) -> "DiversityService":
        """Wrap an existing runtime (e.g. one built by
        ``StreamRuntime.restore``) in the single-tenant façade without
        constructing a new stream."""
        svc = cls.__new__(cls)
        return svc._wire(
            runtime, cache=cache, registry=registry,
            cost_model=cost_model, coalesce=coalesce,
        )

    @classmethod
    def restore(
        cls,
        durability,
        *,
        oracle=None,
        cache=None,
        registry=None,
        fault_policy=None,
        faults=None,
        **overrides,
    ) -> "DiversityService":
        """Rebuild a service from its durability dir: newest checkpoint
        + WAL-tail replay, bit-identical to the stream that died (see
        ``StreamRuntime.restore``; the report is at
        ``svc.runtime.restore_report``)."""
        rt = StreamRuntime.restore(
            durability, oracle=oracle, registry=registry,
            fault_policy=fault_policy, faults=faults, **overrides,
        )
        return cls.from_runtime(rt, cache=cache, registry=registry)

    # ------------------------------------------------------------------
    # ingestion (delegated to the runtime's synchronous path)
    # ------------------------------------------------------------------

    @property
    def state(self):
        """The live scan state (see ``StreamRuntime.state`` for the
        donation caveat: the next ``ingest`` invalidates references
        captured here)."""
        return self.runtime.state

    @property
    def n_offered(self) -> int:
        return self.runtime.n_offered

    @property
    def _fingerprint(self) -> Optional[int]:
        return self.runtime.fingerprint

    def ingest(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Feed one batch of the stream synchronously (resume the blocked
        scan under the service's placement drive; see
        ``StreamRuntime.ingest``). For ingestion that must not block the
        caller, use ``svc.runtime.submit`` — same scan, same resulting
        stream, background worker + published epochs."""
        return self.runtime.ingest(points, cats, pad_to=pad_to)

    def ingest_sharded(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Row-granular sharded deal (vmap/shard_map drives); see
        ``StreamRuntime.ingest_sharded``."""
        return self.runtime.ingest_sharded(points, cats, pad_to=pad_to)

    def ingest_pipeline(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Batch-granular round-robin deal (pipeline placement); see
        ``StreamRuntime.ingest_pipeline``."""
        return self.runtime.ingest_pipeline(points, cats, pad_to=pad_to)

    def warmup(
        self,
        d: Optional[int] = None,
        *,
        ingest_sizes: Sequence[int] = (),
        ks: Sequence[int] = (),
        query_batch_sizes: Sequence[int] = (1,),
        variants: Sequence[str] = ("sum",),
    ) -> dict:
        """Ahead-of-time compile of the scan/solver shapes this service
        will serve, so the first real ingest/query stops paying full
        trace+compile (~seconds) inside its latency.

        Ingest warmup drives the real jit entry points with an all-invalid
        batch of each (bucketed) size in ``ingest_sizes`` — a bit-exact
        no-op for the scan (invalid rows advance nothing), so the stream
        state is unchanged while the compile cache fills. Requires the
        point dimension: pass ``d`` before the first ingest, afterwards it
        is taken from the live state.

        Query warmup answers one discarded batch per (k, batch size,
        variant) cell through the normal dispatch path, compiling the
        bucketed batched-solver kernels against the *current* coreset (the
        distance matrix is content-addressed, so this also builds and
        caches it). Skipped — with a ``"queries": "skipped (...)"`` note —
        until something has been ingested, because the solver shapes depend
        on the coreset size.

        Returns ``{label: seconds}`` per warmed shape.
        """
        import time

        report: dict = {}
        if d is None:
            d = self.runtime.point_dim()
            if d is None:
                raise ValueError(
                    "warmup() before the first ingest needs the point "
                    "dimension: warmup(d=...)"
                )
        self.runtime.ensure_state(d)
        for size in dict.fromkeys(
            int(s) for s in (*ingest_sizes, self.block_size)
        ):
            t0 = time.perf_counter()
            # an empty batch padded to `size` invalid rows: same jit cache
            # key as a real size-`size` ingest, zero state change
            self.ingest(np.zeros((0, d), np.float32), pad_to=size)
            report[f"ingest[{size}]"] = time.perf_counter() - t0
        if self._fingerprint is None or self.snapshot()[0].shape[0] == 0:
            report["queries"] = "skipped (ingest something first)"
            return report
        for variant in variants:
            for k in dict.fromkeys(int(x) for x in (*ks, self.k)):
                for bs in query_batch_sizes:
                    qs = [
                        DiversityQuery(k=k, variant=variant)
                        for _ in range(int(bs))
                    ]
                    t0 = time.perf_counter()
                    self.query_batch(qs)
                    report[f"query[{variant} k={k} b={bs}]"] = (
                        time.perf_counter() - t0
                    )
        return report

    def snapshot(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Compacted current coreset (points, cats, src_idx), buffer order —
        identical row order to ``solve_dmmc(..., setting='streaming')`` for a
        single shard; the shard-major union (§3) when sharded.

        Epoch-aware: reads the published ``EpochSnapshot`` (publishing any
        pending synchronous ingest first) and materializes the buffers only
        when the coreset actually changed — repeated calls on an unchanged
        stream return the same host arrays without touching device state.
        """
        snap = self.runtime.refresh()
        return snap.points, snap.cats, snap.src_idx

    # ------------------------------------------------------------------
    # queries (delegated to the frontend's default tenant)
    # ------------------------------------------------------------------

    def query(
        self,
        q: DiversityQuery,
        *,
        engine: str = "auto",
        deadline_s: Optional[float] = None,
    ) -> QueryResult:
        """Answer one query on the cached coreset matrix.

        The default ``engine="auto"`` (same default as ``query_batch``)
        picks the fastest registered engine with the host-parity guarantee
        — the selection, and therefore the canonical objective value,
        equals the host engine's, which in turn equals ``solve_dmmc`` on
        the same coreset. ``engine="host"`` forces the reference solver
        (bit-identical selection order to the offline driver); any
        registered engine name forces that engine. ``deadline_s`` arms
        deadline-aware admission (degrade/shed; see
        ``QueryFrontend.query_batch``).
        """
        return self.frontend.query(q, engine=engine, deadline_s=deadline_s)

    def query_batch(
        self,
        queries: Sequence[DiversityQuery],
        *,
        engine: str = "auto",
        deadline_s: Optional[float] = None,
    ) -> list[QueryResult]:
        """Answer a batch of heterogeneous queries against ONE cache entry
        (see ``QueryFrontend.query_batch`` for the engine and deadline
        semantics; the façade always queries the default tenant at the
        newest epoch)."""
        return self.frontend.query_batch(
            queries, engine=engine, deadline_s=deadline_s
        )

    def close(self) -> None:
        """Stop the frontend's coalescer and the runtime's async worker,
        if they were started."""
        self.frontend.close()
        self.runtime.close()
