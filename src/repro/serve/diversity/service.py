"""Stateful online diversity service (ingestion + cached query answering).

Serving state is exactly what the paper says to keep (§4.4, §5.2): the
resumable streaming-scan state (``core.streaming.StreamState``) and the small
(1-eps)-coreset it induces. Queries never touch the raw stream:

  ingest     resume the jit'd branchless blocked Alg.-2 scan over each
             arriving batch (``ingest_batch_donated`` — the state is
             reassigned every call, so its buffers are donated and a
             steady-state batch pays no state copy), with global
             ``src_idx`` bookkeeping; with ``num_shards > 1`` the stream
             is partitioned across independent per-shard scan states whose
             coresets compose by union (§3) under a ``placement`` drive:
             row-granular round-robin through one vmapped call ("vmap") or
             a shard_map mesh of per-device shard groups ("shard_map"),
             or batch-granular round-robin over per-device states
             ("pipeline" — each ingest is the unsharded executable);
             ``placement="auto"`` resolves per backend/device count.
             ``warmup()`` pre-compiles the bucketed scan/solver shapes so
             first queries stop paying trace+compile;
  cache      the compacted coreset + its pairwise distance matrix live in a
             ``DistanceCache`` keyed by (MatroidSpec, tau, metric) and a
             content fingerprint — ingestion that does not change the
             coreset keeps the matrix warm;
  query      answered on the cached matrix only, dispatched through the
             ``core.solvers`` engine registry: ``engine="auto"`` (the
             default for both ``query`` and ``query_batch``) partitions a
             batch across the fastest eligible engines carrying the
             host-parity guarantee — the vmapped batched sum solver for
             uniform/partition/transversal matroids, the host final-stage
             solvers (bit-identical selections to ``solve_dmmc``) for
             everything else. ``engine=<name>`` forces one engine; a
             query's ``engine_hint`` opts into non-parity engines like the
             vmapped star/tree greedy ("jit_greedy").
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ...core.solvers.jit_sum import bucket_pow2 as _bucket_pow2

import jax

from ...core import geometry
from ...core.compose import compact_coreset, snapshot_shards, union_coresets
from ...core.final_solve import SubsetMatroidView
from ...core.matroid import MatroidSpec, make_host_matroid
from ...core.solvers import (
    SolveContext,
    SolveSpec,
    get_engine,
    partition_by_engine,
)
from ...core.streaming import (
    StreamState,
    ingest_batch,
    ingest_batch_donated,
    ingest_batch_sharded,
    ingest_batch_sharded_donated,
    ingest_batch_sharded_mapped,
    init_sharded_states,
    init_stream_state,
    resolve_placement,
    snapshot_coreset,
)
from .cache import CacheKey, CoresetEntry, DistanceCache, coreset_fingerprint
from .query import DiversityQuery, QueryResult, candidate_mask


@dataclasses.dataclass
class IngestReport:
    n: int  # points in this batch
    total: int  # stream points offered so far
    coreset_size: int
    coreset_changed: bool
    ingest_s: float


class DiversityService:
    """Online DMMC: incremental coreset ingestion + cached batched queries."""

    def __init__(
        self,
        spec: MatroidSpec,
        k: int,
        *,
        tau: int,
        metric: geometry.Metric = "euclidean",
        caps: Optional[np.ndarray] = None,
        slot_cap: Optional[int] = None,
        variant: str = "radius",
        eps: float = 0.5,
        c_const: int = 32,
        oracle=None,
        cache: Optional[DistanceCache] = None,
        num_shards: int = 1,
        block_size: int = 128,
        placement: str = "auto",
    ):
        if spec.kind == "general" and oracle is None:
            raise ValueError("general matroid service needs a host oracle")
        if spec.kind == "partition" and caps is None:
            raise ValueError("partition matroid service needs per-category caps")
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        # resolves "auto" against jax.devices() once, at construction:
        # shard_map when >1 device can take a whole shard, else the vmap
        # drive (single-device fallback)
        self.placement = resolve_placement(placement, num_shards)
        self.spec = spec
        self.k = int(k)
        self.tau = int(tau)
        self.metric = metric
        self.caps = None if caps is None else np.asarray(caps, np.int32)
        self._caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
        self.slot_cap = slot_cap
        self.stream_variant = variant
        self.eps = float(eps)
        self.c_const = int(c_const)
        self.oracle = oracle
        self.num_shards = int(num_shards)
        self.block_size = int(block_size)
        self.cache = cache if cache is not None else DistanceCache()
        self.cache_key = CacheKey(spec=spec, tau=self.tau, metric=str(metric))
        # single-shard state, stacked shard state (vmap/shard_map), or a
        # list of per-shard states (pipeline)
        self._state = None
        self._gamma_width = max(spec.gamma, 1)
        self.n_offered = 0
        self._fingerprint: Optional[int] = None
        self._rr = 0  # pipeline round-robin cursor (batch granularity)
        # per-shard (valid, src) host pulls for the pipeline fingerprint:
        # only the shard an ingest touched is re-pulled (entry set to None);
        # the rest reuse their cached copy, so the per-ingest host-pull
        # count stays O(1) instead of O(num_shards)
        self._fp_cache: Optional[list] = None

    # ------------------------------------------------------------------
    # ingestion
    # ------------------------------------------------------------------

    @property
    def state(self):
        """The live scan state: a ``StreamState`` (single shard), a
        stacked one (vmap/shard_map), or a list (pipeline).

        The ingest hot path *donates* this state's buffers to XLA (the
        steady-state win of not copying the delegate store every batch),
        so a reference captured here is invalidated by the next
        ``ingest`` — read or copy (``jax.tree_util.tree_map(jnp.copy,
        svc.state)``) anything you need to keep before ingesting again.
        """
        return self._state

    def _check_cats(self, n: int, cats: Optional[np.ndarray]) -> np.ndarray:
        if cats is None:
            return np.zeros((n, self._gamma_width), np.int32)
        cats_arr = np.asarray(cats, np.int32).reshape(n, -1)
        if cats_arr.shape[1] != self._gamma_width:
            raise ValueError(
                f"cats width {cats_arr.shape[1]} != spec gamma "
                f"{self._gamma_width}"
            )
        if (
            self.spec.kind == "partition"
            and cats_arr.shape[1] > 1
            and np.any(cats_arr[:, 1:] >= 0)
        ):
            # refuse at the door rather than truncating labels inside the
            # scan/solvers: a partition matroid is single-label by
            # definition, multi-label points need a transversal spec
            raise ValueError(
                "partition service got a point with >1 category label; "
                "use a transversal MatroidSpec for multi-label data"
            )
        return cats_arr

    def ingest(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Feed one batch of the stream (any size) into the scan state.

        With ``num_shards > 1`` the batch is dealt round-robin across the
        per-shard scan states (``ingest_sharded``); otherwise it resumes the
        single blocked scan. Either way batches are padded to a multiple of
        ``block_size`` with invalid rows — a bit-exact no-op for the scan
        that keeps the jit cache keyed on a handful of bucketed shapes
        instead of recompiling for every ragged final batch. ``pad_to``
        raises the padded length further (``warmup`` uses it to compile a
        target batch shape off an empty batch).
        """
        if self.num_shards > 1:
            if self.placement == "pipeline":
                return self.ingest_pipeline(points, cats, pad_to=pad_to)
            return self.ingest_sharded(points, cats, pad_to=pad_to)
        t0 = time.perf_counter()
        pts = np.asarray(points, np.float32)
        n, d = pts.shape
        cats_arr = self._check_cats(n, cats)
        if self._state is None:
            self._state = init_stream_state(
                d, self._gamma_width, self.spec, self.k, self.tau,
                slot_cap=self.slot_cap,
            )
        total = max(n, pad_to or 0)
        pad = total + (-total % self.block_size) - n
        if pad:
            pts = np.concatenate([pts, np.zeros((pad, d), np.float32)])
            cats_arr = np.concatenate(
                [cats_arr, np.full((pad, self._gamma_width), -1, np.int32)]
            )
        valid = np.arange(n + pad) < n
        pts_norm = geometry.normalize_for_metric(
            jnp.asarray(pts, jnp.float32), self.metric
        )
        # donated: the previous state is dropped on reassignment, so XLA
        # aliases its buffers into the new state instead of copying the
        # whole delegate store every call (the dominant fixed cost of a
        # steady-state no-op batch)
        self._state = ingest_batch_donated(
            self._state,
            pts_norm,
            jnp.asarray(cats_arr),
            jnp.asarray(valid),
            self.spec,
            self._caps_j,
            self.k,
            self.tau,
            base_index=jnp.int32(self.n_offered),
            variant=self.stream_variant,
            eps=self.eps,
            c_const=self.c_const,
            block_size=self.block_size,
        )
        self.n_offered += n
        return self._report(n, t0)

    def ingest_sharded(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Deal one batch round-robin across ``num_shards`` independent
        scan states and ingest all shards in one call — the vmap drive on a
        single device, the ``shard_map``-over-mesh drive when ``placement``
        resolved to it (per-device shard groups run as real parallel
        programs).

        Each shard sees its own sub-stream; per §3 composability the union
        of the per-shard coresets (``snapshot``) is a coreset of the full
        stream. Global ``src_idx`` bookkeeping is preserved by passing
        explicit per-row indices.
        """
        if self.num_shards < 2:
            raise ValueError("ingest_sharded needs num_shards >= 2")
        if self.placement == "pipeline":
            # a pipeline service keeps a *list* of per-shard states; the
            # stacked-state drives here would corrupt it — route through
            # ingest()/ingest_pipeline, or construct with placement="vmap"
            # or "shard_map" for the row-granular deal
            raise ValueError(
                "ingest_sharded is the row-granular drive; this service "
                "resolved placement='pipeline' (batch-granular) — use "
                "ingest()/ingest_pipeline, or pass placement='vmap' or "
                "'shard_map'"
            )
        t0 = time.perf_counter()
        pts = np.asarray(points, np.float32)
        n, d = pts.shape
        cats_arr = self._check_cats(n, cats)
        S = self.num_shards
        if self._state is None:
            self._state = init_sharded_states(
                S, d, self._gamma_width, self.spec, self.k, self.tau,
                slot_cap=self.slot_cap,
            )
        if str(self.metric) == "euclidean":
            pts_norm = pts  # identity metric: skip the device round-trip
        else:
            pts_norm = np.asarray(
                geometry.normalize_for_metric(
                    jnp.asarray(pts, jnp.float32), self.metric
                )
            )
        # per-shard sub-batch length, bucketed so ragged batches reuse a
        # handful of jit shapes; the per-shard block never exceeds it (a
        # 512-point deal across 8 shards is ONE 64-point block per shard,
        # not a 64-point block padded to 128)
        mm0 = -(-max(n, pad_to or 0) // S)
        sb = min(self.block_size, _bucket_pow2(mm0))
        mm = mm0 + (-mm0 % sb)
        Pb = np.zeros((S, mm, d), np.float32)
        Cb = np.full((S, mm, self._gamma_width), -1, np.int32)
        Vb = np.zeros((S, mm), bool)
        Sb = np.full((S, mm), -1, np.int32)
        if n > 0 and n % S == 0:
            # whole deal in three O(n) reshapes: round-robin row r of the
            # batch lands at [r % S, r // S]
            q = n // S
            Pb[:, :q] = pts_norm.reshape(q, S, d).transpose(1, 0, 2)
            Cb[:, :q] = cats_arr.reshape(q, S, -1).transpose(1, 0, 2)
            Vb[:, :q] = True
            Sb[:, :q] = (
                self.n_offered
                + np.arange(n, dtype=np.int64).reshape(q, S).T
            )
        else:
            for s in range(S):
                rows = np.arange(s, n, S)
                r = rows.shape[0]
                Pb[s, :r] = pts_norm[rows]
                Cb[s, :r] = cats_arr[rows]
                Vb[s, :r] = True
                Sb[s, :r] = self.n_offered + rows
        ingest = (
            ingest_batch_sharded_donated
            if self.placement == "vmap"
            else functools.partial(ingest_batch_sharded_mapped, donate=True)
        )
        self._state = ingest(
            self._state,
            jnp.asarray(Pb),
            jnp.asarray(Cb),
            jnp.asarray(Vb),
            jnp.asarray(Sb),
            self.spec,
            self._caps_j,
            self.k,
            self.tau,
            variant=self.stream_variant,
            eps=self.eps,
            c_const=self.c_const,
            block_size=sb,
        )
        self.n_offered += n
        return self._report(n, t0)

    def _init_pipeline_states(self, d: int) -> None:
        devs = jax.devices()
        nd = len(devs)
        self._state = [
            jax.device_put(
                init_stream_state(
                    d, self._gamma_width, self.spec, self.k, self.tau,
                    slot_cap=self.slot_cap,
                ),
                devs[i % nd],
            )
            for i in range(self.num_shards)
        ]

    def ingest_pipeline(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Route one whole batch to the next shard (batch-granular
        round-robin) and resume that shard's plain blocked scan.

        The stream partition is by batches instead of rows — still a
        partition, so §3 union composability is untouched — and each
        ingest is the *same* jit executable as the unsharded path: per
        batch, sharding costs nothing. Shard states are pinned round-robin
        across ``jax.devices()``, so consecutive batches land on different
        devices and async dispatch can overlap them when the hardware has
        more than one. Callers that feed a few huge batches (rather than a
        stream of them) should prefer the row-granular drives, which
        spread every batch across all shards.
        """
        if self.num_shards < 2:
            raise ValueError("ingest_pipeline needs num_shards >= 2")
        t0 = time.perf_counter()
        pts = np.asarray(points, np.float32)
        n, d = pts.shape
        cats_arr = self._check_cats(n, cats)
        if self._state is None:
            self._init_pipeline_states(d)
        total = max(n, pad_to or 0)
        pad = total + (-total % self.block_size) - n
        if pad:
            pts = np.concatenate([pts, np.zeros((pad, d), np.float32)])
            cats_arr = np.concatenate(
                [cats_arr, np.full((pad, self._gamma_width), -1, np.int32)]
            )
        valid = np.arange(n + pad) < n
        pts_norm = geometry.normalize_for_metric(
            jnp.asarray(pts, jnp.float32), self.metric
        )
        i = self._rr % self.num_shards
        if n > 0:  # empty (warmup) batches don't consume a shard slot
            self._rr += 1
        if self._fp_cache is not None:
            self._fp_cache[i] = None  # this shard's pull is now stale
        self._state[i] = ingest_batch_donated(
            self._state[i],
            pts_norm,
            jnp.asarray(cats_arr),
            jnp.asarray(valid),
            self.spec,
            self._caps_j,
            self.k,
            self.tau,
            base_index=jnp.int32(self.n_offered),
            variant=self.stream_variant,
            eps=self.eps,
            c_const=self.c_const,
            block_size=self.block_size,
        )
        self.n_offered += n
        return self._report(n, t0)

    def warmup(
        self,
        d: Optional[int] = None,
        *,
        ingest_sizes: Sequence[int] = (),
        ks: Sequence[int] = (),
        query_batch_sizes: Sequence[int] = (1,),
        variants: Sequence[str] = ("sum",),
    ) -> dict:
        """Ahead-of-time compile of the scan/solver shapes this service
        will serve, so the first real ingest/query stops paying full
        trace+compile (~seconds) inside its latency.

        Ingest warmup drives the real jit entry points with an all-invalid
        batch of each (bucketed) size in ``ingest_sizes`` — a bit-exact
        no-op for the scan (invalid rows advance nothing), so the stream
        state is unchanged while the compile cache fills. Requires the
        point dimension: pass ``d`` before the first ingest, afterwards it
        is taken from the live state.

        Query warmup answers one discarded batch per (k, batch size,
        variant) cell through the normal dispatch path, compiling the
        bucketed batched-solver kernels against the *current* coreset (the
        distance matrix is content-addressed, so this also builds and
        caches it). Skipped — with a ``"queries": "skipped (...)"`` note —
        until something has been ingested, because the solver shapes depend
        on the coreset size.

        Returns ``{label: seconds}`` per warmed shape.
        """
        report: dict = {}
        if d is None:
            if self._state is None:
                raise ValueError(
                    "warmup() before the first ingest needs the point "
                    "dimension: warmup(d=...)"
                )
            x1 = (
                self._state[0].x1
                if isinstance(self._state, list)
                else self._state.x1
            )
            d = int(x1.shape[-1])
        if self._state is None:
            if self.num_shards > 1 and self.placement == "pipeline":
                self._init_pipeline_states(d)
            elif self.num_shards > 1:
                self._state = init_sharded_states(
                    self.num_shards, d, self._gamma_width, self.spec,
                    self.k, self.tau, slot_cap=self.slot_cap,
                )
            else:
                self._state = init_stream_state(
                    d, self._gamma_width, self.spec, self.k, self.tau,
                    slot_cap=self.slot_cap,
                )
            # the empty state has an empty coreset: fingerprint it so a
            # zero-ingest warmup leaves the service in a consistent state
            self._fingerprint, _ = self._fingerprint_and_size()
        for size in dict.fromkeys(
            int(s) for s in (*ingest_sizes, self.block_size)
        ):
            t0 = time.perf_counter()
            # an empty batch padded to `size` invalid rows: same jit cache
            # key as a real size-`size` ingest, zero state change
            self.ingest(np.zeros((0, d), np.float32), pad_to=size)
            report[f"ingest[{size}]"] = time.perf_counter() - t0
        if self._fingerprint is None or self.snapshot()[0].shape[0] == 0:
            report["queries"] = "skipped (ingest something first)"
            return report
        for variant in variants:
            for k in dict.fromkeys(int(x) for x in (*ks, self.k)):
                for bs in query_batch_sizes:
                    qs = [
                        DiversityQuery(k=k, variant=variant)
                        for _ in range(int(bs))
                    ]
                    t0 = time.perf_counter()
                    self.query_batch(qs)
                    report[f"query[{variant} k={k} b={bs}]"] = (
                        time.perf_counter() - t0
                    )
        return report

    def _report(self, n: int, t0: float) -> IngestReport:
        fp, size = self._fingerprint_and_size()
        changed = fp != self._fingerprint
        self._fingerprint = fp
        return IngestReport(
            n=n,
            total=self.n_offered,
            coreset_size=size,
            coreset_changed=changed,
            ingest_s=time.perf_counter() - t0,
        )

    def _fingerprint_and_size(self) -> tuple[int, int]:
        """Coreset fingerprint straight from the raw state buffers.

        The coreset is determined by (per-center validity, delegate validity,
        delegate src ids); hashing those three small host pulls avoids the
        eager ``snapshot_coreset`` graph on every ingest — the hot serving
        path. Row order matches ``snapshot``/``snapshot_shards``, and for a
        single shard the value is identical to the old snapshot-based hash.
        """
        def pull(st):
            dv = np.asarray(st.dv)
            cv = np.asarray(st.cvalid)
            ds = np.asarray(st.ds)
            valid = dv & cv[..., None]
            src = ds[valid].astype(np.int64)
            return coreset_fingerprint(valid.reshape(-1), src), int(
                src.shape[0]
            )

        if isinstance(self._state, list):
            if self._fp_cache is None:
                self._fp_cache = [None] * len(self._state)
            for j, st in enumerate(self._state):
                if self._fp_cache[j] is None:
                    self._fp_cache[j] = pull(st)
            # the union is determined by the shard-major sequence of shard
            # coresets, so hashing the per-shard hashes is an equivalent
            # content key
            return (
                hash(tuple(fp for fp, _sz in self._fp_cache)),
                int(sum(sz for _fp, sz in self._fp_cache)),
            )
        return pull(self._state)

    def snapshot(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Compacted current coreset (points, cats, src_idx), buffer order —
        identical row order to ``solve_dmmc(..., setting='streaming')`` for a
        single shard; the shard-major union (§3) when sharded."""
        if self._state is None:
            raise RuntimeError("ingest at least one batch first")
        if isinstance(self._state, list):  # pipeline: per-shard states
            cs = union_coresets(
                [snapshot_coreset(s) for s in self._state]
            )
        elif self.num_shards > 1:
            cs = snapshot_shards(self._state)
        else:
            cs = snapshot_coreset(self._state)
        return compact_coreset(cs)

    # ------------------------------------------------------------------
    # cached distance matrix
    # ------------------------------------------------------------------

    def _entry(self) -> tuple[CoresetEntry, bool]:
        """Current cache entry (building the matrix only if the coreset
        changed since it was last built). Returns (entry, was_cached)."""
        if self._fingerprint is None:
            raise RuntimeError("ingest at least one batch first")
        e = self.cache.lookup(self.cache_key, self._fingerprint)
        if e is not None:
            return e, True
        pts_c, cats_c, src_c = self.snapshot()
        e = self.cache.build(
            self.cache_key, pts_c, cats_c, src_c, self._fingerprint
        )
        return e, False

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def _host_matroid(self, entry: CoresetEntry, spec: SolveSpec):
        m = entry.size
        if self.spec.kind == "general":
            base = make_host_matroid(
                self.spec, None, None, self.n_offered, spec.k, self.oracle
            )
            return SubsetMatroidView(base, entry.src_idx)
        caps = (
            self.caps if spec.caps is None else np.asarray(spec.caps, np.int32)
        )
        return make_host_matroid(self.spec, entry.cats, caps, m, spec.k)

    def _solve_context(self, entry: CoresetEntry) -> SolveContext:
        """Registry view of one cache entry (what every engine solves on)."""
        return SolveContext(
            D=entry.D,
            spec=self.spec,
            cats=entry.cats,
            caps=self.caps,
            matroid_fn=lambda spec: self._host_matroid(entry, spec),
        )

    def _solve_spec(self, entry: CoresetEntry, q: DiversityQuery) -> SolveSpec:
        return SolveSpec(
            k=q.k,
            variant=q.variant,
            gamma=q.gamma,
            caps=q.caps,
            allow=candidate_mask(entry.cats, q.allowed_cats),
        )

    def query(self, q: DiversityQuery, *, engine: str = "auto") -> QueryResult:
        """Answer one query on the cached coreset matrix.

        The default ``engine="auto"`` (same default as ``query_batch``)
        picks the fastest registered engine with the host-parity guarantee
        — the selection, and therefore the canonical objective value,
        equals the host engine's, which in turn equals ``solve_dmmc`` on
        the same coreset. ``engine="host"`` forces the reference solver
        (bit-identical selection order to the offline driver); any
        registered engine name forces that engine.
        """
        return self.query_batch([q], engine=engine)[0]

    def query_batch(
        self, queries: Sequence[DiversityQuery], *, engine: str = "auto"
    ) -> list[QueryResult]:
        """Answer a batch of heterogeneous queries against ONE cache entry.

        ``engine="auto"`` partitions the batch across registry engines:
        each query goes to the fastest eligible engine carrying the
        host-parity guarantee (sum under uniform/partition/transversal ->
        the vmapped batched solver; everything else -> the host reference
        solvers), honoring per-query ``engine_hint`` opt-ins (e.g.
        "jit_greedy" for approximate star/tree). Any other name forces
        every query through that engine, raising if one is ineligible
        ("vmap" is accepted as a legacy alias of "jit_sum"). The distance
        matrix is fetched (and possibly built) exactly once per batch.
        """
        queries = list(queries)
        if not queries:
            return []
        entry, cached = self._entry()
        ctx = self._solve_context(entry)
        specs = [self._solve_spec(entry, q) for q in queries]
        groups = partition_by_engine(
            ctx,
            specs,
            engine=engine,
            hints=[q.engine_hint for q in queries],
        )
        results: list[Optional[QueryResult]] = [None] * len(queries)
        for name, idxs in groups.items():
            eng = get_engine(name)
            for i, sol in zip(
                idxs, eng.solve_batch(ctx, [specs[i] for i in idxs])
            ):
                loc = np.asarray(sol.local_indices, np.int64)
                results[i] = QueryResult(
                    indices=entry.src_idx[loc],
                    local_indices=loc,
                    diversity=sol.value,
                    variant=queries[i].variant,
                    engine=sol.engine,
                    coreset_size=entry.size,
                    from_cache=cached,
                )
        return results  # type: ignore[return-value]
