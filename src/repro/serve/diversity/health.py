"""Health monitoring for a ``ReplicaSet``: heartbeat + WAL-lag +
worker-liveness, driving automatic failover.

A ``HealthMonitor`` probes on a fixed cadence (or on demand via
``probe()`` for deterministic tests):

  heartbeat        ``ReplicaSet.check_primary()`` — the
                   ``health.heartbeat`` chaos site fires inside it, a
                   closed runtime or a dead/sticky-errored ingest worker
                   fails it;
  replication lag  per-standby acked-minus-applied batch counts into the
                   ``serve.replication.lag_batches`` gauge (per replica)
                   and histogram (the fleet-wide distribution the bench
                   gates on);
  parity           one O(1) fingerprint-exchange round
                   (``verify_standbys``) — divergent standbys fence and
                   re-seed per the set's ``ReplicationConfig``.

``failure_threshold`` *consecutive* failed heartbeats trigger
``ReplicaSet.failover()``; the probe pins the primary it observed, so a
failover that already happened (e.g. the submit path's inline promotion)
is never doubled.

Metrics: ``serve.health.probes`` / ``heartbeat_failures`` /
``failovers_triggered``; ``serve.health.healthy`` gauge (1/0).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
from typing import Optional

from ... import obs

_log = logging.getLogger("repro.serve.diversity.health")


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """``interval_s`` probe cadence; ``failure_threshold`` consecutive
    heartbeat failures before failover; ``verify_parity`` run the
    fingerprint exchange each probe; ``auto_failover`` promote on
    threshold (off = observe/alert only)."""

    interval_s: float = 0.05
    failure_threshold: int = 3
    verify_parity: bool = True
    auto_failover: bool = True


class HealthMonitor:
    """Background prober for one ``ReplicaSet``. ``start()`` spawns the
    thread; tests call ``probe()`` directly for lockstep determinism."""

    def __init__(
        self,
        replica_set,
        config: Optional[HealthConfig] = None,
        *,
        registry: Optional[obs.MetricsRegistry] = None,
    ):
        self.rset = replica_set
        self.config = config if config is not None else HealthConfig()
        self.registry = registry if registry is not None else (
            replica_set.registry
        )
        self._fail_streak = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.last_status: Optional[dict] = None
        reg = self.registry
        self._m_probes = reg.counter("serve.health.probes")
        self._m_hb_failures = reg.counter("serve.health.heartbeat_failures")
        self._m_triggered = reg.counter("serve.health.failovers_triggered")
        self._g_healthy = reg.gauge("serve.health.healthy")

    def probe(self) -> dict:
        """One probe round; returns the status dict it recorded."""
        rset = self.rset
        p = rset.primary  # pin: only fail over the primary we observed
        self._m_probes.inc()
        reason = rset.check_primary()
        healthy = reason is None
        self._g_healthy.set(1.0 if healthy else 0.0)
        if healthy:
            self._fail_streak = 0
        else:
            self._fail_streak += 1
            self._m_hb_failures.inc()
        lag = rset.observe_lag()
        parity = None
        if self.config.verify_parity and healthy:
            parity = rset.verify_standbys()
        failed_over = None
        if (
            not healthy
            and self.config.auto_failover
            and self._fail_streak >= self.config.failure_threshold
        ):
            try:
                failed_over = rset.failover(
                    reason=f"heartbeat: {reason}", expect=p
                )
                self._m_triggered.inc()
                self._fail_streak = 0
            except RuntimeError as e:
                # no promotable standby: keep probing (and degrading)
                _log.warning("failover skipped: %s", e)
        self.last_status = dict(
            healthy=healthy,
            reason=reason,
            fail_streak=self._fail_streak,
            lag=lag,
            parity=parity,
            primary=rset.primary.name,
            failed_over=failed_over,
        )
        return self.last_status

    # -- background thread ---------------------------------------------

    def start(self) -> "HealthMonitor":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="replica-health", daemon=True
        )
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.config.interval_s):
            try:
                self.probe()
            except Exception as e:  # noqa: BLE001 — the monitor must
                # outlive any single probe failure
                _log.warning("health probe error: %s: %s",
                             type(e).__name__, e)

    def close(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
        self._thread = None
