"""QueryFrontend: the read half of the diversity serving runtime.

A frontend answers queries against the *published epochs* of one
``StreamRuntime`` — never against the live device state — holding the
per-tenant ``DistanceCache`` entries and the ``core.solvers`` registry
dispatch that used to live inside ``DiversityService``:

  epoch      every query resolves the newest published ``EpochSnapshot``
             (``runtime.acquire``): stale-but-consistent while async
             ingestion is in flight, freshest-available when idle. The
             freshness contract is explicit — ``flush()`` barriers all
             submitted batches into a new epoch and returns its number,
             and ``query(..., min_epoch=e)`` blocks until an epoch >= e
             serves the answer;
  tenants    a ``TenantRegistry`` maps names to ``(spec, tau, metric,
             caps, oracle)`` configurations sharing the one stream. Each
             tenant's pdist matrix lives under its own cache key and is
             invalidated exactly when a *changed* epoch is published (the
             fingerprint moved) — §3 composability realized as cache
             fan-out instead of stream duplication;
  solve      per-query engine dispatch goes through the registry with a
             calibrated ``CostModel``: ``engine="auto"`` partitions a
             batch across eligible host-parity engines by *estimated
             latency* (host engines win tiny dispatch-dominated batches,
             jit engines win at scale; every measured solve refines the
             model), hints opt into non-parity engines, the matrix is
             fetched (and possibly built) exactly once per batch;
  coalesce   under real concurrency, ``query_batch`` calls from any
             threads/tenants merge through an adaptive micro-batch
             window (``coalesce.Coalescer``: a tenant-sharded dispatcher
             pool with a Little's-law window controller) into shared
             vmapped solves — stacked ACROSS tenants into one device
             dispatch when the engine supports it — bit-identical to
             per-call answers. A solo caller bypasses the window
             entirely — single-threaded behavior (spans, trace IDs,
             latency) is byte-for-byte the uncoalesced path.

Thread-safe: any number of threads may query while the runtime's worker
ingests; the cache serializes entry builds internally.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ... import obs
from ...obs.jaxprof import RecompileWatch
from ...core import geometry
from ...core.final_solve import SubsetMatroidView
from ...core.matroid import MatroidSpec, make_host_matroid
from ...core.solvers import (
    CostModel,
    SolveContext,
    SolveSpec,
    bucket_pow2,
    get_engine,
    partition_by_engine,
)
from .cache import CoresetEntry, DistanceCache
from .coalesce import CoalesceConfig, Coalescer, PendingCall
from .query import DiversityQuery, QueryResult, candidate_mask
from .runtime import EpochSnapshot, StreamRuntime
from .tenants import DEFAULT_TENANT, Tenant, TenantRegistry


class QueryFrontend:
    """Serves diversity queries from published epochs of one runtime."""

    def __init__(
        self,
        runtime: StreamRuntime,
        *,
        cache: Optional[DistanceCache] = None,
        default_tenant: str = DEFAULT_TENANT,
        registry: Optional[obs.MetricsRegistry] = None,
        cost_model: Optional[CostModel] = None,
        coalesce: Optional[CoalesceConfig] = None,
    ):
        self.runtime = runtime
        # default to the runtime's registry so one serving stack counts in
        # one place (tests pass explicit registries to count in isolation)
        self.registry = registry if registry is not None else runtime.registry
        self.cache = cache if cache is not None else DistanceCache(
            registry=self.registry
        )
        self.tenants = TenantRegistry()
        self.default_tenant = self.register_tenant(default_tenant)
        reg = self.registry
        self._m_epoch_wait_s = reg.histogram("serve.query.epoch_wait_s")
        # each frontend owns its model so learned crossovers don't bleed
        # between serving stacks (pass one in to share or pre-calibrate)
        self.cost_model = cost_model if cost_model is not None else CostModel()
        # a solve whose wall includes a jit trace+compile must not train
        # the model: that cost is paid once per shape, not per request,
        # and one 2 s compile EMA'd into a 5 ms cell would pin routing
        # away from the jit engines forever
        self._compiles = RecompileWatch()
        self._active = 0
        self._active_mu = threading.Lock()
        self._traffic_t0 = time.perf_counter()
        self._traffic_prev: dict[str, tuple[float, int]] = {}
        cfg = CoalesceConfig() if coalesce is None else coalesce
        self.coalescer = Coalescer(self, cfg) if cfg.enabled else None
        self._closed = False

    # ------------------------------------------------------------------
    # tenants
    # ------------------------------------------------------------------

    def register_tenant(
        self,
        name: str,
        *,
        spec: Optional[MatroidSpec] = None,
        tau: Optional[int] = None,
        metric: Optional[geometry.Metric] = None,
        caps: Optional[np.ndarray] = None,
        oracle=None,
    ) -> Tenant:
        """Register one logical serving configuration over the shared
        stream. Unspecified fields inherit the runtime's; a partition
        tenant that passes no caps inherits the runtime's caps the same
        way. Returns the (immutable) ``Tenant`` handle."""
        rt = self.runtime
        spec = rt.spec if spec is None else spec
        metric = rt.metric if metric is None else metric
        if str(metric) != str(rt.metric) and str(rt.metric) == "cosine":
            # the stream stores cosine-normalized rows; the raw geometry a
            # euclidean/sqeuclidean tenant needs is not recoverable from
            # them — refuse loudly instead of silently solving on the
            # unit sphere. (The reverse direction is fine: cosine
            # normalization of raw rows is exact, and it is idempotent.)
            raise ValueError(
                f"tenant {name!r} wants metric {str(metric)!r} over a "
                f"cosine-normalized stream; that geometry is not "
                f"derivable from the stored rows — run a separate "
                f"{str(metric)}-metric StreamRuntime instead"
            )
        if caps is None and spec.kind == "partition":
            caps = rt.caps
        return self.tenants.register(
            name,
            spec=spec,
            tau=rt.tau if tau is None else tau,
            metric=metric,
            caps=caps,
            oracle=rt.oracle if oracle is None else oracle,
        )

    def _resolve_tenant(self, tenant) -> Tenant:
        if tenant is None:
            return self.default_tenant
        if isinstance(tenant, Tenant):
            return tenant
        return self.tenants.get(tenant)

    # ------------------------------------------------------------------
    # per-tenant cache entries
    # ------------------------------------------------------------------

    def _entry(
        self, tenant: Tenant, snap: EpochSnapshot
    ) -> tuple[CoresetEntry, bool]:
        """Tenant's cache entry for one epoch (building the matrix only if
        this epoch's fingerprint hasn't been built for this key)."""
        e = self.cache.lookup(tenant.key, snap.fingerprint)
        if e is not None:
            return e, True
        pts = snap.points
        if tenant.metric != str(self.runtime.metric):
            # the epoch stores stream-metric-normalized rows; a tenant on a
            # different metric re-normalizes its private copy at build time
            pts = np.asarray(
                geometry.normalize_for_metric(
                    jnp.asarray(pts, jnp.float32), tenant.metric
                )
            )
        e = self.cache.build(
            tenant.key, pts, snap.cats, snap.src_idx, snap.fingerprint
        )
        return e, False

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def _host_matroid(
        self, tenant: Tenant, snap: EpochSnapshot, entry: CoresetEntry,
        spec: SolveSpec,
    ):
        m = entry.size
        if tenant.spec.kind == "general":
            base = make_host_matroid(
                tenant.spec, None, None, snap.n_offered, spec.k,
                tenant.oracle,
            )
            return SubsetMatroidView(base, entry.src_idx)
        caps = (
            tenant.caps
            if spec.caps is None
            else np.asarray(spec.caps, np.int32)
        )
        return make_host_matroid(tenant.spec, entry.cats, caps, m, spec.k)

    def _solve_context(
        self, tenant: Tenant, snap: EpochSnapshot, entry: CoresetEntry
    ) -> SolveContext:
        """Registry view of one cache entry (what every engine solves on)."""
        return SolveContext(
            D=entry.D,
            spec=tenant.spec,
            cats=entry.cats,
            caps=tenant.caps,
            matroid_fn=lambda spec: self._host_matroid(
                tenant, snap, entry, spec
            ),
        )

    def _solve_spec(
        self, entry: CoresetEntry, q: DiversityQuery
    ) -> SolveSpec:
        return SolveSpec(
            k=q.k,
            variant=q.variant,
            gamma=q.gamma,
            caps=q.caps,
            allow=candidate_mask(entry.cats, q.allowed_cats),
        )

    # ------------------------------------------------------------------
    # deadline-aware admission
    # ------------------------------------------------------------------

    def _predict_s(
        self, tenant: str, engine: str, *,
        B: int = 1, kmax: int = 1, m: int = 1,
    ) -> float:
        """Predicted wall time of one ``solve_batch`` call on ``engine``
        for this tenant: the p95 of its measured latency histogram
        (PR 6's ``serve.solve.latency_s``) once the tenant has history.
        A *cold* tenant — empty histogram — is no longer admitted
        optimistically (the old 0.0 prediction waved every first call
        through any deadline): the cost model's estimate for the actual
        (B, kmax, m) shape seeds the prediction until measurements take
        over."""
        h = self.registry.histogram(
            "serve.solve.latency_s", tenant=tenant, engine=engine
        )
        if h.count:
            return h.quantile(0.95)
        return self.cost_model.estimate(engine, B=B, kmax=kmax, m=m)

    def _admit(
        self,
        ctx: SolveContext,
        specs: Sequence[SolveSpec],
        groups: dict,
        tenant: str,
        remaining_s: float,
    ) -> tuple[dict, set, set]:
        """Fit the engine plan into the remaining deadline budget.

        Degradation matrix (in order): (1) exact star/tree queries
        routed to ``host_exhaustive`` move to the vmapped ``jit_greedy``
        engine when eligible — still a valid independent set, value is
        the greedy approximation (``degraded=True``); (2) whatever still
        doesn't fit is shed, most expensive predicted group first
        (``shed=True``, never queued past the deadline). Sum queries
        have no faster approximate target in the registry, so an
        over-budget sum group sheds rather than degrades.
        """
        degraded: set = set()
        shed: set = set()
        groups = {n: list(ix) for n, ix in groups.items() if ix}
        if remaining_s <= 0:
            for ix in groups.values():
                shed.update(ix)
            return {}, degraded, shed

        def pred(name: str) -> float:
            ix = groups[name]
            return self._predict_s(
                tenant, name, B=len(ix),
                kmax=max(specs[i].k for i in ix), m=ctx.size,
            )

        total = sum(pred(n) for n in groups)
        if total > remaining_s and "host_exhaustive" in groups:
            greedy = get_engine("jit_greedy")
            moved = [
                i for i in groups["host_exhaustive"]
                if greedy.eligible(ctx, specs[i])
            ]
            if moved:
                kept = [
                    i for i in groups["host_exhaustive"] if i not in moved
                ]
                if kept:
                    groups["host_exhaustive"] = kept
                else:
                    del groups["host_exhaustive"]
                groups.setdefault("jit_greedy", []).extend(moved)
                degraded.update(moved)
                total = sum(pred(n) for n in groups)
        if total > remaining_s:
            preds = {n: pred(n) for n in groups}
            for name in sorted(preds, key=preds.get, reverse=True):
                if total <= remaining_s:
                    break
                total -= preds[name]
                ix = groups.pop(name)
                shed.update(ix)
                degraded.difference_update(ix)
        return groups, degraded, shed

    def _shed_result(
        self, q: DiversityQuery, entry, cached: bool, epoch: int,
        tenant: str,
    ) -> QueryResult:
        return QueryResult(
            indices=np.empty((0,), np.int64),
            local_indices=np.empty((0,), np.int64),
            diversity=0.0,
            variant=q.variant,
            engine="shed",
            coreset_size=0 if entry is None else entry.size,
            from_cache=cached,
            epoch=epoch,
            tenant=tenant,
            shed=True,
        )

    def query(
        self,
        q: DiversityQuery,
        *,
        tenant=None,
        engine: str = "auto",
        min_epoch: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> QueryResult:
        """Answer one query on the named tenant's cached matrix over the
        newest published epoch (see ``query_batch`` for the engine and
        freshness semantics)."""
        return self.query_batch(
            [q], tenant=tenant, engine=engine, min_epoch=min_epoch,
            deadline_s=deadline_s,
        )[0]

    def active_calls(self) -> int:
        """Number of ``query_batch`` calls currently inside the frontend
        (counted before the coalesce-or-direct decision; coalesced
        callers stay counted while parked in the window)."""
        return self._active

    def query_batch(
        self,
        queries: Sequence[DiversityQuery],
        *,
        tenant=None,
        engine: str = "auto",
        min_epoch: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> list[QueryResult]:
        """Answer a batch of heterogeneous queries against ONE epoch and
        ONE tenant cache entry.

        ``engine="auto"`` partitions the batch across registry engines:
        each query goes to an eligible engine carrying the host-parity
        guarantee, picked by the frontend's calibrated ``CostModel``
        (host engines win tiny dispatch-dominated batches, jit engines
        win at scale; decisions are logged in
        ``cost_model.decisions()``), honoring per-query ``engine_hint``
        opt-ins (e.g. "jit_greedy" for approximate star/tree). Any other
        name forces every query through that engine, raising if one is
        ineligible ("vmap" is accepted as a legacy alias of "jit_sum").

        Under concurrency, calls coalesce through the micro-batch window
        (see ``coalesce.py``) into merged vmapped solves — answers stay
        bit-identical because only host-parity engines merge. A solo
        caller bypasses the window and runs the direct path inline.

        ``min_epoch`` blocks until an epoch >= it is published (use the
        epoch returned by ``flush()`` to read your own writes); without
        it, the newest published epoch answers immediately — during
        active ingestion that answer is stale-but-consistent, never torn.

        ``deadline_s`` arms deadline-aware admission: before solving,
        the measured per-engine latency (p95 of PR 6's histograms, cost-
        model estimates while cold) predicts whether the plan fits the
        remaining budget. Over-budget exact star/tree queries downgrade
        to ``jit_greedy`` (result marked ``degraded=True``); whatever
        still doesn't fit is shed (``shed=True``, ``engine="shed"``,
        empty selection) instead of queuing past the deadline. In the
        coalescer, a deadline also bounds the time spent waiting in the
        window. Per-tenant outcomes land in ``serve.query.degraded`` /
        ``serve.query.shed`` / ``serve.query.deadline_miss``.
        """
        queries = list(queries)
        if not queries:
            return []
        t = self._resolve_tenant(tenant)
        reg = self.registry
        reg.counter("serve.query.requests", tenant=t.name).inc()
        reg.counter("serve.query.queries", tenant=t.name).inc(len(queries))
        in_flight = reg.gauge("serve.query.in_flight", tenant=t.name)
        with self._active_mu:
            self._active += 1
        in_flight.inc()
        try:
            co = self.coalescer
            if co is not None and (self._active > 1 or co.backlog > 0):
                return co.submit(
                    t, queries, engine=engine, min_epoch=min_epoch,
                    deadline_s=deadline_s,
                )
            if co is not None:
                reg.counter("serve.coalesce.solo").inc()
            return self._query_batch_direct(
                queries, tenant=t, engine=engine, min_epoch=min_epoch,
                deadline_s=deadline_s,
            )
        finally:
            in_flight.inc(-1.0)
            with self._active_mu:
                self._active -= 1

    def _query_batch_direct(
        self,
        queries: list[DiversityQuery],
        *,
        tenant=None,
        engine: str = "auto",
        min_epoch: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> list[QueryResult]:
        """The uncoalesced solve path (one caller, one tenant, one epoch).
        This is byte-for-byte the historical ``query_batch`` body — the
        coalescer's parity contract is defined against it."""
        reg = self.registry
        t_batch = time.perf_counter()
        deadline = None if deadline_s is None else t_batch + deadline_s
        with obs.trace(), obs.span(
            "query_batch", cat="query", n=len(queries), engine=engine
        ):
            with obs.span("resolve_tenant", cat="query"):
                t = self._resolve_tenant(tenant)
            t0 = time.perf_counter()

            def _shed_all(entry=None, cached=False, epoch=-1):
                reg.counter(
                    "serve.query.shed", tenant=t.name
                ).inc(len(queries))
                return [
                    self._shed_result(q, entry, cached, epoch, t.name)
                    for q in queries
                ]

            with obs.span(
                "acquire_epoch", cat="query", min_epoch=min_epoch
            ):
                try:
                    snap = self.runtime.acquire(
                        min_epoch,
                        **(
                            {}
                            if deadline is None
                            else {"timeout": max(
                                0.0, deadline - time.perf_counter()
                            )}
                        ),
                    )
                except TimeoutError:
                    # the epoch can't publish inside the budget: shed
                    # the whole batch rather than blocking past it
                    return _shed_all()
            if min_epoch is not None:
                # how long freshness (read-your-writes) made this query
                # wait for its epoch to publish
                self._m_epoch_wait_s.observe(time.perf_counter() - t0)
            with obs.span(
                "cache_entry", cat="query", tenant=t.name,
                epoch=snap.epoch,
            ):
                entry, cached = self._entry(t, snap)
            reg.counter(
                "serve.query.cache_hits" if cached
                else "serve.query.cache_misses",
                tenant=t.name,
            ).inc()
            ctx = self._solve_context(t, snap, entry)
            specs = [self._solve_spec(entry, q) for q in queries]
            with obs.span("partition_by_engine", cat="query"):
                groups = partition_by_engine(
                    ctx,
                    specs,
                    engine=engine,
                    hints=[q.engine_hint for q in queries],
                    cost_model=self.cost_model,
                )
            degraded_ix: set = set()
            shed_ix: set = set()
            if deadline is not None:
                with obs.span("admit", cat="query"):
                    groups, degraded_ix, shed_ix = self._admit(
                        ctx, specs, groups, t.name,
                        deadline - time.perf_counter(),
                    )
                if degraded_ix:
                    reg.counter(
                        "serve.query.degraded", tenant=t.name
                    ).inc(len(degraded_ix))
                if shed_ix:
                    reg.counter(
                        "serve.query.shed", tenant=t.name
                    ).inc(len(shed_ix))
            results: list[Optional[QueryResult]] = [None] * len(queries)
            for i in shed_ix:
                results[i] = self._shed_result(
                    queries[i], entry, cached, snap.epoch, t.name
                )
            for name, idxs in groups.items():
                eng = get_engine(name)
                self._note_window_cost(
                    self.cost_model.estimate(
                        name, B=len(idxs),
                        kmax=max(specs[i].k for i in idxs), m=ctx.size,
                    )
                )
                t1 = time.perf_counter()
                c0 = self._compiles.total()
                with obs.span(
                    "solve", cat="query", engine=name, n=len(idxs)
                ):
                    sols = eng.solve_batch(
                        ctx, [specs[i] for i in idxs]
                    )
                # materializing local_indices/value blocks on the device:
                # the sync cost rides in this span, and the solve latency
                # histogram (below) includes it — what the caller feels
                with obs.span("device_sync", cat="query", engine=name):
                    for i, sol in zip(idxs, sols):
                        loc = np.asarray(sol.local_indices, np.int64)
                        results[i] = QueryResult(
                            indices=entry.src_idx[loc],
                            local_indices=loc,
                            diversity=sol.value,
                            variant=queries[i].variant,
                            engine=sol.engine,
                            coreset_size=entry.size,
                            from_cache=cached,
                            epoch=snap.epoch,
                            tenant=t.name,
                            degraded=i in degraded_ix,
                        )
                dt = time.perf_counter() - t1
                reg.histogram(
                    "serve.solve.latency_s", tenant=t.name, engine=name
                ).observe(dt)
                reg.histogram(
                    "serve.solve.batch_size", engine=name
                ).observe(len(idxs))
                if self._compiles.total() == c0:
                    self.cost_model.observe(
                        name, len(idxs),
                        max(specs[i].k for i in idxs), ctx.size, dt,
                    )
            reg.histogram(
                "serve.query.latency_s", tenant=t.name
            ).observe(time.perf_counter() - t_batch)
            reg.histogram(
                "serve.query.batch_size", tenant=t.name
            ).observe(len(queries))
            if (
                deadline is not None
                and time.perf_counter() > deadline
            ):
                # admitted work still overran the budget: the predictor
                # was wrong (cold histograms, a compile) — count it so
                # the miss rate is observable, and the histograms it
                # just fed make the next prediction honest
                reg.counter(
                    "serve.query.deadline_miss", tenant=t.name
                ).inc()
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # coalesced execution (dispatcher thread)
    # ------------------------------------------------------------------

    def _solve_coalesced(self, calls: "list[PendingCall]") -> None:
        """Execute one coalesced group (calls agreeing on tenant, engine,
        and ``min_epoch``; see ``coalesce.Coalescer``).

        Semantics per caller are exactly the direct path's: per-caller
        engine partition (hints honored) and per-caller deadline
        admission happen *before* merging; only then do admitted specs
        merge into pow-2-``k``-bucketed ``(engine, bucket)`` vmapped
        solves shared across callers. Cost-model routing sees the merged
        batch size, so a swarm of B=1 callers routes like the one big
        batch it actually is. Bit-identity holds because auto/hinted
        routing only merges host-parity engines and per-row vmap results
        are independent of batch composition.
        """
        t: Tenant = calls[0].tenant
        engine = calls[0].engine
        min_epoch = calls[0].min_epoch
        reg = self.registry
        n_total = sum(len(c.queries) for c in calls)
        with obs.trace(), obs.span(
            "coalesce_group", cat="query", calls=len(calls), n=n_total,
            engine=engine,
        ):

            def _shed_call(c, entry=None, cached=False, epoch=-1):
                reg.counter(
                    "serve.query.shed", tenant=t.name
                ).inc(len(c.queries))
                c.results = [
                    self._shed_result(q, entry, cached, epoch, t.name)
                    for q in c.queries
                ]

            # the group's epoch wait is bounded by its most patient
            # caller; any deadline-free caller restores the default wait
            kw = {}
            if all(c.deadline is not None for c in calls):
                kw["timeout"] = max(
                    0.0,
                    max(c.deadline for c in calls) - time.perf_counter(),
                )
            with obs.span(
                "acquire_epoch", cat="query", min_epoch=min_epoch
            ):
                try:
                    snap = self.runtime.acquire(min_epoch, **kw)
                except TimeoutError:
                    for c in calls:
                        _shed_call(c)
                    return
            if min_epoch is not None:
                now = time.perf_counter()
                for c in calls:
                    self._m_epoch_wait_s.observe(now - c.enq_t)
            with obs.span(
                "cache_entry", cat="query", tenant=t.name,
                epoch=snap.epoch,
            ):
                entry, cached = self._entry(t, snap)
            ctx = self._solve_context(t, snap, entry)
            # per-caller plan: partition + admission before any merging
            merged: dict[tuple[str, int], list] = {}
            first = True
            for c in calls:
                c.from_cache = cached or not first
                first = False
                reg.counter(
                    "serve.query.cache_hits" if c.from_cache
                    else "serve.query.cache_misses",
                    tenant=t.name,
                ).inc()
                c.results = [None] * len(c.queries)
                c.specs = [self._solve_spec(entry, q) for q in c.queries]
                groups = partition_by_engine(
                    ctx,
                    c.specs,
                    engine=c.engine,
                    hints=[q.engine_hint for q in c.queries],
                    cost_model=self.cost_model,
                    batch_size=n_total,
                )
                c.degraded = set()
                shed_ix: set = set()
                if c.deadline is not None:
                    with obs.span("admit", cat="query"):
                        groups, c.degraded, shed_ix = self._admit(
                            ctx, c.specs, groups, t.name,
                            c.deadline - time.perf_counter(),
                        )
                    if c.degraded:
                        reg.counter(
                            "serve.query.degraded", tenant=t.name
                        ).inc(len(c.degraded))
                    if shed_ix:
                        reg.counter(
                            "serve.query.shed", tenant=t.name
                        ).inc(len(shed_ix))
                for i in shed_ix:
                    c.results[i] = self._shed_result(
                        c.queries[i], entry, c.from_cache, snap.epoch,
                        t.name,
                    )
                for name, idxs in groups.items():
                    for i in idxs:
                        kb = bucket_pow2(max(1, c.specs[i].k))
                        merged.setdefault((name, kb), []).append((c, i))
            # merged solves: one launch per (engine, k-bucket)
            for (name, kb) in sorted(merged):
                items = merged[(name, kb)]
                eng = get_engine(name)
                mspecs = [c.specs[i] for c, i in items]
                self._note_window_cost(
                    self.cost_model.estimate(
                        name, B=len(items),
                        kmax=max(s.k for s in mspecs), m=ctx.size,
                    )
                )
                t1 = time.perf_counter()
                c0 = self._compiles.total()
                with obs.span(
                    "solve", cat="query", engine=name, n=len(items),
                    k_bucket=kb, coalesced_calls=len({
                        id(c) for c, _ in items
                    }),
                ):
                    sols = eng.solve_batch(ctx, mspecs)
                with obs.span("device_sync", cat="query", engine=name):
                    for (c, i), sol in zip(items, sols):
                        loc = np.asarray(sol.local_indices, np.int64)
                        c.results[i] = QueryResult(
                            indices=entry.src_idx[loc],
                            local_indices=loc,
                            diversity=sol.value,
                            variant=c.queries[i].variant,
                            engine=sol.engine,
                            coreset_size=entry.size,
                            from_cache=c.from_cache,
                            epoch=snap.epoch,
                            tenant=t.name,
                            degraded=i in c.degraded,
                        )
                dt = time.perf_counter() - t1
                reg.histogram(
                    "serve.solve.latency_s", tenant=t.name, engine=name
                ).observe(dt)
                reg.histogram(
                    "serve.solve.batch_size", engine=name
                ).observe(len(items))
                if self._compiles.total() == c0:
                    self.cost_model.observe(
                        name, len(items), max(s.k for s in mspecs),
                        ctx.size, dt,
                    )
            now = time.perf_counter()
            for c in calls:
                reg.histogram(
                    "serve.query.latency_s", tenant=t.name
                ).observe(now - c.enq_t)
                reg.histogram(
                    "serve.query.batch_size", tenant=t.name
                ).observe(len(c.queries))
                if c.deadline is not None and now > c.deadline:
                    reg.counter(
                        "serve.query.deadline_miss", tenant=t.name
                    ).inc()

    def _note_window_cost(self, est_s: float) -> None:
        """Feed one merged launch's cost-model estimate to the adaptive
        window controller (the S in its Little's-law target)."""
        co = self.coalescer
        if co is not None:
            co.window.observe_solve(est_s)

    def _solve_coalesced_stacked(
        self, subs: "list[list[PendingCall]]"
    ) -> None:
        """Execute one cross-tenant wave: several single-tenant coalesced
        sub-groups (each a ``_solve_coalesced``-shaped call list)
        agreeing on ``(engine, min_epoch)``, solved together.

        Per-caller semantics are the single-tenant path's — engine
        partition with hints, deadline admission, shed/degrade — applied
        per tenant lane before any merging. The merge then goes one step
        further than ``_solve_coalesced``: admitted specs landing in the
        same ``(engine, k-bucket)`` across *different tenants* stack
        into ONE device dispatch (``core/solvers/stacked.py``) when the
        engine supports it, because entries for different tenants over
        the same stream differ only in their pdist matrix. A 4-tenant
        mixed window pays one launch instead of four. Lanes the engine
        cannot stack (transversal/general matroids, mismatched coreset
        size or dtype, engines without the path) fall back to per-lane
        solves inside the same wave. A lane whose cache-entry build
        fails takes down only its own callers.
        """
        engine = subs[0][0].engine
        min_epoch = subs[0][0].min_epoch
        reg = self.registry
        all_calls = [c for sub in subs for c in sub]
        n_total = sum(len(c.queries) for c in all_calls)
        with obs.trace(), obs.span(
            "coalesce_stacked_group", cat="query", calls=len(all_calls),
            n=n_total, tenants=len(subs), engine=engine,
        ):

            def _shed_call(c, entry=None, cached=False, epoch=-1):
                reg.counter(
                    "serve.query.shed", tenant=c.tenant.name
                ).inc(len(c.queries))
                c.results = [
                    self._shed_result(
                        q, entry, cached, epoch, c.tenant.name
                    )
                    for q in c.queries
                ]

            # the wave's epoch wait is bounded by its most patient
            # caller; any deadline-free caller restores the default wait
            kw = {}
            if all(c.deadline is not None for c in all_calls):
                kw["timeout"] = max(
                    0.0,
                    max(c.deadline for c in all_calls)
                    - time.perf_counter(),
                )
            with obs.span(
                "acquire_epoch", cat="query", min_epoch=min_epoch
            ):
                try:
                    snap = self.runtime.acquire(min_epoch, **kw)
                except TimeoutError:
                    for c in all_calls:
                        _shed_call(c)
                    return
            if min_epoch is not None:
                now = time.perf_counter()
                for c in all_calls:
                    self._m_epoch_wait_s.observe(now - c.enq_t)
            # per-tenant lane prep: cache entry + per-caller plan
            lanes: list = []  # (tenant, ctx, entry, calls)
            merged: dict[tuple[str, int], list] = {}
            for sub in subs:
                t: Tenant = sub[0].tenant
                try:
                    with obs.span(
                        "cache_entry", cat="query", tenant=t.name,
                        epoch=snap.epoch,
                    ):
                        entry, cached = self._entry(t, snap)
                    ctx = self._solve_context(t, snap, entry)
                except BaseException as e:  # noqa: BLE001 — isolate the
                    # failed lane; the rest of the wave proceeds
                    for c in sub:
                        c.error = e
                    continue
                lane_i = len(lanes)
                lanes.append((t, ctx, entry, sub))
                first = True
                for c in sub:
                    c.from_cache = cached or not first
                    first = False
                    reg.counter(
                        "serve.query.cache_hits" if c.from_cache
                        else "serve.query.cache_misses",
                        tenant=t.name,
                    ).inc()
                    c.results = [None] * len(c.queries)
                    c.specs = [
                        self._solve_spec(entry, q) for q in c.queries
                    ]
                    groups = partition_by_engine(
                        ctx,
                        c.specs,
                        engine=c.engine,
                        hints=[q.engine_hint for q in c.queries],
                        cost_model=self.cost_model,
                        batch_size=n_total,
                        stacked=True,
                    )
                    c.degraded = set()
                    shed_ix: set = set()
                    if c.deadline is not None:
                        with obs.span("admit", cat="query"):
                            groups, c.degraded, shed_ix = self._admit(
                                ctx, c.specs, groups, t.name,
                                c.deadline - time.perf_counter(),
                            )
                        if c.degraded:
                            reg.counter(
                                "serve.query.degraded", tenant=t.name
                            ).inc(len(c.degraded))
                        if shed_ix:
                            reg.counter(
                                "serve.query.shed", tenant=t.name
                            ).inc(len(shed_ix))
                    for i in shed_ix:
                        c.results[i] = self._shed_result(
                            c.queries[i], entry, c.from_cache,
                            snap.epoch, t.name,
                        )
                    for name, idxs in groups.items():
                        for i in idxs:
                            kb = bucket_pow2(max(1, c.specs[i].k))
                            merged.setdefault((name, kb), []).append(
                                (lane_i, c, i)
                            )

            def _fan(lane_i, li, sols):
                lt, _ctx, lentry, _sub = lanes[lane_i]
                for (c, i), sol in zip(li, sols):
                    loc = np.asarray(sol.local_indices, np.int64)
                    c.results[i] = QueryResult(
                        indices=lentry.src_idx[loc],
                        local_indices=loc,
                        diversity=sol.value,
                        variant=c.queries[i].variant,
                        engine=sol.engine,
                        coreset_size=lentry.size,
                        from_cache=c.from_cache,
                        epoch=snap.epoch,
                        tenant=lt.name,
                        degraded=i in c.degraded,
                    )

            # merged launches: per (engine, k-bucket), stack the lanes
            # the engine can take together; solve the rest per lane
            for (name, kb) in sorted(merged):
                items = merged[(name, kb)]
                eng = get_engine(name)
                per_lane: dict[int, list] = {}
                for lane_i, c, i in items:
                    per_lane.setdefault(lane_i, []).append((c, i))
                stacks: dict[tuple, list[int]] = {}
                solo: list[int] = []
                for lane_i, li in per_lane.items():
                    ctx = lanes[lane_i][1]
                    if all(
                        eng.stack_eligible(ctx, c.specs[i])
                        for c, i in li
                    ):
                        sig = (ctx.size, str(ctx.D.dtype))
                        stacks.setdefault(sig, []).append(lane_i)
                    else:
                        solo.append(lane_i)
                # a lone stackable lane has nothing to amortize with
                for sig in list(stacks):
                    if len(stacks[sig]) < 2:
                        solo.extend(stacks.pop(sig))
                for sig, lis in stacks.items():
                    lane_args = []
                    parts = []
                    for lane_i in lis:
                        ctx = lanes[lane_i][1]
                        li = per_lane[lane_i]
                        lspecs = [c.specs[i] for c, i in li]
                        lane_args.append((ctx, lspecs))
                        parts.append(
                            (len(lspecs), max(s.k for s in lspecs))
                        )
                    m = sig[0]
                    rows = sum(b for b, _k in parts)
                    self._note_window_cost(
                        self.cost_model.estimate_stacked(name, parts, m)
                    )
                    t1 = time.perf_counter()
                    c0 = self._compiles.total()
                    with obs.span(
                        "solve", cat="query", engine=name, n=rows,
                        k_bucket=kb, stacked_tenants=len(lis),
                        coalesced_calls=len({
                            id(c)
                            for lane_i in lis
                            for c, _ in per_lane[lane_i]
                        }),
                    ):
                        lane_sols = eng.solve_batch_stacked(lane_args)
                    with obs.span(
                        "device_sync", cat="query", engine=name
                    ):
                        for lane_i, sols in zip(lis, lane_sols):
                            _fan(lane_i, per_lane[lane_i], sols)
                    dt = time.perf_counter() - t1
                    reg.counter("serve.coalesce.stacked_solves").inc()
                    reg.counter(
                        "serve.coalesce.stacked_rows"
                    ).inc(rows)
                    reg.histogram(
                        "serve.coalesce.stacked_tenants"
                    ).observe(len(lis))
                    for lane_i in lis:
                        reg.histogram(
                            "serve.solve.latency_s",
                            tenant=lanes[lane_i][0].name, engine=name,
                        ).observe(dt)
                    reg.histogram(
                        "serve.solve.batch_size", engine=name
                    ).observe(rows)
                    if self._compiles.total() == c0:
                        self.cost_model.observe(
                            name, rows, max(k for _b, k in parts), m, dt
                        )
                for lane_i in solo:
                    lt, ctx, _e, _sub = lanes[lane_i]
                    li = per_lane[lane_i]
                    lspecs = [c.specs[i] for c, i in li]
                    self._note_window_cost(
                        self.cost_model.estimate(
                            name, B=len(li),
                            kmax=max(s.k for s in lspecs), m=ctx.size,
                        )
                    )
                    t1 = time.perf_counter()
                    c0 = self._compiles.total()
                    with obs.span(
                        "solve", cat="query", engine=name, n=len(li),
                        k_bucket=kb,
                        coalesced_calls=len({id(c) for c, _ in li}),
                    ):
                        sols = eng.solve_batch(ctx, lspecs)
                    with obs.span(
                        "device_sync", cat="query", engine=name
                    ):
                        _fan(lane_i, li, sols)
                    dt = time.perf_counter() - t1
                    reg.histogram(
                        "serve.solve.latency_s", tenant=lt.name,
                        engine=name,
                    ).observe(dt)
                    reg.histogram(
                        "serve.solve.batch_size", engine=name
                    ).observe(len(li))
                    if self._compiles.total() == c0:
                        self.cost_model.observe(
                            name, len(li), max(s.k for s in lspecs),
                            ctx.size, dt,
                        )
            now = time.perf_counter()
            for lt, _ctx, _e, sub in lanes:
                for c in sub:
                    reg.histogram(
                        "serve.query.latency_s", tenant=lt.name
                    ).observe(now - c.enq_t)
                    reg.histogram(
                        "serve.query.batch_size", tenant=lt.name
                    ).observe(len(c.queries))
                    if c.deadline is not None and now > c.deadline:
                        reg.counter(
                            "serve.query.deadline_miss", tenant=lt.name
                        ).inc()

    # ------------------------------------------------------------------
    # freshness + observability
    # ------------------------------------------------------------------

    def flush(self, *, timeout: Optional[float] = 120.0) -> int:
        """Barrier every submitted batch into a published epoch and return
        its number (pass as ``min_epoch`` to read your own writes)."""
        return self.runtime.flush(timeout=timeout)

    def tenant_traffic(self) -> dict:
        """Per-tenant traffic accounting from the ``serve.query.*``
        series: cumulative requests/queries, live in-flight gauge, and
        the QPS over the interval since the previous ``stats()`` /
        ``tenant_traffic()`` call (first call: since frontend creation) —
        who is saturating the frontend, at a glance."""
        reg = self.registry
        now = time.perf_counter()
        out = {}
        for name in self.tenants.names():
            requests = reg.counter(
                "serve.query.requests", tenant=name
            ).value
            queries = reg.counter("serve.query.queries", tenant=name).value
            prev_t, prev_q = self._traffic_prev.get(
                name, (self._traffic_t0, 0)
            )
            dt = now - prev_t
            self._traffic_prev[name] = (now, queries)
            out[name] = {
                "requests": requests,
                "queries": queries,
                "in_flight": reg.gauge(
                    "serve.query.in_flight", tenant=name
                ).value,
                "qps": (queries - prev_q) / dt if dt > 0 else 0.0,
            }
        return out

    def stats(self) -> dict:
        """One observability snapshot: epoch/publication counters from the
        runtime, the shared cache's ``CacheStats``, per-tenant traffic,
        the coalescer's window/queue accounting, and the cost model's
        calibration state (including the routing-decision tail)."""
        lat = self.runtime.latest()
        return {
            "epoch": 0 if lat is None else lat.epoch,
            "epoch_fingerprint": None if lat is None else lat.fingerprint,
            "coreset_size": 0 if lat is None else lat.size,
            "n_offered": self.runtime.n_offered,
            "pending": self.runtime.pending,
            "epochs_published": self.runtime.epochs_published,
            "snapshot_materializations": (
                self.runtime.snapshot_materializations
            ),
            "tenants": self.tenants.names(),
            "cache_entries": len(self.cache),
            "cache": self.cache.stats.snapshot(),
            "active_calls": self.active_calls(),
            "tenant_traffic": self.tenant_traffic(),
            "coalesce": (
                None if self.coalescer is None else self.coalescer.stats()
            ),
            "cost_model": self.cost_model.snapshot(),
        }

    def drain_pending(self) -> list:
        """Failover support: stop this frontend's coalescer and return
        every in-window ``PendingCall`` *un-failed* — the callers stay
        blocked on their events. The drainer (``ReplicaSet.failover``)
        re-dispatches them on the promoted frontend via
        ``adopt_pending``. Idempotent with ``close()``: after draining,
        this frontend is closed."""
        self._closed = True
        self._compiles.close()
        if self.coalescer is None:
            return []
        return self.coalescer.drain()

    def adopt_pending(self, calls: list) -> int:
        """Re-dispatch ``PendingCall``s drained from a failed peer
        frontend on THIS frontend: remap each call's tenant to the local
        registry (replica frontends register the same tenant names),
        solve, and release the still-blocked caller. Calls drained from
        ALL of the peer's dispatcher shards arrive here; they regroup by
        ``(engine, min_epoch)`` and a multi-tenant group re-dispatches
        as one stacked wave, exactly as the pool would have run it.
        Returns the number of calls released."""
        released = 0
        waves: dict[tuple, dict[str, list]] = {}
        for c in calls:
            try:
                c.tenant = self._resolve_tenant(c.tenant.name)
            except BaseException as e:  # noqa: BLE001 — fan the failure
                # back to the blocked caller; adoption must release all
                c.error = e
                c.done.set()
                released += 1
                continue
            waves.setdefault(
                (c.engine, c.min_epoch), {}
            ).setdefault(c.tenant.name, []).append(c)
        for by_tenant in waves.values():
            subs = list(by_tenant.values())
            grp = [c for sub in subs for c in sub]
            try:
                if len(subs) == 1:
                    self._solve_coalesced(subs[0])
                else:
                    self._solve_coalesced_stacked(subs)
            except BaseException as e:  # noqa: BLE001
                for c in grp:
                    c.error = e
            finally:
                for c in grp:
                    c.done.set()
                    released += 1
        return released

    def close(self) -> None:
        """Shut down the coalescer's dispatcher thread (idempotent). The
        runtime is owned by the caller and is not touched."""
        if self._closed:
            return
        self._closed = True
        self._compiles.close()
        if self.coalescer is not None:
            self.coalescer.close()
