"""QueryFrontend: the read half of the diversity serving runtime.

A frontend answers queries against the *published epochs* of one
``StreamRuntime`` — never against the live device state — holding the
per-tenant ``DistanceCache`` entries and the ``core.solvers`` registry
dispatch that used to live inside ``DiversityService``:

  epoch      every query resolves the newest published ``EpochSnapshot``
             (``runtime.acquire``): stale-but-consistent while async
             ingestion is in flight, freshest-available when idle. The
             freshness contract is explicit — ``flush()`` barriers all
             submitted batches into a new epoch and returns its number,
             and ``query(..., min_epoch=e)`` blocks until an epoch >= e
             serves the answer;
  tenants    a ``TenantRegistry`` maps names to ``(spec, tau, metric,
             caps, oracle)`` configurations sharing the one stream. Each
             tenant's pdist matrix lives under its own cache key and is
             invalidated exactly when a *changed* epoch is published (the
             fingerprint moved) — §3 composability realized as cache
             fan-out instead of stream duplication;
  solve      per-query engine dispatch is unchanged from the single-tenant
             service: ``engine="auto"`` partitions a batch across the
             fastest eligible host-parity engines, hints opt into
             non-parity engines, the matrix is fetched (and possibly
             built) exactly once per batch.

Thread-safe: any number of threads may query while the runtime's worker
ingests; the cache serializes entry builds internally.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core import geometry
from ...core.final_solve import SubsetMatroidView
from ...core.matroid import MatroidSpec, make_host_matroid
from ...core.solvers import (
    SolveContext,
    SolveSpec,
    get_engine,
    partition_by_engine,
)
from .cache import CoresetEntry, DistanceCache
from .query import DiversityQuery, QueryResult, candidate_mask
from .runtime import EpochSnapshot, StreamRuntime
from .tenants import DEFAULT_TENANT, Tenant, TenantRegistry


class QueryFrontend:
    """Serves diversity queries from published epochs of one runtime."""

    def __init__(
        self,
        runtime: StreamRuntime,
        *,
        cache: Optional[DistanceCache] = None,
        default_tenant: str = DEFAULT_TENANT,
        registry: Optional[obs.MetricsRegistry] = None,
    ):
        self.runtime = runtime
        # default to the runtime's registry so one serving stack counts in
        # one place (tests pass explicit registries to count in isolation)
        self.registry = registry if registry is not None else runtime.registry
        self.cache = cache if cache is not None else DistanceCache(
            registry=self.registry
        )
        self.tenants = TenantRegistry()
        self.default_tenant = self.register_tenant(default_tenant)
        reg = self.registry
        self._m_epoch_wait_s = reg.histogram("serve.query.epoch_wait_s")

    # ------------------------------------------------------------------
    # tenants
    # ------------------------------------------------------------------

    def register_tenant(
        self,
        name: str,
        *,
        spec: Optional[MatroidSpec] = None,
        tau: Optional[int] = None,
        metric: Optional[geometry.Metric] = None,
        caps: Optional[np.ndarray] = None,
        oracle=None,
    ) -> Tenant:
        """Register one logical serving configuration over the shared
        stream. Unspecified fields inherit the runtime's; a partition
        tenant that passes no caps inherits the runtime's caps the same
        way. Returns the (immutable) ``Tenant`` handle."""
        rt = self.runtime
        spec = rt.spec if spec is None else spec
        metric = rt.metric if metric is None else metric
        if str(metric) != str(rt.metric) and str(rt.metric) == "cosine":
            # the stream stores cosine-normalized rows; the raw geometry a
            # euclidean/sqeuclidean tenant needs is not recoverable from
            # them — refuse loudly instead of silently solving on the
            # unit sphere. (The reverse direction is fine: cosine
            # normalization of raw rows is exact, and it is idempotent.)
            raise ValueError(
                f"tenant {name!r} wants metric {str(metric)!r} over a "
                f"cosine-normalized stream; that geometry is not "
                f"derivable from the stored rows — run a separate "
                f"{str(metric)}-metric StreamRuntime instead"
            )
        if caps is None and spec.kind == "partition":
            caps = rt.caps
        return self.tenants.register(
            name,
            spec=spec,
            tau=rt.tau if tau is None else tau,
            metric=metric,
            caps=caps,
            oracle=rt.oracle if oracle is None else oracle,
        )

    def _resolve_tenant(self, tenant) -> Tenant:
        if tenant is None:
            return self.default_tenant
        if isinstance(tenant, Tenant):
            return tenant
        return self.tenants.get(tenant)

    # ------------------------------------------------------------------
    # per-tenant cache entries
    # ------------------------------------------------------------------

    def _entry(
        self, tenant: Tenant, snap: EpochSnapshot
    ) -> tuple[CoresetEntry, bool]:
        """Tenant's cache entry for one epoch (building the matrix only if
        this epoch's fingerprint hasn't been built for this key)."""
        e = self.cache.lookup(tenant.key, snap.fingerprint)
        if e is not None:
            return e, True
        pts = snap.points
        if tenant.metric != str(self.runtime.metric):
            # the epoch stores stream-metric-normalized rows; a tenant on a
            # different metric re-normalizes its private copy at build time
            pts = np.asarray(
                geometry.normalize_for_metric(
                    jnp.asarray(pts, jnp.float32), tenant.metric
                )
            )
        e = self.cache.build(
            tenant.key, pts, snap.cats, snap.src_idx, snap.fingerprint
        )
        return e, False

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def _host_matroid(
        self, tenant: Tenant, snap: EpochSnapshot, entry: CoresetEntry,
        spec: SolveSpec,
    ):
        m = entry.size
        if tenant.spec.kind == "general":
            base = make_host_matroid(
                tenant.spec, None, None, snap.n_offered, spec.k,
                tenant.oracle,
            )
            return SubsetMatroidView(base, entry.src_idx)
        caps = (
            tenant.caps
            if spec.caps is None
            else np.asarray(spec.caps, np.int32)
        )
        return make_host_matroid(tenant.spec, entry.cats, caps, m, spec.k)

    def _solve_context(
        self, tenant: Tenant, snap: EpochSnapshot, entry: CoresetEntry
    ) -> SolveContext:
        """Registry view of one cache entry (what every engine solves on)."""
        return SolveContext(
            D=entry.D,
            spec=tenant.spec,
            cats=entry.cats,
            caps=tenant.caps,
            matroid_fn=lambda spec: self._host_matroid(
                tenant, snap, entry, spec
            ),
        )

    def _solve_spec(
        self, entry: CoresetEntry, q: DiversityQuery
    ) -> SolveSpec:
        return SolveSpec(
            k=q.k,
            variant=q.variant,
            gamma=q.gamma,
            caps=q.caps,
            allow=candidate_mask(entry.cats, q.allowed_cats),
        )

    # ------------------------------------------------------------------
    # deadline-aware admission
    # ------------------------------------------------------------------

    def _predict_s(self, tenant: str, engine: str) -> float:
        """Predicted wall time of one ``solve_batch`` call on ``engine``
        for this tenant: the p95 of its measured latency histogram
        (PR 6's ``serve.solve.latency_s``). 0.0 with no history — the
        first calls are admitted and train the predictor."""
        h = self.registry.histogram(
            "serve.solve.latency_s", tenant=tenant, engine=engine
        )
        return h.quantile(0.95) if h.count else 0.0

    def _admit(
        self,
        ctx: SolveContext,
        specs: Sequence[SolveSpec],
        groups: dict,
        tenant: str,
        remaining_s: float,
    ) -> tuple[dict, set, set]:
        """Fit the engine plan into the remaining deadline budget.

        Degradation matrix (in order): (1) exact star/tree queries
        routed to ``host_exhaustive`` move to the vmapped ``jit_greedy``
        engine when eligible — still a valid independent set, value is
        the greedy approximation (``degraded=True``); (2) whatever still
        doesn't fit is shed, most expensive predicted group first
        (``shed=True``, never queued past the deadline). Sum queries
        have no faster approximate target in the registry, so an
        over-budget sum group sheds rather than degrades.
        """
        degraded: set = set()
        shed: set = set()
        groups = {n: list(ix) for n, ix in groups.items() if ix}
        if remaining_s <= 0:
            for ix in groups.values():
                shed.update(ix)
            return {}, degraded, shed
        total = sum(self._predict_s(tenant, n) for n in groups)
        if total > remaining_s and "host_exhaustive" in groups:
            greedy = get_engine("jit_greedy")
            moved = [
                i for i in groups["host_exhaustive"]
                if greedy.eligible(ctx, specs[i])
            ]
            if moved:
                kept = [
                    i for i in groups["host_exhaustive"] if i not in moved
                ]
                if kept:
                    groups["host_exhaustive"] = kept
                else:
                    del groups["host_exhaustive"]
                groups.setdefault("jit_greedy", []).extend(moved)
                degraded.update(moved)
                total = sum(self._predict_s(tenant, n) for n in groups)
        if total > remaining_s:
            for name in sorted(
                groups, key=lambda n: self._predict_s(tenant, n),
                reverse=True,
            ):
                if total <= remaining_s:
                    break
                total -= self._predict_s(tenant, name)
                ix = groups.pop(name)
                shed.update(ix)
                degraded.difference_update(ix)
        return groups, degraded, shed

    def _shed_result(
        self, q: DiversityQuery, entry, cached: bool, epoch: int,
        tenant: str,
    ) -> QueryResult:
        return QueryResult(
            indices=np.empty((0,), np.int64),
            local_indices=np.empty((0,), np.int64),
            diversity=0.0,
            variant=q.variant,
            engine="shed",
            coreset_size=0 if entry is None else entry.size,
            from_cache=cached,
            epoch=epoch,
            tenant=tenant,
            shed=True,
        )

    def query(
        self,
        q: DiversityQuery,
        *,
        tenant=None,
        engine: str = "auto",
        min_epoch: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> QueryResult:
        """Answer one query on the named tenant's cached matrix over the
        newest published epoch (see ``query_batch`` for the engine and
        freshness semantics)."""
        return self.query_batch(
            [q], tenant=tenant, engine=engine, min_epoch=min_epoch,
            deadline_s=deadline_s,
        )[0]

    def query_batch(
        self,
        queries: Sequence[DiversityQuery],
        *,
        tenant=None,
        engine: str = "auto",
        min_epoch: Optional[int] = None,
        deadline_s: Optional[float] = None,
    ) -> list[QueryResult]:
        """Answer a batch of heterogeneous queries against ONE epoch and
        ONE tenant cache entry.

        ``engine="auto"`` partitions the batch across registry engines:
        each query goes to the fastest eligible engine carrying the
        host-parity guarantee (sum under uniform/partition/transversal ->
        the vmapped batched solver; everything else -> the host reference
        solvers), honoring per-query ``engine_hint`` opt-ins (e.g.
        "jit_greedy" for approximate star/tree). Any other name forces
        every query through that engine, raising if one is ineligible
        ("vmap" is accepted as a legacy alias of "jit_sum").

        ``min_epoch`` blocks until an epoch >= it is published (use the
        epoch returned by ``flush()`` to read your own writes); without
        it, the newest published epoch answers immediately — during
        active ingestion that answer is stale-but-consistent, never torn.

        ``deadline_s`` arms deadline-aware admission: before solving,
        the measured per-engine latency (p95 of PR 6's histograms)
        predicts whether the plan fits the remaining budget. Over-budget
        exact star/tree queries downgrade to ``jit_greedy`` (result
        marked ``degraded=True``); whatever still doesn't fit is shed
        (``shed=True``, ``engine="shed"``, empty selection) instead of
        queuing past the deadline. Per-tenant outcomes land in
        ``serve.query.degraded`` / ``serve.query.shed`` /
        ``serve.query.deadline_miss``.
        """
        queries = list(queries)
        if not queries:
            return []
        reg = self.registry
        t_batch = time.perf_counter()
        deadline = None if deadline_s is None else t_batch + deadline_s
        with obs.trace(), obs.span(
            "query_batch", cat="query", n=len(queries), engine=engine
        ):
            with obs.span("resolve_tenant", cat="query"):
                t = self._resolve_tenant(tenant)
            t0 = time.perf_counter()

            def _shed_all(entry=None, cached=False, epoch=-1):
                reg.counter(
                    "serve.query.shed", tenant=t.name
                ).inc(len(queries))
                return [
                    self._shed_result(q, entry, cached, epoch, t.name)
                    for q in queries
                ]

            with obs.span(
                "acquire_epoch", cat="query", min_epoch=min_epoch
            ):
                try:
                    snap = self.runtime.acquire(
                        min_epoch,
                        **(
                            {}
                            if deadline is None
                            else {"timeout": max(
                                0.0, deadline - time.perf_counter()
                            )}
                        ),
                    )
                except TimeoutError:
                    # the epoch can't publish inside the budget: shed
                    # the whole batch rather than blocking past it
                    return _shed_all()
            if min_epoch is not None:
                # how long freshness (read-your-writes) made this query
                # wait for its epoch to publish
                self._m_epoch_wait_s.observe(time.perf_counter() - t0)
            with obs.span(
                "cache_entry", cat="query", tenant=t.name,
                epoch=snap.epoch,
            ):
                entry, cached = self._entry(t, snap)
            reg.counter(
                "serve.query.cache_hits" if cached
                else "serve.query.cache_misses",
                tenant=t.name,
            ).inc()
            ctx = self._solve_context(t, snap, entry)
            specs = [self._solve_spec(entry, q) for q in queries]
            with obs.span("partition_by_engine", cat="query"):
                groups = partition_by_engine(
                    ctx,
                    specs,
                    engine=engine,
                    hints=[q.engine_hint for q in queries],
                )
            degraded_ix: set = set()
            shed_ix: set = set()
            if deadline is not None:
                with obs.span("admit", cat="query"):
                    groups, degraded_ix, shed_ix = self._admit(
                        ctx, specs, groups, t.name,
                        deadline - time.perf_counter(),
                    )
                if degraded_ix:
                    reg.counter(
                        "serve.query.degraded", tenant=t.name
                    ).inc(len(degraded_ix))
                if shed_ix:
                    reg.counter(
                        "serve.query.shed", tenant=t.name
                    ).inc(len(shed_ix))
            results: list[Optional[QueryResult]] = [None] * len(queries)
            for i in shed_ix:
                results[i] = self._shed_result(
                    queries[i], entry, cached, snap.epoch, t.name
                )
            for name, idxs in groups.items():
                eng = get_engine(name)
                t1 = time.perf_counter()
                with obs.span(
                    "solve", cat="query", engine=name, n=len(idxs)
                ):
                    sols = eng.solve_batch(
                        ctx, [specs[i] for i in idxs]
                    )
                # materializing local_indices/value blocks on the device:
                # the sync cost rides in this span, and the solve latency
                # histogram (below) includes it — what the caller feels
                with obs.span("device_sync", cat="query", engine=name):
                    for i, sol in zip(idxs, sols):
                        loc = np.asarray(sol.local_indices, np.int64)
                        results[i] = QueryResult(
                            indices=entry.src_idx[loc],
                            local_indices=loc,
                            diversity=sol.value,
                            variant=queries[i].variant,
                            engine=sol.engine,
                            coreset_size=entry.size,
                            from_cache=cached,
                            epoch=snap.epoch,
                            tenant=t.name,
                            degraded=i in degraded_ix,
                        )
                reg.histogram(
                    "serve.solve.latency_s", tenant=t.name, engine=name
                ).observe(time.perf_counter() - t1)
                reg.histogram(
                    "serve.solve.batch_size", engine=name
                ).observe(len(idxs))
            reg.histogram(
                "serve.query.latency_s", tenant=t.name
            ).observe(time.perf_counter() - t_batch)
            reg.histogram(
                "serve.query.batch_size", tenant=t.name
            ).observe(len(queries))
            if (
                deadline is not None
                and time.perf_counter() > deadline
            ):
                # admitted work still overran the budget: the predictor
                # was wrong (cold histograms, a compile) — count it so
                # the miss rate is observable, and the histograms it
                # just fed make the next prediction honest
                reg.counter(
                    "serve.query.deadline_miss", tenant=t.name
                ).inc()
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # freshness + observability
    # ------------------------------------------------------------------

    def flush(self, *, timeout: Optional[float] = 120.0) -> int:
        """Barrier every submitted batch into a published epoch and return
        its number (pass as ``min_epoch`` to read your own writes)."""
        return self.runtime.flush(timeout=timeout)

    def stats(self) -> dict:
        """One observability snapshot: epoch/publication counters from the
        runtime plus the shared cache's ``CacheStats``."""
        lat = self.runtime.latest()
        return {
            "epoch": 0 if lat is None else lat.epoch,
            "epoch_fingerprint": None if lat is None else lat.fingerprint,
            "coreset_size": 0 if lat is None else lat.size,
            "n_offered": self.runtime.n_offered,
            "pending": self.runtime.pending,
            "epochs_published": self.runtime.epochs_published,
            "snapshot_materializations": (
                self.runtime.snapshot_materializations
            ),
            "tenants": self.tenants.names(),
            "cache_entries": len(self.cache),
            "cache": self.cache.stats.snapshot(),
        }
