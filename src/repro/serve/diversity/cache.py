"""Coreset/distance-matrix cache for the diversity serving stack.

One entry per ``(MatroidSpec, tau, metric)`` configuration: the compacted,
metric-normalized coreset buffer plus its pairwise distance matrix (built by
the Pallas pdist kernel via ``core.final_solve.coreset_distance_matrix``).
An entry is keyed additionally by a *fingerprint* of the coreset contents —
ingestion that leaves the coreset unchanged (the common steady-state case:
most stream points become non-delegates) keeps the matrix warm; the entry is
rebuilt only when the coreset actually changed.

Many tenants share one ``DistanceCache`` — one entry per
``(spec, tau, metric)`` key — so the cache is bounded: ``max_entries`` caps
the entry count with least-recently-used eviction (per-key last-use
ordering) and ``ttl_s`` expires entries that have not been *rebuilt* within
the window, whichever comes first. Both are off by default. The full
expiry sweep is *lazy*: it runs on insert, and only once the earliest
possible expiry deadline has actually passed (tracked in ``_next_sweep``) —
a busy cache with nothing expiring pays per-key checks only, never a full
scan per operation. Under capacity pressure expired entries are swept
before any live entry is LRU-evicted.

All public operations are thread-safe (the serving frontend answers
queries from many threads while the ingest worker publishes epochs).

``CacheStats`` is the observability hook: the tests, serve_bench, and
``QueryFrontend.stats()`` use it to assert "no pdist recomputation on the
warm path" and to watch hit/miss/eviction/expiry rates per cache.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import time
from typing import Callable, NamedTuple, Optional

import numpy as np

from ... import obs
from ...core.final_solve import coreset_distance_matrix
from ...core.matroid import MatroidSpec


class CacheKey(NamedTuple):
    spec: MatroidSpec
    tau: int
    metric: str


@dataclasses.dataclass
class CoresetEntry:
    """Compacted coreset (valid rows only, buffer order) + its distances."""

    points: np.ndarray  # f32[m, d] metric-normalized
    cats: np.ndarray  # int32[m, gamma]
    src_idx: np.ndarray  # int64[m] global stream indices
    D: np.ndarray  # f32[m, m] pairwise Euclidean distances
    fingerprint: int
    built_at: float = 0.0  # clock() at build time (TTL anchor)
    last_use: float = 0.0  # clock() at last lookup hit (LRU ordering)

    @property
    def size(self) -> int:
        return int(self.src_idx.shape[0])


# distinguishes co-existing DistanceCache instances in a shared registry:
# each cache's counters live under their own cache=cN label, so a fresh
# cache always starts its series at zero
_cache_seq = itertools.count()


class CacheStats:
    """Per-cache counters, backed by ``repro.obs`` registry series
    (``serve.cache.<field>{cache=cN}``).

    Back-compat surface is unchanged: ``stats.hits`` etc. read as plain
    ints and ``snapshot()`` returns the same plain dict as the old
    dataclass did — but the counts now also appear in the registry's
    snapshot/JSONL exports alongside every other serving metric. Mutation
    goes through ``incr`` (called under the cache's RLock; each registry
    counter additionally takes its own lock, so the counts stay exact
    even for future lock-free callers).
    """

    FIELDS = (
        "hits",
        "misses",
        "builds",  # pdist matrix constructions (the expensive part)
        "invalidations",
        "evictions",  # max_entries LRU evictions
        "expirations",  # TTL expiries
        "sweeps",  # full expiry scans actually run (lazy: deadline-gated)
    )

    def __init__(
        self, registry: Optional[obs.MetricsRegistry] = None, **labels
    ):
        reg = registry if registry is not None else obs.default_registry()
        if "cache" not in labels:
            labels["cache"] = f"c{next(_cache_seq)}"
        self._counters = {
            f: reg.counter(f"serve.cache.{f}", **labels)
            for f in self.FIELDS
        }

    def incr(self, field: str, n: int = 1) -> None:
        self._counters[field].inc(n)

    def __getattr__(self, name: str) -> int:
        c = self.__dict__.get("_counters", {}).get(name)
        if c is None:
            raise AttributeError(name)
        return c.value

    def snapshot(self) -> dict:
        """Plain-dict copy (what ``QueryFrontend.stats()``/serve_bench
        record — counters keep mutating underneath)."""
        return {f: c.value for f, c in self._counters.items()}


def coreset_fingerprint(valid: np.ndarray, src_idx: np.ndarray) -> int:
    """Cheap content hash: the coreset is determined by (valid, src_idx)
    since points/cats are copies of the stream rows named by src_idx.

    The serving runtime now fingerprints on-device without the host pull
    (``core.streaming.epoch_fingerprint``); this host-side form remains for
    callers that already hold the buffers.
    """
    return hash((valid.tobytes(), src_idx.tobytes()))


class DistanceCache:
    """Maps CacheKey -> CoresetEntry, invalidating on fingerprint change,
    with optional max-entries LRU eviction and per-entry TTL expiry."""

    def __init__(
        self,
        build_fn: Callable[[np.ndarray], np.ndarray] = coreset_distance_matrix,
        *,
        max_entries: Optional[int] = None,
        ttl_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        registry: Optional[obs.MetricsRegistry] = None,
    ):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self._build_fn = build_fn
        self._entries: dict[CacheKey, CoresetEntry] = {}
        self.max_entries = max_entries
        self.ttl_s = ttl_s
        self._clock = clock
        self._mu = threading.RLock()
        # earliest instant at which *any* entry can expire: a full sweep
        # before this is provably a no-op, so inserts skip it (lazy sweep)
        self._next_sweep = math.inf
        self.stats = CacheStats(registry)

    def _expired(self, e: CoresetEntry) -> bool:
        return (
            self.ttl_s is not None
            and self._clock() - e.built_at > self.ttl_s
        )

    def _sweep_expired(self) -> None:
        """Drop every expired entry — without this, a ttl_s-only cache would
        keep abandoned tenants' O(m^2) matrices forever, since per-key
        expiry in lookup() only fires for keys that are queried again.

        Deadline-gated: callers consult ``_next_sweep`` first, so the scan
        runs only when some entry has actually aged past the TTL (or under
        capacity pressure), not on every insert.
        """
        if self.ttl_s is None:
            return
        self.stats.incr("sweeps")
        for k in [k for k, e in self._entries.items() if self._expired(e)]:
            del self._entries[k]
            self.stats.incr("expirations")
        self._next_sweep = (
            min(e.built_at for e in self._entries.values()) + self.ttl_s
            if self._entries
            else math.inf
        )

    def lookup(self, key: CacheKey, fingerprint: int) -> Optional[CoresetEntry]:
        with self._mu:
            e = self._entries.get(key)
            if e is not None and self._expired(e):
                self.stats.incr("expirations")
                del self._entries[key]
                e = None
            if e is not None and e.fingerprint == fingerprint:
                self.stats.incr("hits")
                e.last_use = self._clock()
                return e
            if e is not None:
                self.stats.incr("invalidations")
                del self._entries[key]
            self.stats.incr("misses")
            return None

    def build(
        self,
        key: CacheKey,
        points: np.ndarray,
        cats: np.ndarray,
        src_idx: np.ndarray,
        fingerprint: int,
    ) -> CoresetEntry:
        # the O(m^2) matrix is computed OUTSIDE the cache lock: a cold
        # tenant's build must not block every other tenant's warm lookup.
        # Two threads racing the same (key, fingerprint) both pay the
        # build and the later insert wins — correct (same inputs, same
        # matrix) and honest (both builds counted).
        D = self._build_fn(points)
        with self._mu:
            self.stats.incr("builds")
            now = self._clock()
            if now >= self._next_sweep:
                self._sweep_expired()
            e = CoresetEntry(
                points=points, cats=cats, src_idx=src_idx, D=D,
                fingerprint=fingerprint, built_at=now, last_use=now,
            )
            self._entries[key] = e
            if self.ttl_s is not None:
                self._next_sweep = min(self._next_sweep, now + self.ttl_s)
            if self.max_entries is not None:
                if len(self._entries) > self.max_entries:
                    # capacity pressure: reclaim dead entries before
                    # evicting a live tenant's matrix
                    self._sweep_expired()
                while len(self._entries) > self.max_entries:
                    lru = min(
                        self._entries, key=lambda k: self._entries[k].last_use
                    )
                    del self._entries[lru]
                    self.stats.incr("evictions")
            return e

    def invalidate(self, key: CacheKey) -> None:
        with self._mu:
            if key in self._entries:
                del self._entries[key]
                self.stats.incr("invalidations")

    def __len__(self) -> int:
        return len(self._entries)
