"""Coreset/distance-matrix cache for the diversity service.

One entry per ``(MatroidSpec, tau, metric)`` configuration: the compacted,
metric-normalized coreset buffer plus its pairwise distance matrix (built by
the Pallas pdist kernel via ``core.final_solve.coreset_distance_matrix``).
An entry is keyed additionally by a *fingerprint* of the coreset contents —
ingestion that leaves the coreset unchanged (the common steady-state case:
most stream points become non-delegates) keeps the matrix warm; the entry is
rebuilt only when the coreset actually changed.

Many services (tenants) may share one ``DistanceCache`` — one entry per
``(spec, tau, metric)`` key — so the cache is bounded: ``max_entries`` caps
the entry count with least-recently-used eviction (per-key last-use
ordering) and ``ttl_s`` expires entries that have not been *rebuilt* within
the window, whichever comes first. Both are off by default.

``CacheStats`` is the observability hook the tests and serve_bench use to
assert "no pdist recomputation on the warm path".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import numpy as np

from ...core.final_solve import coreset_distance_matrix
from ...core.matroid import MatroidSpec


class CacheKey(NamedTuple):
    spec: MatroidSpec
    tau: int
    metric: str


@dataclasses.dataclass
class CoresetEntry:
    """Compacted coreset (valid rows only, buffer order) + its distances."""

    points: np.ndarray  # f32[m, d] metric-normalized
    cats: np.ndarray  # int32[m, gamma]
    src_idx: np.ndarray  # int64[m] global stream indices
    D: np.ndarray  # f32[m, m] pairwise Euclidean distances
    fingerprint: int
    built_at: float = 0.0  # clock() at build time (TTL anchor)
    last_use: float = 0.0  # clock() at last lookup hit (LRU ordering)

    @property
    def size(self) -> int:
        return int(self.src_idx.shape[0])


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0  # pdist matrix constructions (the expensive part)
    invalidations: int = 0
    evictions: int = 0  # max_entries LRU evictions
    expirations: int = 0  # TTL expiries


def coreset_fingerprint(valid: np.ndarray, src_idx: np.ndarray) -> int:
    """Cheap content hash: the coreset is determined by (valid, src_idx)
    since points/cats are copies of the stream rows named by src_idx."""
    return hash((valid.tobytes(), src_idx.tobytes()))


class DistanceCache:
    """Maps CacheKey -> CoresetEntry, invalidating on fingerprint change,
    with optional max-entries LRU eviction and per-entry TTL expiry."""

    def __init__(
        self,
        build_fn: Callable[[np.ndarray], np.ndarray] = coreset_distance_matrix,
        *,
        max_entries: Optional[int] = None,
        ttl_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self._build_fn = build_fn
        self._entries: dict[CacheKey, CoresetEntry] = {}
        self.max_entries = max_entries
        self.ttl_s = ttl_s
        self._clock = clock
        self.stats = CacheStats()

    def _expired(self, e: CoresetEntry) -> bool:
        return (
            self.ttl_s is not None
            and self._clock() - e.built_at > self.ttl_s
        )

    def _sweep_expired(self) -> None:
        """Drop every expired entry — without this, a ttl_s-only cache would
        keep abandoned tenants' O(m^2) matrices forever, since per-key
        expiry in lookup() only fires for keys that are queried again."""
        for k in [k for k, e in self._entries.items() if self._expired(e)]:
            del self._entries[k]
            self.stats.expirations += 1

    def lookup(self, key: CacheKey, fingerprint: int) -> Optional[CoresetEntry]:
        e = self._entries.get(key)
        if e is not None and self._expired(e):
            self.stats.expirations += 1
            del self._entries[key]
            e = None
        if e is not None and e.fingerprint == fingerprint:
            self.stats.hits += 1
            e.last_use = self._clock()
            return e
        if e is not None:
            self.stats.invalidations += 1
            del self._entries[key]
        self.stats.misses += 1
        return None

    def build(
        self,
        key: CacheKey,
        points: np.ndarray,
        cats: np.ndarray,
        src_idx: np.ndarray,
        fingerprint: int,
    ) -> CoresetEntry:
        D = self._build_fn(points)
        self.stats.builds += 1
        self._sweep_expired()
        now = self._clock()
        e = CoresetEntry(
            points=points, cats=cats, src_idx=src_idx, D=D,
            fingerprint=fingerprint, built_at=now, last_use=now,
        )
        self._entries[key] = e
        if self.max_entries is not None:
            while len(self._entries) > self.max_entries:
                lru = min(self._entries, key=lambda k: self._entries[k].last_use)
                del self._entries[lru]
                self.stats.evictions += 1
        return e

    def invalidate(self, key: CacheKey) -> None:
        if key in self._entries:
            del self._entries[key]
            self.stats.invalidations += 1

    def __len__(self) -> int:
        return len(self._entries)
