"""Coreset/distance-matrix cache for the diversity serving stack.

One entry per ``(MatroidSpec, tau, metric)`` configuration: the compacted,
metric-normalized coreset buffer plus its pairwise distance matrix (built by
the Pallas pdist kernel via ``core.final_solve.coreset_distance_matrix``).
An entry is keyed additionally by a *fingerprint* of the coreset contents —
ingestion that leaves the coreset unchanged (the common steady-state case:
most stream points become non-delegates) keeps the matrix warm; the entry is
rebuilt only when the coreset actually changed.

Many tenants share one ``DistanceCache`` — one entry per
``(spec, tau, metric)`` key — so the cache is bounded: ``max_entries`` caps
the entry count with least-recently-used eviction (per-key last-use
ordering) and ``ttl_s`` expires entries that have not been *rebuilt* within
the window, whichever comes first. Both are off by default. The full
expiry sweep is *lazy*: it runs on insert, and only once the earliest
possible expiry deadline has actually passed (tracked in ``_next_sweep``) —
a busy cache with nothing expiring pays per-key checks only, never a full
scan per operation. Under capacity pressure expired entries are swept
before any live entry is LRU-evicted.

All public operations are thread-safe (the serving frontend answers
queries from many threads while the ingest worker publishes epochs).

``CacheStats`` is the observability hook: the tests, serve_bench, and
``QueryFrontend.stats()`` use it to assert "no pdist recomputation on the
warm path" and to watch hit/miss/eviction/expiry rates per cache.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, NamedTuple, Optional

import numpy as np

from ...core.final_solve import coreset_distance_matrix
from ...core.matroid import MatroidSpec


class CacheKey(NamedTuple):
    spec: MatroidSpec
    tau: int
    metric: str


@dataclasses.dataclass
class CoresetEntry:
    """Compacted coreset (valid rows only, buffer order) + its distances."""

    points: np.ndarray  # f32[m, d] metric-normalized
    cats: np.ndarray  # int32[m, gamma]
    src_idx: np.ndarray  # int64[m] global stream indices
    D: np.ndarray  # f32[m, m] pairwise Euclidean distances
    fingerprint: int
    built_at: float = 0.0  # clock() at build time (TTL anchor)
    last_use: float = 0.0  # clock() at last lookup hit (LRU ordering)

    @property
    def size(self) -> int:
        return int(self.src_idx.shape[0])


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0  # pdist matrix constructions (the expensive part)
    invalidations: int = 0
    evictions: int = 0  # max_entries LRU evictions
    expirations: int = 0  # TTL expiries
    sweeps: int = 0  # full expiry scans actually run (lazy: deadline-gated)

    def snapshot(self) -> dict:
        """Plain-dict copy (what ``QueryFrontend.stats()``/serve_bench
        record — counters keep mutating underneath)."""
        return dataclasses.asdict(self)


def coreset_fingerprint(valid: np.ndarray, src_idx: np.ndarray) -> int:
    """Cheap content hash: the coreset is determined by (valid, src_idx)
    since points/cats are copies of the stream rows named by src_idx.

    The serving runtime now fingerprints on-device without the host pull
    (``core.streaming.epoch_fingerprint``); this host-side form remains for
    callers that already hold the buffers.
    """
    return hash((valid.tobytes(), src_idx.tobytes()))


class DistanceCache:
    """Maps CacheKey -> CoresetEntry, invalidating on fingerprint change,
    with optional max-entries LRU eviction and per-entry TTL expiry."""

    def __init__(
        self,
        build_fn: Callable[[np.ndarray], np.ndarray] = coreset_distance_matrix,
        *,
        max_entries: Optional[int] = None,
        ttl_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self._build_fn = build_fn
        self._entries: dict[CacheKey, CoresetEntry] = {}
        self.max_entries = max_entries
        self.ttl_s = ttl_s
        self._clock = clock
        self._mu = threading.RLock()
        # earliest instant at which *any* entry can expire: a full sweep
        # before this is provably a no-op, so inserts skip it (lazy sweep)
        self._next_sweep = math.inf
        self.stats = CacheStats()

    def _expired(self, e: CoresetEntry) -> bool:
        return (
            self.ttl_s is not None
            and self._clock() - e.built_at > self.ttl_s
        )

    def _sweep_expired(self) -> None:
        """Drop every expired entry — without this, a ttl_s-only cache would
        keep abandoned tenants' O(m^2) matrices forever, since per-key
        expiry in lookup() only fires for keys that are queried again.

        Deadline-gated: callers consult ``_next_sweep`` first, so the scan
        runs only when some entry has actually aged past the TTL (or under
        capacity pressure), not on every insert.
        """
        if self.ttl_s is None:
            return
        self.stats.sweeps += 1
        for k in [k for k, e in self._entries.items() if self._expired(e)]:
            del self._entries[k]
            self.stats.expirations += 1
        self._next_sweep = (
            min(e.built_at for e in self._entries.values()) + self.ttl_s
            if self._entries
            else math.inf
        )

    def lookup(self, key: CacheKey, fingerprint: int) -> Optional[CoresetEntry]:
        with self._mu:
            e = self._entries.get(key)
            if e is not None and self._expired(e):
                self.stats.expirations += 1
                del self._entries[key]
                e = None
            if e is not None and e.fingerprint == fingerprint:
                self.stats.hits += 1
                e.last_use = self._clock()
                return e
            if e is not None:
                self.stats.invalidations += 1
                del self._entries[key]
            self.stats.misses += 1
            return None

    def build(
        self,
        key: CacheKey,
        points: np.ndarray,
        cats: np.ndarray,
        src_idx: np.ndarray,
        fingerprint: int,
    ) -> CoresetEntry:
        # the O(m^2) matrix is computed OUTSIDE the cache lock: a cold
        # tenant's build must not block every other tenant's warm lookup.
        # Two threads racing the same (key, fingerprint) both pay the
        # build and the later insert wins — correct (same inputs, same
        # matrix) and honest (both builds counted).
        D = self._build_fn(points)
        with self._mu:
            self.stats.builds += 1
            now = self._clock()
            if now >= self._next_sweep:
                self._sweep_expired()
            e = CoresetEntry(
                points=points, cats=cats, src_idx=src_idx, D=D,
                fingerprint=fingerprint, built_at=now, last_use=now,
            )
            self._entries[key] = e
            if self.ttl_s is not None:
                self._next_sweep = min(self._next_sweep, now + self.ttl_s)
            if self.max_entries is not None:
                if len(self._entries) > self.max_entries:
                    # capacity pressure: reclaim dead entries before
                    # evicting a live tenant's matrix
                    self._sweep_expired()
                while len(self._entries) > self.max_entries:
                    lru = min(
                        self._entries, key=lambda k: self._entries[k].last_use
                    )
                    del self._entries[lru]
                    self.stats.evictions += 1
            return e

    def invalidate(self, key: CacheKey) -> None:
        with self._mu:
            if key in self._entries:
                del self._entries[key]
                self.stats.invalidations += 1

    def __len__(self) -> int:
        return len(self._entries)
