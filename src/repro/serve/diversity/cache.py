"""Coreset/distance-matrix cache for the diversity service.

One entry per ``(MatroidSpec, tau, metric)`` configuration: the compacted,
metric-normalized coreset buffer plus its pairwise distance matrix (built by
the Pallas pdist kernel via ``core.final_solve.coreset_distance_matrix``).
An entry is keyed additionally by a *fingerprint* of the coreset contents —
ingestion that leaves the coreset unchanged (the common steady-state case:
most stream points become non-delegates) keeps the matrix warm; the entry is
rebuilt only when the coreset actually changed.

``CacheStats`` is the observability hook the tests and serve_bench use to
assert "no pdist recomputation on the warm path".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import numpy as np

from ...core.final_solve import coreset_distance_matrix
from ...core.matroid import MatroidSpec


class CacheKey(NamedTuple):
    spec: MatroidSpec
    tau: int
    metric: str


@dataclasses.dataclass
class CoresetEntry:
    """Compacted coreset (valid rows only, buffer order) + its distances."""

    points: np.ndarray  # f32[m, d] metric-normalized
    cats: np.ndarray  # int32[m, gamma]
    src_idx: np.ndarray  # int64[m] global stream indices
    D: np.ndarray  # f32[m, m] pairwise Euclidean distances
    fingerprint: int

    @property
    def size(self) -> int:
        return int(self.src_idx.shape[0])


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    builds: int = 0  # pdist matrix constructions (the expensive part)
    invalidations: int = 0


def coreset_fingerprint(valid: np.ndarray, src_idx: np.ndarray) -> int:
    """Cheap content hash: the coreset is determined by (valid, src_idx)
    since points/cats are copies of the stream rows named by src_idx."""
    return hash((valid.tobytes(), src_idx.tobytes()))


class DistanceCache:
    """Maps CacheKey -> CoresetEntry, invalidating on fingerprint change."""

    def __init__(
        self,
        build_fn: Callable[[np.ndarray], np.ndarray] = coreset_distance_matrix,
    ):
        self._build_fn = build_fn
        self._entries: dict[CacheKey, CoresetEntry] = {}
        self.stats = CacheStats()

    def lookup(self, key: CacheKey, fingerprint: int) -> Optional[CoresetEntry]:
        e = self._entries.get(key)
        if e is not None and e.fingerprint == fingerprint:
            self.stats.hits += 1
            return e
        if e is not None:
            self.stats.invalidations += 1
            del self._entries[key]
        self.stats.misses += 1
        return None

    def build(
        self,
        key: CacheKey,
        points: np.ndarray,
        cats: np.ndarray,
        src_idx: np.ndarray,
        fingerprint: int,
    ) -> CoresetEntry:
        D = self._build_fn(points)
        self.stats.builds += 1
        e = CoresetEntry(
            points=points, cats=cats, src_idx=src_idx, D=D,
            fingerprint=fingerprint,
        )
        self._entries[key] = e
        return e

    def invalidate(self, key: CacheKey) -> None:
        if key in self._entries:
            del self._entries[key]
            self.stats.invalidations += 1

    def __len__(self) -> int:
        return len(self._entries)
