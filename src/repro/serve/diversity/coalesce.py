"""Parallel micro-batch coalescing for the query frontend.

At high concurrency, every ``query_batch`` call pays registry dispatch,
epoch acquire, cache fetch, and a device launch *per call* — the costs
the paper's coreset construction made small enough to amortize. The
coalescer amortizes them: concurrent calls from any number of threads
and tenants land in bounded-window queues, a small dispatcher pool
drains them into groups, and each group executes as merged pow-2-
bucketed vmapped solves — stacked ACROSS tenants into one device
dispatch when the engine supports it (``core/solvers/stacked.py``) —
fanning results back to each blocked caller, bit-identical to what the
caller would have computed alone.

Topology (PR 10 — previously one dispatcher thread did everything):

* **sharded assembly** — calls hash by tenant name onto one of
  ``CoalesceConfig.dispatchers`` shards (default ``min(4, cpu)``), each
  with its own queue + window-assembly thread. Same tenant, same shard:
  per-tenant FIFO holds by construction through assembly.
* **shared dispatch stage** — assembled windows split into
  ``(tenant, engine, min_epoch)`` sub-groups and land in one shared
  ready deque. Any dispatcher thread grabs every ready sub whose tenant
  is not currently executing (a busy set — so two windows of one tenant
  can never reorder or run concurrently) and executes the grab as one
  wave: subs agreeing on ``(engine, min_epoch)`` become a single
  cross-tenant stacked solve. Work conservation: a grab that comes back
  empty only leaves subs whose tenants are busy, and every busy-holder
  re-grabs after it releases — nothing strands.
* **adaptive window** — the fixed 300 µs window became a Little's-law
  controller (``AdaptiveWindow``): the target in-window delay is the
  cost model's estimate for the solve the window is building (waiting
  about one solve-time doubles the batch for at worst ~2x latency —
  the classic batching sweet spot), *widened* when backlog shows
  arrivals outrunning service (``L = λW``: a standing queue means W is
  too small for the observed λ) and *collapsed to zero* when the
  observed arrival rate λ could not deliver a single companion even at
  the widest window (``λ · window_max_s < 1``) — an idle or lightly
  loaded frontend dispatches immediately instead of idling 300 µs.
  ``window_min_s``/``window_max_s`` clamp the controller; a deadline
  caller's cap (``deadline_window_frac`` of its budget) still bounds
  its group's wait — the window can shave a deadline, never blow it.

Groups cap at ``max_calls`` callers / ``max_queries`` queries, and a
window still closes early the moment every in-flight caller is already
parked somewhere in the pool (nobody new can be en route). A solo
caller never enters the queue at all: the frontend bypasses the
coalescer entirely when it is the only active caller.

Observability: the aggregate ``serve.coalesce.*`` series of PR 8 stay
(queue_wait_s / group_calls / group_queries histograms, queue_depth
gauge, coalesced/groups counters), joined by per-dispatcher
``serve.coalesce.{groups,calls,queue_depth}{dispatcher=dN}``, a
pool-wide ``serve.coalesce.backlog`` gauge, the live
``serve.coalesce.window_s`` gauge, and the stacked-solve counters the
frontend emits (``serve.coalesce.stacked_{solves,rows}``,
``stacked_tenants`` histogram). ``stats()`` aggregates across the pool
and embeds the controller's window-size-over-time trace.
"""
from __future__ import annotations

import dataclasses
import math
import os
import threading
import time
import zlib
from collections import deque
from typing import Optional, Sequence


def _default_dispatchers() -> int:
    return max(1, min(4, os.cpu_count() or 1))


@dataclasses.dataclass(frozen=True)
class CoalesceConfig:
    """Tuning knobs for the micro-batch window (see module docstring).

    ``window_s`` is the fixed window when ``adaptive=False`` (the PR 8
    semantics) and the controller's cold-start solve estimate before
    the cost model has fed it anything. ``dispatchers=0`` sizes the
    pool to ``min(4, cpu)``.
    """

    window_s: float = 300e-6
    max_calls: int = 64
    max_queries: int = 512
    # fraction of a deadline caller's remaining budget it may spend
    # waiting in the window (the rest is reserved for the solve itself)
    deadline_window_frac: float = 0.25
    enabled: bool = True
    dispatchers: int = 0  # 0 -> min(4, cpu)
    adaptive: bool = True
    window_min_s: float = 50e-6
    window_max_s: float = 2e-3

    def pool_size(self) -> int:
        return (
            int(self.dispatchers)
            if self.dispatchers and self.dispatchers > 0
            else _default_dispatchers()
        )


class PendingCall:
    """One caller parked in the window (internal).

    ``dispatch_by`` is the caller's absolute deadline-derived cap on
    in-window waiting (``+inf`` without a deadline); the window's own
    open duration is the assembling dispatcher's business (adaptive).
    """

    __slots__ = (
        "tenant", "queries", "engine", "min_epoch", "deadline",
        "enq_t", "dispatch_by", "done", "results", "error",
        "specs", "degraded", "from_cache",
    )

    def __init__(self, tenant, queries, *, engine, min_epoch, deadline,
                 enq_t, dispatch_by):
        self.tenant = tenant
        self.queries = queries
        self.engine = engine
        self.min_epoch = min_epoch
        self.deadline = deadline  # absolute perf_counter or None
        self.enq_t = enq_t
        self.dispatch_by = dispatch_by
        self.done = threading.Event()
        self.results = None
        self.error: Optional[BaseException] = None
        self.specs = None
        self.degraded = None
        self.from_cache = False


class AdaptiveWindow:
    """Little's-law window controller.

    State: an EMA of the call inter-arrival time (λ = 1/IAT, decayed by
    silence: the effective IAT is never shorter than the time since the
    last arrival) and an EMA of the cost model's solve estimates for
    dispatched groups (fed by the frontend at each merged launch).

    ``current(backlog)`` returns the window the assembling dispatcher
    should hold open right now:

    * idle collapse — if ``λ · window_max_s < 1``, even the widest
      legal window would not catch one companion call: return 0 and
      dispatch immediately;
    * target — ``W* = clamp(S, window_min_s, window_max_s)`` where S is
      the solve-estimate EMA: waiting about one solve-time doubles the
      batch at worst-equal latency;
    * queue growth — a standing backlog means arrivals outrun service
      at the current W (Little: L = λW); widen by
      ``1 + backlog / backlog_norm`` so the batch grows until service
      catches up, still clamped at ``window_max_s``.

    Every evaluation appends to a bounded (t, window) trace ring — the
    series the bench uploads so window dynamics are inspectable.
    """

    _ALPHA = 0.25  # EMA weight of one new arrival/solve observation
    _BACKLOG_NORM = 8.0  # backlog calls per +100% widening
    TRACE = 512

    def __init__(self, config: CoalesceConfig, clock=time.perf_counter):
        self.config = config
        self._clock = clock
        self._mu = threading.Lock()
        self._iat: Optional[float] = None  # EMA inter-arrival seconds
        self._last_arrival: Optional[float] = None
        self._solve_s: Optional[float] = None  # EMA solve estimate
        self._trace: deque = deque(maxlen=self.TRACE)

    def observe_arrival(self) -> None:
        now = self._clock()
        with self._mu:
            last = self._last_arrival
            if last is not None:
                dt = max(now - last, 1e-9)
                self._iat = (
                    dt if self._iat is None
                    else self._iat + self._ALPHA * (dt - self._iat)
                )
            self._last_arrival = now

    def observe_solve(self, est_s: float) -> None:
        """Feed one dispatched group's cost-model solve estimate."""
        if not (est_s >= 0.0):  # NaN/negative: refuse quietly
            return
        with self._mu:
            self._solve_s = (
                float(est_s) if self._solve_s is None
                else self._solve_s + self._ALPHA * (est_s - self._solve_s)
            )

    def rate_hz(self) -> float:
        """Current silence-decayed arrival-rate estimate."""
        now = self._clock()
        with self._mu:
            return self._rate_locked(now)

    def _rate_locked(self, now: float) -> float:
        if self._iat is None or self._last_arrival is None:
            return 0.0
        iat_eff = max(self._iat, now - self._last_arrival)
        return 1.0 / max(iat_eff, 1e-9)

    def current(self, backlog: int = 0) -> float:
        """Window seconds the assembler should hold open right now."""
        cfg = self.config
        if not cfg.adaptive:
            w = cfg.window_s
            with self._mu:
                self._trace.append((self._clock(), w))
            return w
        now = self._clock()
        with self._mu:
            lam = self._rate_locked(now)
            if lam * cfg.window_max_s < 1.0:
                w = 0.0  # idle: no companion expected, dispatch now
            else:
                s = self._solve_s if self._solve_s is not None else cfg.window_s
                target = min(max(s, cfg.window_min_s), cfg.window_max_s)
                w = target * (1.0 + max(0, backlog) / self._BACKLOG_NORM)
                w = min(w, cfg.window_max_s)
            self._trace.append((now, w))
            return w

    def snapshot(self) -> dict:
        now = self._clock()
        with self._mu:
            return {
                "adaptive": self.config.adaptive,
                "rate_hz": self._rate_locked(now),
                "interarrival_s": self._iat,
                "solve_est_s": self._solve_s,
                "window_s": self._trace[-1][1] if self._trace else 0.0,
                "trace": [[t, w] for t, w in self._trace],
            }


class _Shard:
    """One dispatcher's assembly queue (tenant-hash sharded)."""

    __slots__ = ("idx", "q", "cv", "thread")

    def __init__(self, idx: int):
        self.idx = idx
        self.q: deque[PendingCall] = deque()
        self.cv = threading.Condition()
        self.thread: Optional[threading.Thread] = None


class _DispatchStage:
    """Shared hand-off between sharded window assembly and solve
    execution. Items are ``(tenant_name, key, sub)`` in push order; a
    busy set keyed by tenant name guarantees at most one executor per
    tenant at a time, which (with FIFO ready order) preserves per-tenant
    execution order across windows while letting any free dispatcher
    stack whatever mix of tenants is ready."""

    def __init__(self):
        self._mu = threading.Lock()
        self._ready: deque = deque()
        self._busy: set = set()

    def push(self, items: Sequence[tuple]) -> None:
        with self._mu:
            self._ready.extend(items)

    def grab(self) -> tuple[list, set]:
        """Take every ready sub whose tenant is not executing, marking
        those tenants busy. Two subs of one (non-busy) tenant are taken
        together, in order — the executor merges them."""
        with self._mu:
            taken, names = [], set()
            keep: deque = deque()
            for item in self._ready:
                name = item[0]
                if name in self._busy:
                    keep.append(item)
                else:
                    taken.append(item)
                    names.add(name)
            self._ready = keep
            self._busy |= names
            return taken, names

    def release(self, names: set) -> None:
        with self._mu:
            self._busy -= names

    def depth(self) -> int:
        with self._mu:
            return len(self._ready)


class Coalescer:
    """Sharded bounded-window queues + a dispatcher pool in front of a
    frontend. Shard threads start lazily on the first call they see, so
    frontends that never see concurrency never own a thread."""

    def __init__(self, frontend, config: CoalesceConfig):
        self.frontend = frontend
        self.config = config
        reg = frontend.registry
        self._m_queue_wait = reg.histogram("serve.coalesce.queue_wait_s")
        self._m_group_calls = reg.histogram("serve.coalesce.group_calls")
        self._m_group_queries = reg.histogram(
            "serve.coalesce.group_queries"
        )
        self._m_depth = reg.gauge("serve.coalesce.queue_depth")
        self._g_backlog = reg.gauge("serve.coalesce.backlog")
        self._g_window = reg.gauge("serve.coalesce.window_s")
        self._c_coalesced = reg.counter("serve.coalesce.coalesced")
        self._c_groups = reg.counter("serve.coalesce.groups")
        n = config.pool_size()
        self._shards = [_Shard(i) for i in range(n)]
        self._sh_groups = [
            reg.counter("serve.coalesce.groups", dispatcher=f"d{i}")
            for i in range(n)
        ]
        self._sh_calls = [
            reg.counter("serve.coalesce.calls", dispatcher=f"d{i}")
            for i in range(n)
        ]
        self._sh_depth = [
            reg.gauge("serve.coalesce.queue_depth", dispatcher=f"d{i}")
            for i in range(n)
        ]
        self.window = AdaptiveWindow(config)
        self._stage = _DispatchStage()
        # calls owned by the coalescer pool-wide: from submit-enqueue
        # until just before their done event fires. The early-close
        # heuristic compares it against the frontend's active-call count.
        self._parked = 0
        self._pmu = threading.Lock()
        self._close_mu = threading.Lock()
        self._closed = False

    # ------------------------------------------------------------------
    # caller side
    # ------------------------------------------------------------------

    @property
    def backlog(self) -> int:
        """Pool-wide queued (not yet assembled) call count."""
        return sum(len(sh.q) for sh in self._shards)

    @property
    def parked(self) -> int:
        """Calls the pool currently owns (queued, staged, or solving)."""
        return self._parked

    def _shard_for(self, tenant_name: str) -> _Shard:
        # stable hash: per-tenant FIFO requires the same tenant to land
        # on the same shard in every process (hash() is salted)
        h = zlib.crc32(tenant_name.encode("utf-8", "surrogatepass"))
        return self._shards[h % len(self._shards)]

    def submit(
        self, tenant, queries: Sequence, *, engine: str,
        min_epoch: Optional[int], deadline_s: Optional[float],
    ):
        """Park the call in its tenant's shard; block until its group
        executed. Returns the call's results (same list the direct path
        returns) or re-raises whatever its group's execution raised."""
        now = time.perf_counter()
        cfg = self.config
        if deadline_s is None:
            deadline = None
            cap = math.inf
        else:
            deadline = now + deadline_s
            cap = now + max(0.0, deadline_s) * cfg.deadline_window_frac
        p = PendingCall(
            tenant, queries, engine=engine, min_epoch=min_epoch,
            deadline=deadline, enq_t=now, dispatch_by=cap,
        )
        self.window.observe_arrival()
        sh = self._shard_for(tenant.name)
        with sh.cv:
            if self._closed:
                raise RuntimeError("frontend is closed")
            sh.q.append(p)
            with self._pmu:
                self._parked += 1
            self._sh_depth[sh.idx].set(len(sh.q))
            depth = self.backlog
            self._m_depth.set(depth)
            self._g_backlog.set(depth)
            if sh.thread is None:
                sh.thread = threading.Thread(
                    target=self._loop,
                    args=(sh,),
                    name=f"repro-coalesce-{sh.idx}",
                    daemon=True,
                )
                sh.thread.start()
            sh.cv.notify_all()
        p.done.wait()
        if p.error is not None:
            raise p.error
        return p.results

    def drain(self) -> list:
        """Stop the pool and hand back everything still queued on any
        shard — WITHOUT failing it. The callers stay blocked on their
        events; whoever drained (``ReplicaSet`` failover) owns
        re-dispatching each returned ``PendingCall`` on the new primary
        and setting ``results``/``error`` + ``done``. Calls a dispatcher
        already pulled into a window keep executing here and complete
        normally. After ``drain()`` the coalescer is closed: new
        submits raise."""
        with self._close_mu:
            self._closed = True
            pending: list[PendingCall] = []
            for sh in self._shards:
                with sh.cv:
                    pending.extend(sh.q)
                    sh.q.clear()
                    self._sh_depth[sh.idx].set(0)
                    sh.cv.notify_all()
            self._m_depth.set(0)
            self._g_backlog.set(0)
            self._join_threads()
            return pending

    def close(self) -> None:
        """Stop the pool; fail anything still queued on any shard (the
        callers get the RuntimeError) rather than leaving them blocked.
        Idempotent, including with dispatchers mid-solve: in-flight
        groups complete and release their callers, queued calls on
        every shard fail loudly, none hang."""
        with self._close_mu:
            self._closed = True
            pending: list[PendingCall] = []
            for sh in self._shards:
                with sh.cv:
                    pending.extend(sh.q)
                    sh.q.clear()
                    self._sh_depth[sh.idx].set(0)
                    sh.cv.notify_all()
            self._m_depth.set(0)
            self._g_backlog.set(0)
            for p in pending:
                p.error = RuntimeError(
                    "frontend closed while call was queued"
                )
                self._finish(p)
            self._join_threads()

    def _join_threads(self) -> None:
        me = threading.current_thread()
        for sh in self._shards:
            t = sh.thread
            if t is not None and t is not me:
                t.join(timeout=5.0)

    def stats(self) -> dict:
        reg = self.frontend.registry
        per = {
            f"d{sh.idx}": {
                "queue_depth": len(sh.q),
                "groups": self._sh_groups[sh.idx].value,
                "calls": self._sh_calls[sh.idx].value,
            }
            for sh in self._shards
        }
        return {
            "queue_depth": self.backlog,
            "staged": self._stage.depth(),
            "parked": self._parked,
            "dispatchers": len(self._shards),
            "per_dispatcher": per,
            "groups": self._c_groups.value,
            "coalesced_calls": self._c_coalesced.value,
            "stacked_solves": reg.counter(
                "serve.coalesce.stacked_solves"
            ).value,
            "stacked_rows": reg.counter(
                "serve.coalesce.stacked_rows"
            ).value,
            "group_calls_p95": self._m_group_calls.quantile(0.95),
            "queue_wait_p95_s": self._m_queue_wait.quantile(0.95),
            "window": self.window.snapshot(),
            "window_s": self.config.window_s,
            "adaptive": self.config.adaptive,
            "max_calls": self.config.max_calls,
            "max_queries": self.config.max_queries,
        }

    # ------------------------------------------------------------------
    # dispatcher side
    # ------------------------------------------------------------------

    def _finish(self, p: PendingCall) -> None:
        with self._pmu:
            self._parked -= 1
        p.done.set()

    def _collect(self, sh: _Shard) -> list[PendingCall]:
        """Block for the shard's next group: first waiting call +
        everything that arrives inside the adaptive window, closing
        early when every active caller is already parked pool-wide or
        the size caps hit. Deadline callers' caps bound the wait."""
        cfg = self.config
        group: list[PendingCall] = []
        n_queries = 0
        with sh.cv:
            while not sh.q and not self._closed:
                sh.cv.wait(timeout=0.1)
            if self._closed and not sh.q:
                return group
            t_open = time.perf_counter()
            while True:
                while (
                    sh.q
                    and len(group) < cfg.max_calls
                    and n_queries < cfg.max_queries
                ):
                    p = sh.q.popleft()
                    group.append(p)
                    n_queries += len(p.queries)
                self._sh_depth[sh.idx].set(len(sh.q))
                depth = self.backlog
                self._m_depth.set(depth)
                self._g_backlog.set(depth)
                if (
                    self._closed
                    or len(group) >= cfg.max_calls
                    or n_queries >= cfg.max_queries
                ):
                    break
                # parked callers (anywhere in the pool) stay "active"
                # until their results fan back, so active <= parked
                # means nobody new can be en route: close the window
                # early instead of idling it out
                if self.frontend.active_calls() <= self._parked:
                    break
                w = self.window.current(backlog=depth)
                self._g_window.set(w)
                dispatch_by = min(
                    t_open + w, min(p.dispatch_by for p in group)
                )
                now = time.perf_counter()
                if now >= dispatch_by:
                    break
                # bounded nap: re-evaluate the adaptive window as
                # arrivals/backlog move it while this group waits
                sh.cv.wait(timeout=min(dispatch_by - now, 0.05))
        return group

    def _loop(self, sh: _Shard) -> None:
        while True:
            group = self._collect(sh)
            if not group:
                if self._closed:
                    return
                continue
            now = time.perf_counter()
            for p in group:
                self._m_queue_wait.observe(now - p.enq_t)
            self._m_group_calls.observe(len(group))
            self._m_group_queries.observe(
                sum(len(p.queries) for p in group)
            )
            self._sh_calls[sh.idx].inc(len(group))
            if len(group) > 1:
                self._c_coalesced.inc(len(group))
            # executable sub-groups: only calls agreeing on
            # (tenant, engine, min_epoch) share an epoch acquire + solve
            subs: dict[tuple, list[PendingCall]] = {}
            for p in group:
                key = (p.tenant.name, p.engine, p.min_epoch)
                subs.setdefault(key, []).append(p)
            self._stage.push(
                [(key[0], key, sub) for key, sub in subs.items()]
            )
            self._drain_stage(sh)

    def _drain_stage(self, sh: _Shard) -> None:
        """Execute ready subs until a grab comes back empty. Any
        dispatcher that pushed drains; whichever one grabs a mixed set
        executes it as one stacked wave."""
        while True:
            taken, names = self._stage.grab()
            if not taken:
                return
            try:
                self._execute(sh, taken)
            finally:
                self._stage.release(names)

    def _execute(self, sh: _Shard, taken: list) -> None:
        """One execution wave: regroup grabbed subs by
        ``(engine, min_epoch)`` (re-merging multiple windows of one
        tenant, in ready order), solve each — stacked across tenants
        when >1 tenant shares the key — and release every caller."""
        waves: dict[tuple, dict[str, list[PendingCall]]] = {}
        for name, key, sub in taken:
            _tn, engine, min_epoch = key
            by_tenant = waves.setdefault((engine, min_epoch), {})
            by_tenant.setdefault(name, []).extend(sub)
        for (engine, min_epoch), by_tenant in waves.items():
            subs = list(by_tenant.values())
            self._c_groups.inc(len(subs))
            self._sh_groups[sh.idx].inc(len(subs))
            calls = [p for sub in subs for p in sub]
            try:
                if len(subs) == 1:
                    self.frontend._solve_coalesced(subs[0])
                else:
                    self.frontend._solve_coalesced_stacked(subs)
            except BaseException as e:  # noqa: BLE001 — fan the
                # failure back to every caller; the dispatcher must
                # survive any single wave's error
                for p in calls:
                    p.error = e
            finally:
                for p in calls:
                    self._finish(p)
