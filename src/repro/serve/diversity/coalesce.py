"""Adaptive micro-batch coalescing for the query frontend.

At high concurrency, every ``query_batch`` call pays registry dispatch,
epoch acquire, cache fetch, and a device launch *per call* — the costs
the paper's coreset construction made small enough to amortize. The
coalescer amortizes them: concurrent calls from any number of threads
and tenants land in one bounded-window queue, a single dispatcher thread
drains them into groups, and each group executes as merged pow-2-
bucketed vmapped solves (one ``(engine, k-bucket)`` launch per group,
routed by the calibrated cost model at the *merged* batch size), fanning
results back to each blocked caller — bit-identical to what the caller
would have computed alone, because only host-parity engines are merged
and per-row vmap results are independent of batch composition.

Window semantics (fairness = strict FIFO arrival order):

* a call waits at most ``window_s`` (default 300 µs) for company; the
  window closes *early* the moment every in-flight caller is already
  represented in the group — a solo caller never idles out the window
  (and in fact never enters the queue at all: the frontend bypasses the
  coalescer entirely when it is the only active caller, keeping the
  single-threaded path — spans, trace IDs, latency — byte-for-byte the
  uncoalesced one);
* a deadline caller's willingness to wait is ``deadline_window_frac`` of
  its remaining budget, capped by ``window_s`` — the window can shave a
  deadline, never blow it; admission (degrade/shed) then applies per
  caller against whatever budget remains at dispatch;
* groups cap at ``max_calls`` callers / ``max_queries`` queries so one
  burst cannot build an unboundedly large device launch.

Only calls agreeing on ``(tenant, engine, min_epoch)`` merge into one
executed group: distinct ``min_epoch`` values must not share an epoch
acquire (one may need to wait for a future publish), and distinct
tenants solve on different cached matrices (their calls still share the
dispatcher drain, which is where the per-call overhead lived).

Observability: ``serve.coalesce.queue_wait_s`` / ``group_calls`` /
``group_queries`` histograms, a live ``serve.coalesce.queue_depth``
gauge, and ``serve.coalesce.{coalesced,solo}`` counters; each executed
group runs under a ``coalesce_group`` span.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class CoalesceConfig:
    """Tuning knobs for the micro-batch window (see module docstring)."""

    window_s: float = 300e-6
    max_calls: int = 64
    max_queries: int = 512
    # fraction of a deadline caller's remaining budget it may spend
    # waiting in the window (the rest is reserved for the solve itself)
    deadline_window_frac: float = 0.25
    enabled: bool = True


class PendingCall:
    """One caller parked in the window (internal)."""

    __slots__ = (
        "tenant", "queries", "engine", "min_epoch", "deadline",
        "enq_t", "dispatch_by", "done", "results", "error",
        "specs", "degraded", "from_cache",
    )

    def __init__(self, tenant, queries, *, engine, min_epoch, deadline,
                 enq_t, dispatch_by):
        self.tenant = tenant
        self.queries = queries
        self.engine = engine
        self.min_epoch = min_epoch
        self.deadline = deadline  # absolute perf_counter or None
        self.enq_t = enq_t
        self.dispatch_by = dispatch_by
        self.done = threading.Event()
        self.results = None
        self.error: Optional[BaseException] = None
        self.specs = None
        self.degraded = None
        self.from_cache = False


class Coalescer:
    """Bounded-window queue + dispatcher thread in front of a frontend.

    The dispatcher thread starts lazily on the first submitted call, so
    frontends that never see concurrency never own a thread.
    """

    def __init__(self, frontend, config: CoalesceConfig):
        self.frontend = frontend
        self.config = config
        reg = frontend.registry
        self._m_queue_wait = reg.histogram("serve.coalesce.queue_wait_s")
        self._m_group_calls = reg.histogram("serve.coalesce.group_calls")
        self._m_group_queries = reg.histogram(
            "serve.coalesce.group_queries"
        )
        self._m_depth = reg.gauge("serve.coalesce.queue_depth")
        self._c_coalesced = reg.counter("serve.coalesce.coalesced")
        self._c_groups = reg.counter("serve.coalesce.groups")
        self._q: deque[PendingCall] = deque()
        self._cv = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._closed = False

    # ------------------------------------------------------------------
    # caller side
    # ------------------------------------------------------------------

    @property
    def backlog(self) -> int:
        return len(self._q)

    def submit(
        self, tenant, queries: Sequence, *, engine: str,
        min_epoch: Optional[int], deadline_s: Optional[float],
    ):
        """Park the call in the window; block until its group executed.
        Returns the call's results (same list the direct path returns) or
        re-raises whatever its group's execution raised."""
        now = time.perf_counter()
        cfg = self.config
        if deadline_s is None:
            deadline = None
            wait = cfg.window_s
        else:
            deadline = now + deadline_s
            wait = min(
                cfg.window_s,
                max(0.0, deadline_s) * cfg.deadline_window_frac,
            )
        p = PendingCall(
            tenant, queries, engine=engine, min_epoch=min_epoch,
            deadline=deadline, enq_t=now, dispatch_by=now + wait,
        )
        with self._cv:
            if self._closed:
                raise RuntimeError("frontend is closed")
            self._q.append(p)
            self._m_depth.set(len(self._q))
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop,
                    name="repro-coalesce",
                    daemon=True,
                )
                self._thread.start()
            self._cv.notify_all()
        p.done.wait()
        if p.error is not None:
            raise p.error
        return p.results

    def drain(self) -> list:
        """Stop the dispatcher and hand back everything still parked in
        the window — WITHOUT failing it. The callers stay blocked on
        their events; whoever drained (``ReplicaSet`` failover) owns
        re-dispatching each returned ``PendingCall`` on the new primary
        and setting ``results``/``error`` + ``done``. After ``drain()``
        the coalescer is closed: new submits raise."""
        with self._cv:
            self._closed = True
            pending = list(self._q)
            self._q.clear()
            self._m_depth.set(0)
            self._cv.notify_all()
            t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout=5.0)
        return pending

    def close(self) -> None:
        """Stop the dispatcher; fail anything still parked in the queue
        (callers get the RuntimeError) rather than leaving them blocked."""
        with self._cv:
            self._closed = True
            pending = list(self._q)
            self._q.clear()
            self._m_depth.set(0)
            self._cv.notify_all()
            t = self._thread
        for p in pending:
            p.error = RuntimeError("frontend closed while call was queued")
            p.done.set()
        if t is not None and t is not threading.current_thread():
            t.join(timeout=5.0)

    def stats(self) -> dict:
        return {
            "queue_depth": len(self._q),
            "groups": self._c_groups.value,
            "coalesced_calls": self._c_coalesced.value,
            "group_calls_p95": self._m_group_calls.quantile(0.95),
            "queue_wait_p95_s": self._m_queue_wait.quantile(0.95),
            "window_s": self.config.window_s,
            "max_calls": self.config.max_calls,
            "max_queries": self.config.max_queries,
        }

    # ------------------------------------------------------------------
    # dispatcher side
    # ------------------------------------------------------------------

    def _collect(self) -> list[PendingCall]:
        """Block for the next group: first waiting call + everything that
        arrives before the group's earliest ``dispatch_by``, closing
        early when all active callers are represented or the size caps
        hit."""
        cfg = self.config
        group: list[PendingCall] = []
        n_queries = 0
        with self._cv:
            while not self._q and not self._closed:
                self._cv.wait(timeout=0.1)
            if self._closed and not self._q:
                return group
            while True:
                while (
                    self._q
                    and len(group) < cfg.max_calls
                    and n_queries < cfg.max_queries
                ):
                    p = self._q.popleft()
                    group.append(p)
                    n_queries += len(p.queries)
                self._m_depth.set(len(self._q))
                if (
                    self._closed
                    or len(group) >= cfg.max_calls
                    or n_queries >= cfg.max_queries
                ):
                    break
                # grouped callers stay "active" until their results fan
                # back, so active <= group size means nobody new can be
                # en route: close the window early instead of idling
                if self.frontend.active_calls() <= len(group):
                    break
                now = time.perf_counter()
                earliest = min(p.dispatch_by for p in group)
                if now >= earliest:
                    break
                self._cv.wait(timeout=earliest - now)
        return group

    def _loop(self) -> None:
        while True:
            group = self._collect()
            if not group:
                with self._cv:
                    if self._closed:
                        return
                continue
            now = time.perf_counter()
            for p in group:
                self._m_queue_wait.observe(now - p.enq_t)
            self._m_group_calls.observe(len(group))
            self._m_group_queries.observe(
                sum(len(p.queries) for p in group)
            )
            if len(group) > 1:
                self._c_coalesced.inc(len(group))
            # executable sub-groups: only calls agreeing on
            # (tenant, engine, min_epoch) share an epoch acquire + solve
            subs: dict[tuple, list[PendingCall]] = {}
            for p in group:
                key = (p.tenant.name, p.engine, p.min_epoch)
                subs.setdefault(key, []).append(p)
            for sub in subs.values():
                self._c_groups.inc()
                try:
                    self.frontend._solve_coalesced(sub)
                except BaseException as e:  # noqa: BLE001 — fan the
                    # failure back to every caller; the dispatcher must
                    # survive any single group's error
                    for p in sub:
                        p.error = e
                finally:
                    for p in sub:
                        p.done.set()
