"""StreamRuntime: the ingestion half of the diversity serving runtime.

One runtime owns ONE physical stream — the resumable Alg.-2 scan state(s)
under whichever placement drive the service resolved (single state, stacked
vmap/shard_map state, or the pipeline placement's per-device state list) —
and exposes two ways to feed it plus one way to read it:

  ingest(points, cats)   synchronous: resume the scan, update the O(1)
                         epoch fingerprint, return an ``IngestReport``
                         (the historical ``DiversityService`` path);
  submit(points, cats)   asynchronous: enqueue the batch onto a background
                         ingest worker and return immediately. The worker
                         drives the same jit entry points — JAX async
                         dispatch overlaps consecutive batches — and
                         *publishes epochs* as it drains, so ingestion and
                         query answering stop blocking each other;
  latest()/acquire()     read the newest *published* ``EpochSnapshot`` — an
                         immutable host-side materialization of the coreset
                         (compacted points/cats/src + fingerprint), built
                         once per epoch instead of once per query. The
                         query path (``QueryFrontend``) only ever touches
                         these snapshots, never the live device state, so a
                         query concurrent with ingestion always answers
                         from a consistent epoch (possibly slightly stale)
                         and a torn read is impossible by construction.

Epoch semantics:

* epochs are integers, strictly increasing from 1, published under the
  runtime lock;
* a new epoch *materializes* (device -> host compact of the union coreset,
  ``core.compose.snapshot_at_epoch``) only when the coreset fingerprint
  moved (``core.streaming.epoch_fingerprint`` — an O(1) host sync off the
  per-center count tables); a forced publish of an unchanged coreset reuses
  the previous epoch's buffers and just advances the counter;
* the async worker publishes when its queue drains and at least every
  ``publish_every`` ingested batches in between, so epoch staleness under
  continuous load is bounded by ``publish_every`` batches;
* ``flush()`` is the freshness barrier: wait until every submitted batch is
  ingested, force-publish, and return the new epoch number. A reader that
  needs everything it submitted can then pass that epoch as ``min_epoch``
  to ``acquire`` (or ``QueryFrontend.query``) — the freshness contract.

Fault tolerance (see README "Fault tolerance"):

* with ``durability=DurabilityConfig(dir)`` every accepted batch is
  appended to a write-ahead log *before* it is enqueued/applied, and the
  scan state is checkpointed every ``checkpoint_every`` applied batches
  — ``StreamRuntime.restore(dir)`` rebuilds a bit-identical stream from
  the newest checkpoint plus the WAL tail replayed in submission order
  (the paper's §3 composability: the state is a pure fold over batches);
* ``fault_policy=FaultPolicy(...)`` upgrades the worker from the
  historical fail-fast truncation to supervised ingestion: transient
  errors retry with capped exponential backoff, repeatedly-failing
  batches quarantine to ``StreamRuntime.poison`` (stream continues), and
  a crashed worker thread is respawned preserving submission order;
* ``faults=FaultPlan(...)`` arms the deterministic fault-injection
  harness at the named sites (chaos tests / bench only).

With the default policy, errors raised by the worker truncate the
stream and re-raise on the next ``submit``/``flush``; ``close()`` drains
the queue then stops the worker (idempotent).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import logging
import os
import queue
import threading
import time
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core import geometry
from ...core.compose import compact_coreset, snapshot_at_epoch
from ...core.matroid import MatroidSpec
from ...core.solvers.jit_sum import bucket_pow2 as _bucket_pow2
from ...core.streaming import (
    epoch_fingerprint,
    ingest_batch_donated,
    ingest_batch_sharded_donated,
    ingest_batch_sharded_mapped,
    init_sharded_states,
    init_stream_state,
    resolve_placement,
)
from .checkpoint import (
    DurabilityConfig,
    checkpoint_path,
    latest_checkpoint,
    load_checkpoint,
    prune_checkpoints,
    save_checkpoint,
)
from .faults import FaultPlan, FaultPolicy, InjectedCrash
from .wal import WriteAheadLog


@dataclasses.dataclass
class IngestReport:
    n: int  # points in this batch
    total: int  # stream points offered so far
    coreset_size: int
    coreset_changed: bool
    ingest_s: float


@dataclasses.dataclass(frozen=True)
class EpochSnapshot:
    """One published, immutable serving epoch: the compacted union coreset
    of the stream at a consistent instant, plus its content fingerprint.

    Published snapshots are plain host arrays — they survive the donation
    of the live scan state's buffers by later ingests, and any number of
    reader threads can solve on them without synchronization.
    """

    epoch: int  # strictly increasing publication counter (from 1)
    fingerprint: int  # coreset content hash at publication
    points: np.ndarray  # f32[m, d] stream-metric-normalized coreset rows
    cats: np.ndarray  # int32[m, gamma]
    src_idx: np.ndarray  # int64[m] global stream indices
    n_offered: int  # stream points ingested when this epoch was published
    published_at: float  # time.monotonic() at publication

    @property
    def size(self) -> int:
        return int(self.src_idx.shape[0])


@dataclasses.dataclass(frozen=True)
class PoisonedBatch:
    """One quarantined batch: failed every ingest attempt under a
    ``FaultPolicy(on_failure="quarantine")`` runtime. The data is kept so
    the operator can inspect/re-``submit`` it; ``seq`` is its WAL ordinal
    (-1 when the runtime is not durable)."""

    seq: int
    points: np.ndarray
    cats: Optional[np.ndarray]
    attempts: int
    error: BaseException


_STOP = object()  # worker shutdown sentinel

_log = logging.getLogger("repro.serve.diversity")


class StreamRuntime:
    """Ingestion engine + epoch publisher for one physical stream."""

    def __init__(
        self,
        spec: MatroidSpec,
        k: int,
        *,
        tau: int,
        metric: geometry.Metric = "euclidean",
        caps: Optional[np.ndarray] = None,
        slot_cap: Optional[int] = None,
        variant: str = "radius",
        eps: float = 0.5,
        c_const: int = 32,
        oracle=None,
        num_shards: int = 1,
        block_size: int = 128,
        placement: str = "auto",
        publish_every: int = 8,
        max_pending: int = 64,
        on_publish: Optional[Callable[[EpochSnapshot], None]] = None,
        registry: Optional[obs.MetricsRegistry] = None,
        durability: Optional[Union[DurabilityConfig, str]] = None,
        fault_policy: Optional[FaultPolicy] = None,
        faults: Optional[FaultPlan] = None,
    ):
        if spec.kind == "general" and oracle is None:
            raise ValueError("general matroid service needs a host oracle")
        if spec.kind == "partition" and caps is None:
            raise ValueError("partition matroid service needs per-category caps")
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if publish_every < 1:
            raise ValueError(f"publish_every must be >= 1, got {publish_every}")
        # resolves "auto" against jax.devices() once, at construction
        self.placement = resolve_placement(placement, num_shards)
        self.spec = spec
        self.k = int(k)
        self.tau = int(tau)
        self.metric = metric
        self.caps = None if caps is None else np.asarray(caps, np.int32)
        self._caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
        self.slot_cap = slot_cap
        self.stream_variant = variant
        self.eps = float(eps)
        self.c_const = int(c_const)
        self.oracle = oracle
        self.num_shards = int(num_shards)
        self.block_size = int(block_size)
        self.publish_every = int(publish_every)
        self.on_publish = on_publish
        # single-shard state, stacked shard state (vmap/shard_map), or a
        # list of per-shard states (pipeline)
        self._state = None
        self._gamma_width = max(spec.gamma, 1)
        self.n_offered = 0
        self._fingerprint: Optional[int] = None
        self._coreset_size = 0
        self._rr = 0  # pipeline round-robin cursor (batch granularity)
        # per-shard (fingerprint, size) pulls for the pipeline drive: only
        # the shard an ingest touched is re-pulled (entry set to None), so
        # the per-ingest host-sync count stays O(1), not O(num_shards)
        self._fp_cache: Optional[list] = None
        # --- epoch publication state (all guarded by _cv's lock) ---
        self._cv = threading.Condition(threading.RLock())
        self._published: Optional[EpochSnapshot] = None
        self._dirty = False  # ingested since last publish
        self._unpublished = 0  # ingests since last publish (staleness bound)
        self.epochs_published = 0
        self.snapshot_materializations = 0
        # --- async ingestion (lazy worker; see submit/flush/close) ---
        self._queue: queue.Queue = queue.Queue(maxsize=int(max_pending))
        self._worker: Optional[threading.Thread] = None
        self._worker_err: Optional[BaseException] = None
        self._pending = 0  # submitted batches not yet fully ingested
        self._closed = False
        self._force_stop = False  # close(drain=False): drop, don't ingest
        # --- fault tolerance (durability + supervised worker) ---
        self.fault_policy = (
            fault_policy if fault_policy is not None else FaultPolicy()
        )
        self.faults = faults
        # epoch timestamps and staleness all read one clock, so an
        # injected clock skew shifts every stamp coherently instead of
        # tearing publish-vs-submit deltas (wait deadlines stay on the
        # real clock)
        self._clock = (
            faults.monotonic if faults is not None else time.monotonic
        )
        if isinstance(durability, str):
            durability = DurabilityConfig(dir=durability)
        self.durability = durability
        self._wal: Optional[WriteAheadLog] = None
        self._next_seq = 0  # next submission ordinal to assign
        self._applied_seq = -1  # newest seq folded into the scan state
        self._last_ckpt_seq = -1  # _applied_seq at the last checkpoint
        self._poisoned_seqs: list[int] = []  # skipped on WAL replay
        self._replaying = False  # restore() replay: don't re-append
        self._inflight = None  # batch a crashed worker must re-apply first
        self._worker_restarts = 0
        self.poison: list[PoisonedBatch] = []
        self.restore_report: Optional[dict] = None
        # --- observability (repro.obs; see README "Observability") ---
        # submit times of worker-ingested batches awaiting an epoch: the
        # publish drains it into the staleness histogram (publish time -
        # submit time, the freshness-under-load signal). Guarded by _cv.
        self._stale_pending: list[float] = []
        self.registry = registry if registry is not None else (
            obs.default_registry()
        )
        reg = self.registry
        self._m_ingest_s = reg.histogram(
            "serve.ingest.latency_s", placement=self.placement
        )
        self._m_ingest_points = reg.counter(
            "serve.ingest.points", placement=self.placement
        )
        self._m_ingest_batches = reg.counter(
            "serve.ingest.batches", placement=self.placement
        )
        self._m_queue_depth = reg.gauge("serve.submit.queue_depth")
        self._m_submitted = reg.counter("serve.submit.batches")
        self._m_publish_s = reg.histogram("serve.epoch.publish_latency_s")
        self._m_staleness_s = reg.histogram("serve.epoch.staleness_s")
        self._m_epochs = reg.counter("serve.epoch.published")
        self._m_materializations = reg.counter(
            "serve.epoch.materializations"
        )
        self._m_worker_errors = reg.counter("serve.worker.errors")
        self._m_callback_errors = reg.counter(
            "serve.publish.callback_errors"
        )
        self._m_worker_retries = reg.counter("serve.worker.retries")
        self._m_worker_poisoned = reg.counter("serve.worker.poisoned")
        self._m_worker_crashes = reg.counter("serve.worker.crashes")
        self._m_worker_restarts = reg.counter("serve.worker.restarts")
        self._m_ckpt_saved = reg.counter("serve.ckpt.saved")
        self._m_ckpt_failures = reg.counter("serve.ckpt.failures")
        self._m_ckpt_last_seq = reg.gauge("serve.ckpt.last_seq")
        self._m_rejected_nonfinite = reg.counter(
            "serve.ingest.rejected", reason="nonfinite"
        )
        # (n_offered, fingerprint) after each ingest: replicas seeing the
        # same batch sequence compare fingerprints at a common watermark
        # in O(1) instead of shipping coresets (see replication.py).
        self._fp_history: collections.deque = collections.deque(maxlen=1024)
        if self.durability is not None:
            os.makedirs(self.durability.dir, exist_ok=True)
            self._wal = WriteAheadLog(
                self.durability.wal_path,
                fsync=self.durability.fsync,
                faults=self.faults,
                registry=reg,
            )

    # ------------------------------------------------------------------
    # synchronous ingestion (the scan itself)
    # ------------------------------------------------------------------

    @property
    def state(self):
        """The live scan state: a ``StreamState`` (single shard), a
        stacked one (vmap/shard_map), or a list (pipeline).

        The ingest hot path *donates* this state's buffers to XLA (the
        steady-state win of not copying the delegate store every batch),
        so a reference captured here is invalidated by the next
        ``ingest`` — read or copy (``jax.tree_util.tree_map(jnp.copy,
        rt.state)``) anything you need to keep before ingesting again.
        Published ``EpochSnapshot``s are host copies and never affected.
        """
        return self._state

    @property
    def fingerprint(self) -> Optional[int]:
        """Coreset content fingerprint as of the last ingest (``None``
        until something was ingested or ``ensure_state`` ran)."""
        return self._fingerprint

    def fingerprint_at(self, n_offered: int) -> Optional[int]:
        """Coreset fingerprint recorded right after the ingest that
        brought the stream to ``n_offered`` points, or ``None`` if no
        ingest landed exactly there (or it aged out of the bounded
        history). Because the stream is a pure fold over the batch
        sequence, two runtimes fed the same batches must agree at every
        common watermark — replication's O(1) divergence check."""
        with self._cv:
            for n, fp in reversed(self._fp_history):
                if n == n_offered:
                    return fp
                if n < n_offered:
                    break
            return None

    def fingerprint_watermarks(self) -> list[int]:
        """The ``n_offered`` watermarks currently in the fingerprint
        history (ascending)."""
        with self._cv:
            return [n for n, _fp in self._fp_history]

    def _check_finite(self, points: np.ndarray) -> None:
        """Reject NaN/Inf points at the door — *before* the WAL append.
        A poisoned log entry would otherwise replay poison on every
        restore."""
        pts = np.asarray(points)
        if pts.size and not bool(np.isfinite(pts).all()):
            self._m_rejected_nonfinite.inc()
            raise ValueError(
                "batch contains non-finite point coordinates (NaN/Inf); "
                "rejected before WAL append"
            )

    def _check_cats(self, n: int, cats: Optional[np.ndarray]) -> np.ndarray:
        if cats is None:
            return np.zeros((n, self._gamma_width), np.int32)
        cats_arr = np.asarray(cats, np.int32).reshape(n, -1)
        if cats_arr.shape[1] != self._gamma_width:
            raise ValueError(
                f"cats width {cats_arr.shape[1]} != spec gamma "
                f"{self._gamma_width}"
            )
        if (
            self.spec.kind == "partition"
            and cats_arr.shape[1] > 1
            and np.any(cats_arr[:, 1:] >= 0)
        ):
            # refuse at the door rather than truncating labels inside the
            # scan/solvers: a partition matroid is single-label by
            # definition, multi-label points need a transversal spec
            raise ValueError(
                "partition service got a point with >1 category label; "
                "use a transversal MatroidSpec for multi-label data"
            )
        return cats_arr

    def ensure_state(self, d: int) -> None:
        """Initialize the (placement-appropriate) empty scan state for
        point dimension ``d`` if none exists yet, and fingerprint it —
        the pre-ingest warmup entry point."""
        with self._cv:
            if self._state is not None:
                return
            if self.num_shards > 1 and self.placement == "pipeline":
                self._init_pipeline_states(d)
            elif self.num_shards > 1:
                self._state = init_sharded_states(
                    self.num_shards, d, self._gamma_width, self.spec,
                    self.k, self.tau, slot_cap=self.slot_cap,
                )
            else:
                self._state = init_stream_state(
                    d, self._gamma_width, self.spec, self.k, self.tau,
                    slot_cap=self.slot_cap,
                )
            # the empty state has an empty coreset: fingerprint it so a
            # zero-ingest warmup leaves the runtime in a consistent state
            self._fingerprint, self._coreset_size = (
                self._fingerprint_and_size()
            )
            self._fp_history.append((self.n_offered, self._fingerprint))
            self._dirty = True  # first refresh publishes the empty epoch

    def point_dim(self) -> Optional[int]:
        if self._state is None:
            return None
        x1 = (
            self._state[0].x1
            if isinstance(self._state, list)
            else self._state.x1
        )
        return int(x1.shape[-1])

    def ingest(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Feed one batch of the stream (any size) into the scan state.

        With ``num_shards > 1`` the batch is dealt round-robin across the
        per-shard scan states (``ingest_sharded``); otherwise it resumes the
        single blocked scan. Either way batches are padded to a multiple of
        ``block_size`` with invalid rows — a bit-exact no-op for the scan
        that keeps the jit cache keyed on a handful of bucketed shapes
        instead of recompiling for every ragged final batch. ``pad_to``
        raises the padded length further (``warmup`` uses it to compile a
        target batch shape off an empty batch).

        Thread-safe (the async worker calls this too); does NOT publish an
        epoch — publication happens in ``refresh``/``flush`` or on the
        worker's drain cadence.

        On a durable runtime (``durability=``) this entry point write-ahead
        logs the batch before applying it (``submit`` logs at enqueue time
        instead); calling ``ingest_sharded``/``ingest_pipeline`` directly
        bypasses the log.

        Raises ``ValueError`` (batch neither logged nor applied) on
        NaN/Inf coordinates.
        """
        self._check_finite(points)
        with self._cv:
            seq = self._wal_begin(points, cats)
            if self.num_shards > 1:
                if self.placement == "pipeline":
                    rep = self.ingest_pipeline(points, cats, pad_to=pad_to)
                else:
                    rep = self.ingest_sharded(points, cats, pad_to=pad_to)
                self._wal_commit(seq)
                return rep
            t0 = time.perf_counter()
            pts = np.asarray(points, np.float32)
            n, d = pts.shape
            cats_arr = self._check_cats(n, cats)
            if self._state is None:
                self._state = init_stream_state(
                    d, self._gamma_width, self.spec, self.k, self.tau,
                    slot_cap=self.slot_cap,
                )
            total = max(n, pad_to or 0)
            pad = total + (-total % self.block_size) - n
            if pad:
                pts = np.concatenate([pts, np.zeros((pad, d), np.float32)])
                cats_arr = np.concatenate(
                    [cats_arr, np.full((pad, self._gamma_width), -1, np.int32)]
                )
            valid = np.arange(n + pad) < n
            pts_norm = geometry.normalize_for_metric(
                jnp.asarray(pts, jnp.float32), self.metric
            )
            # donated: the previous state is dropped on reassignment, so XLA
            # aliases its buffers into the new state instead of copying the
            # whole delegate store every call (the dominant fixed cost of a
            # steady-state no-op batch)
            with obs.compile_region(f"ingest[single b={pts.shape[0]}]"):
                self._state = ingest_batch_donated(
                    self._state,
                    pts_norm,
                    jnp.asarray(cats_arr),
                    jnp.asarray(valid),
                    self.spec,
                    self._caps_j,
                    self.k,
                    self.tau,
                    base_index=jnp.int32(self.n_offered),
                    variant=self.stream_variant,
                    eps=self.eps,
                    c_const=self.c_const,
                    block_size=self.block_size,
                )
            self.n_offered += n
            rep = self._report(n, t0)
            self._wal_commit(seq)
            return rep

    def _wal_begin(
        self, points: np.ndarray, cats: Optional[np.ndarray]
    ) -> Optional[int]:
        """Assign a submission ordinal and write-ahead log one externally
        originated synchronous batch (under ``_cv``). Returns ``None`` for
        non-durable runtimes and for internal applications (the async
        worker's — logged at submit time — and restore's replay); raises
        ``WalError`` (batch NOT applied, seq burned) if the append fails.
        """
        if self._wal is None or self._replaying:
            return None
        if (
            self._worker is not None
            and threading.current_thread() is self._worker
        ):
            return None
        pts = np.asarray(points, np.float32)
        if pts.shape[0] == 0:
            return None  # warmup no-op batches don't advance the stream
        if self._pending > 0:
            # interleaving a sync ingest between in-flight async batches
            # would apply it out of submission order — the WAL could no
            # longer replay to the same stream, so refuse loudly
            raise RuntimeError(
                "durable runtime: synchronous ingest while async batches "
                "are pending would break WAL replay order; flush() first "
                "or submit() this batch"
            )
        seq = self._next_seq
        self._next_seq += 1
        self._wal.append(seq, pts, cats)
        return seq

    def _wal_commit(self, seq: Optional[int]) -> None:
        """Mark one ``_wal_begin``-logged batch as applied (under
        ``_cv``) and checkpoint if the cadence says so."""
        if seq is None:
            return
        self._applied_seq = seq
        self.checkpoint(force=False)

    def ingest_sharded(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Deal one batch round-robin across ``num_shards`` independent
        scan states and ingest all shards in one call — the vmap drive on a
        single device, the ``shard_map``-over-mesh drive when ``placement``
        resolved to it (per-device shard groups run as real parallel
        programs).

        Each shard sees its own sub-stream; per §3 composability the union
        of the per-shard coresets (the epoch snapshot) is a coreset of the
        full stream. Global ``src_idx`` bookkeeping is preserved by passing
        explicit per-row indices.
        """
        if self.num_shards < 2:
            raise ValueError("ingest_sharded needs num_shards >= 2")
        if self.placement == "pipeline":
            # a pipeline runtime keeps a *list* of per-shard states; the
            # stacked-state drives here would corrupt it — route through
            # ingest()/ingest_pipeline, or construct with placement="vmap"
            # or "shard_map" for the row-granular deal
            raise ValueError(
                "ingest_sharded is the row-granular drive; this service "
                "resolved placement='pipeline' (batch-granular) — use "
                "ingest()/ingest_pipeline, or pass placement='vmap' or "
                "'shard_map'"
            )
        with self._cv:
            t0 = time.perf_counter()
            pts = np.asarray(points, np.float32)
            n, d = pts.shape
            cats_arr = self._check_cats(n, cats)
            S = self.num_shards
            if self._state is None:
                self._state = init_sharded_states(
                    S, d, self._gamma_width, self.spec, self.k, self.tau,
                    slot_cap=self.slot_cap,
                )
            if str(self.metric) == "euclidean":
                pts_norm = pts  # identity metric: skip the device round-trip
            else:
                pts_norm = np.asarray(
                    geometry.normalize_for_metric(
                        jnp.asarray(pts, jnp.float32), self.metric
                    )
                )
            # per-shard sub-batch length, bucketed so ragged batches reuse a
            # handful of jit shapes; the per-shard block never exceeds it (a
            # 512-point deal across 8 shards is ONE 64-point block per
            # shard, not a 64-point block padded to 128)
            mm0 = -(-max(n, pad_to or 0) // S)
            sb = min(self.block_size, _bucket_pow2(mm0))
            mm = mm0 + (-mm0 % sb)
            Pb = np.zeros((S, mm, d), np.float32)
            Cb = np.full((S, mm, self._gamma_width), -1, np.int32)
            Vb = np.zeros((S, mm), bool)
            Sb = np.full((S, mm), -1, np.int32)
            if n > 0 and n % S == 0:
                # whole deal in three O(n) reshapes: round-robin row r of
                # the batch lands at [r % S, r // S]
                q = n // S
                Pb[:, :q] = pts_norm.reshape(q, S, d).transpose(1, 0, 2)
                Cb[:, :q] = cats_arr.reshape(q, S, -1).transpose(1, 0, 2)
                Vb[:, :q] = True
                Sb[:, :q] = (
                    self.n_offered
                    + np.arange(n, dtype=np.int64).reshape(q, S).T
                )
            else:
                for s in range(S):
                    rows = np.arange(s, n, S)
                    r = rows.shape[0]
                    Pb[s, :r] = pts_norm[rows]
                    Cb[s, :r] = cats_arr[rows]
                    Vb[s, :r] = True
                    Sb[s, :r] = self.n_offered + rows
            ingest = (
                ingest_batch_sharded_donated
                if self.placement == "vmap"
                else functools.partial(
                    ingest_batch_sharded_mapped, donate=True
                )
            )
            with obs.compile_region(
                f"ingest[{self.placement} s={S} b={mm}]"
            ):
                self._state = ingest(
                    self._state,
                    jnp.asarray(Pb),
                    jnp.asarray(Cb),
                    jnp.asarray(Vb),
                    jnp.asarray(Sb),
                    self.spec,
                    self._caps_j,
                    self.k,
                    self.tau,
                    variant=self.stream_variant,
                    eps=self.eps,
                    c_const=self.c_const,
                    block_size=sb,
                )
            self.n_offered += n
            return self._report(n, t0)

    def _init_pipeline_states(self, d: int) -> None:
        devs = jax.devices()
        nd = len(devs)
        self._state = [
            jax.device_put(
                init_stream_state(
                    d, self._gamma_width, self.spec, self.k, self.tau,
                    slot_cap=self.slot_cap,
                ),
                devs[i % nd],
            )
            for i in range(self.num_shards)
        ]

    def ingest_pipeline(
        self,
        points: np.ndarray,
        cats: Optional[np.ndarray] = None,
        *,
        pad_to: Optional[int] = None,
    ) -> IngestReport:
        """Route one whole batch to the next shard (batch-granular
        round-robin) and resume that shard's plain blocked scan.

        The stream partition is by batches instead of rows — still a
        partition, so §3 union composability is untouched — and each
        ingest is the *same* jit executable as the unsharded path: per
        batch, sharding costs nothing. Shard states are pinned round-robin
        across ``jax.devices()``, so consecutive batches land on different
        devices and async dispatch can overlap them when the hardware has
        more than one — the natural substrate of the async ``submit``
        worker. Callers that feed a few huge batches (rather than a stream
        of them) should prefer the row-granular drives, which spread every
        batch across all shards.
        """
        if self.num_shards < 2:
            raise ValueError("ingest_pipeline needs num_shards >= 2")
        with self._cv:
            t0 = time.perf_counter()
            pts = np.asarray(points, np.float32)
            n, d = pts.shape
            cats_arr = self._check_cats(n, cats)
            if self._state is None:
                self._init_pipeline_states(d)
            total = max(n, pad_to or 0)
            pad = total + (-total % self.block_size) - n
            if pad:
                pts = np.concatenate([pts, np.zeros((pad, d), np.float32)])
                cats_arr = np.concatenate(
                    [cats_arr, np.full((pad, self._gamma_width), -1, np.int32)]
                )
            valid = np.arange(n + pad) < n
            pts_norm = geometry.normalize_for_metric(
                jnp.asarray(pts, jnp.float32), self.metric
            )
            i = self._rr % self.num_shards
            if n > 0:  # empty (warmup) batches don't consume a shard slot
                self._rr += 1
            if self._fp_cache is not None:
                self._fp_cache[i] = None  # this shard's pull is now stale
            with obs.compile_region(
                f"ingest[pipeline b={pts.shape[0]}]"
            ):
                self._state[i] = ingest_batch_donated(
                    self._state[i],
                    pts_norm,
                    jnp.asarray(cats_arr),
                    jnp.asarray(valid),
                    self.spec,
                    self._caps_j,
                    self.k,
                    self.tau,
                    base_index=jnp.int32(self.n_offered),
                    variant=self.stream_variant,
                    eps=self.eps,
                    c_const=self.c_const,
                    block_size=self.block_size,
                )
            self.n_offered += n
            return self._report(n, t0)

    def _report(self, n: int, t0: float) -> IngestReport:
        fp, size = self._fingerprint_and_size()
        changed = fp != self._fingerprint
        self._fingerprint = fp
        self._coreset_size = size
        self._fp_history.append((self.n_offered, fp))
        self._dirty = True
        self._unpublished += 1
        self._m_ingest_s.observe(time.perf_counter() - t0)
        self._m_ingest_points.inc(n)
        self._m_ingest_batches.inc()
        return IngestReport(
            n=n,
            total=self.n_offered,
            coreset_size=size,
            coreset_changed=changed,
            ingest_s=time.perf_counter() - t0,
        )

    def _fingerprint_and_size(self) -> tuple[int, int]:
        """Coreset fingerprint via the O(1)-host-sync device reduction
        (``core.streaming.epoch_fingerprint``): three scalars per ingest
        instead of pulling and hashing the delegate buffers.

        For the pipeline drive only the shard the last ingest touched is
        re-reduced; the rest reuse their cached (fingerprint, size).
        """
        if isinstance(self._state, list):
            if self._fp_cache is None:
                self._fp_cache = [None] * len(self._state)
            for j, st in enumerate(self._state):
                if self._fp_cache[j] is None:
                    self._fp_cache[j] = epoch_fingerprint(st)
            # the union is determined by the shard-major sequence of shard
            # coresets, so hashing the per-shard hashes is an equivalent
            # content key
            return (
                hash(tuple(fp for fp, _sz in self._fp_cache)),
                int(sum(sz for _fp, sz in self._fp_cache)),
            )
        return epoch_fingerprint(self._state)

    # ------------------------------------------------------------------
    # epoch publication
    # ------------------------------------------------------------------

    @property
    def pending(self) -> int:
        """Submitted batches not yet ingested by the worker."""
        with self._cv:
            return self._pending

    def latest(self) -> Optional[EpochSnapshot]:
        """Newest published epoch (``None`` before the first publish).
        Never touches device state."""
        return self._published

    def refresh(self, *, force: bool = False) -> EpochSnapshot:
        """Publish the current state as a new epoch if anything was
        ingested since the last publish; otherwise return the published
        epoch unchanged.

        Materializes the coreset (device -> host) only when the
        fingerprint moved; a ``force`` publish of an unchanged coreset
        reuses the previous buffers and just advances the epoch counter
        (the ``flush`` barrier uses this so its returned epoch provably
        covers everything ingested before it). Without ``force``, an
        unchanged-coreset ingest does not bump the epoch — the published
        snapshot already serves it.
        """
        t0 = time.perf_counter()
        with self._cv:
            if self._state is None:
                raise RuntimeError("ingest at least one batch first")
            pub = self._published
            changed = pub is None or pub.fingerprint != self._fingerprint
            if not self._dirty and not changed:
                return pub
            if not changed and not force:
                return pub
            now = self._clock()
            with obs.span(
                "publish", cat="ingest",
                force=force, materialize=changed,
            ):
                if changed:
                    pts, cats, src = compact_coreset(
                        snapshot_at_epoch(self._state)
                    )
                    self.snapshot_materializations += 1
                    self._m_materializations.inc()
                else:  # forced epoch bump over an unchanged coreset
                    pts, cats, src = pub.points, pub.cats, pub.src_idx
            snap = EpochSnapshot(
                epoch=(pub.epoch if pub else 0) + 1,
                fingerprint=self._fingerprint,
                points=pts,
                cats=cats,
                src_idx=src,
                n_offered=self.n_offered,
                published_at=now,
            )
            self._published = snap
            self._dirty = False
            self._unpublished = 0
            self.epochs_published += 1
            self._m_epochs.inc()
            self._m_publish_s.observe(time.perf_counter() - t0)
            # every worker-ingested batch awaiting an epoch is now covered
            # by this publish: its staleness is publish time - submit time
            # (same clock as the submit stamp, so injected skew cancels)
            t_pub = self._clock()
            for t_submit in self._stale_pending:
                self._m_staleness_s.observe(t_pub - t_submit)
            self._stale_pending.clear()
            self._cv.notify_all()
        if self.on_publish is not None:
            try:
                self.on_publish(snap)
            except Exception:
                # a subscriber's bug must not kill the ingest worker (or a
                # synchronous refresh caller): count it, log it, move on
                self._m_callback_errors.inc()
                _log.exception(
                    "on_publish callback raised for epoch %d", snap.epoch
                )
        return snap

    def acquire(
        self,
        min_epoch: Optional[int] = None,
        *,
        timeout: Optional[float] = 60.0,
    ) -> EpochSnapshot:
        """Snapshot for a reader: stale-but-consistent while ingestion is
        in flight, freshest-available when the runtime is idle.

        With async batches pending, returns the newest *published* epoch
        without touching device state — or the runtime lock: the stale
        read path is entirely lock-free, so a query never queues behind
        the scan call the worker is inside. When idle, publishes any
        unpublished synchronous ingests first — so the façade's
        sequential ingest-then-query flow always sees its own writes.
        ``min_epoch`` blocks until an epoch >= it is published; if
        nothing in flight can ever satisfy it, raises ``ValueError`` (and
        ``TimeoutError`` after ``timeout`` seconds).
        """
        self._raise_worker_error()
        snap = self._published  # single-ref read: atomic, no lock
        if (
            snap is not None
            and self._pending > 0
            and (min_epoch is None or snap.epoch >= min_epoch)
        ):
            return snap
        with self._cv:
            self._raise_worker_error()
            if self._pending == 0:
                snap = self.refresh()
            else:
                snap = self._published
                if snap is None:
                    # first batches still in flight: wait for epoch 1
                    self._wait_for(1, timeout)
                    snap = self._published
            if min_epoch is not None and snap.epoch < min_epoch:
                self._wait_for(min_epoch, timeout)
                snap = self._published
            return snap

    def _wait_for(self, min_epoch: int, timeout: Optional[float]) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while self._published is None or self._published.epoch < min_epoch:
            self._raise_worker_error()
            if self._pending == 0:
                # nothing in flight can advance the epoch: force at most
                # one publish, then the request is provably unsatisfiable
                snap = self.refresh(force=True)
                if snap.epoch >= min_epoch:
                    return
                raise ValueError(
                    f"min_epoch {min_epoch} is ahead of the newest epoch "
                    f"{snap.epoch} and no ingestion is in flight"
                )
            remaining = (
                None if deadline is None else deadline - time.monotonic()
            )
            if remaining is not None and remaining <= 0:
                raise TimeoutError(
                    f"epoch {min_epoch} not published within timeout"
                )
            self._cv.wait(remaining)

    # ------------------------------------------------------------------
    # async ingestion
    # ------------------------------------------------------------------

    def submit(
        self, points: np.ndarray, cats: Optional[np.ndarray] = None
    ) -> int:
        """Enqueue one batch for background ingestion and return without
        waiting for the scan. Batches are ingested strictly in submission
        order (one worker), so the resulting stream — and therefore every
        published epoch — is bit-identical to the same sequence of
        synchronous ``ingest`` calls. Blocks only when ``max_pending``
        batches are already queued (backpressure). Worker errors surface
        on the next ``submit``/``flush``.

        On a durable runtime the batch is appended to the write-ahead log
        *before* it is enqueued: once ``submit`` returns, the batch
        survives a process death (``restore`` replays it). A failed
        append raises ``WalError`` here, in the submitter — the batch was
        neither persisted nor enqueued. Non-finite points raise
        ``ValueError`` before the append, so the log never holds poison.

        Returns the WAL seq assigned to the batch (-1 on a non-durable
        runtime) — ``ReplicaSet`` ships that seq to standbys.
        """
        pts = np.asarray(points, np.float32)
        self._check_finite(pts)
        with obs.trace() as tid, obs.span(
            "submit", cat="ingest", n=int(pts.shape[0])
        ):
            with self._cv:
                self._raise_worker_error()
                if self._closed:
                    raise RuntimeError("runtime is closed")
                seq = -1
                if self._wal is not None:
                    # log-then-enqueue: a WalError propagates to the
                    # caller with the batch not enqueued (the burned seq
                    # leaves a harmless gap in the log)
                    seq = self._next_seq
                    self._next_seq += 1
                    self._wal.append(seq, pts, cats)
                self._ensure_worker()
                self._pending += 1
                self._m_submitted.inc()
            # queue items carry submit time (the staleness clock) and the
            # submitter's trace ID (the worker resumes it, so one trace
            # covers submit -> ingest -> publish across threads)
            self._queue.put((pts, cats, seq, self._clock(), tid))
            self._m_queue_depth.set(self._queue.qsize())
        return seq

    def _ensure_worker(self) -> None:
        """Start (or, defensively, respawn) the ingest worker. Caller
        holds ``_cv``. Normal crash recovery happens in ``_worker_main``'s
        supervisor; this only catches a worker that died without it."""
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._worker_main,
                name="stream-runtime-ingest",
                daemon=True,
            )
            self._worker.start()

    def _drop_pending_item(self, reason: str) -> None:
        """Account one submitted batch that will never be ingested:
        ``reason="truncated"`` (a batch after the stream-truncating
        failure) or ``reason="close"`` (forced ``close(drain=False)``).
        Drops are NOT worker errors — ``serve.worker.errors`` counts each
        failure exactly once, where it happens."""
        self.registry.counter(
            "serve.worker.dropped_batches", reason=reason
        ).inc()
        with self._cv:
            self._pending -= 1
            self._cv.notify_all()

    def _worker_main(self) -> None:
        """Worker thread entry: the ingest loop under a supervisor.

        A loop-fatal error (e.g. an injected ``InjectedCrash``) kills
        this thread — the supervisor respawns a replacement (bounded by
        ``fault_policy.max_worker_restarts``) that first re-applies the
        in-flight batch the dead worker was holding, preserving
        submission order exactly.
        """
        try:
            self._worker_loop()
        except BaseException as e:  # noqa: BLE001 — supervisor boundary
            self._m_worker_crashes.inc()
            _log.warning(
                "ingest worker crashed (%s: %s)", type(e).__name__, e
            )
            with self._cv:
                policy = self.fault_policy
                if (
                    self._closed
                    or self._worker_restarts >= policy.max_worker_restarts
                ):
                    if self._worker_err is None:
                        self._m_worker_errors.inc()
                        self._worker_err = e
                    self._cv.notify_all()
                    return
                self._worker_restarts += 1
                self._m_worker_restarts.inc()
                self._worker = threading.Thread(
                    target=self._worker_main,
                    name="stream-runtime-ingest",
                    daemon=True,
                )
                self._worker.start()

    def _worker_loop(self) -> None:
        while True:
            if self._inflight is not None:
                # a restarted worker re-applies its predecessor's
                # in-flight batch before touching the queue: order holds
                item = self._inflight
            else:
                item = self._queue.get()
                if item is _STOP:
                    self._drain_after_stop()
                    return
                self._inflight = item
            pts, cats, seq, t_submit, tid = item
            self._m_queue_depth.set(self._queue.qsize())
            if self._force_stop:
                # forced close: accepted-but-unqueued work is dropped,
                # recorded BEFORE the pending count moves so a racing
                # flush() can never see a "clean" drain (on a durable
                # runtime the batches are in the WAL and restore replays
                # them)
                with self._cv:
                    if self._worker_err is None:
                        self._worker_err = RuntimeError(
                            "close(drain=False) dropped queued batch(es) "
                            "without ingesting them (see serve.worker."
                            "dropped_batches{reason=close})"
                        )
                self._inflight = None
                self._drop_pending_item("close")
                continue
            if self.faults is not None:
                # loop-fatal injection site: _inflight already holds the
                # batch, so the supervised restart replays it in order
                self.faults.check("worker.loop")
            if self._worker_err is not None:
                # after a stream-truncating failure later batches are
                # dropped (not ingested out of order), so the error
                # surfaced to callers tells the truth — everything after
                # the failure needs re-submitting
                self._inflight = None
                self._drop_pending_item("truncated")
                continue
            with obs.resume_trace(tid):
                ok = self._ingest_with_retry(pts, cats, seq)
                self._inflight = None
                if not ok:
                    continue
                with self._cv:
                    self._pending -= 1
                    drained = self._pending == 0
                    overdue = self._unpublished >= self.publish_every
                    self._stale_pending.append(t_submit)
                    self._cv.notify_all()
                if drained or overdue:
                    # publish off the ingest lock's critical path: the epoch
                    # materialization (device pull) runs here, in the worker,
                    # never in a query thread
                    try:
                        self.refresh(force=drained)
                    except BaseException as e:  # noqa: BLE001
                        with self._cv:
                            if self._worker_err is None:
                                self._m_worker_errors.inc()
                                self._worker_err = e
                            self._cv.notify_all()
                self.checkpoint(force=False)

    def _drain_after_stop(self) -> None:
        """Drain batches racing (or force-dropped by) ``close``: they
        will never be ingested — account them and unblock waiters
        instead of hanging them, and leave a truthful error for any
        later ``flush``/``acquire``."""
        while True:
            try:
                nxt = self._queue.get(timeout=0.1)
            except queue.Empty:
                return
            if nxt is not _STOP:
                # error recorded BEFORE the pending count drops, so a
                # concurrent flush() can never observe a "clean" drain
                with self._cv:
                    if self._worker_err is None:
                        self._worker_err = RuntimeError(
                            "close() dropped queued batch(es) without "
                            "ingesting them (see serve.worker."
                            "dropped_batches{reason=close})"
                        )
                self._drop_pending_item("close")

    def _ingest_with_retry(
        self, pts: np.ndarray, cats: Optional[np.ndarray], seq: int
    ) -> bool:
        """Apply one dequeued batch under the fault policy: retry
        transient errors with capped exponential backoff, then either
        truncate the stream (default, the historical contract) or
        quarantine the batch to ``self.poison`` and keep going. Returns
        True iff the batch was ingested. ``serve.worker.errors`` is
        incremented exactly once per failed batch, never per retry and
        never per later re-raise."""
        policy = self.fault_policy
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.check("worker.ingest")
                with obs.span(
                    "worker_ingest", cat="ingest", n=int(pts.shape[0]),
                    attempt=attempt,
                ):
                    self.ingest(pts, cats)
                if seq >= 0:
                    with self._cv:
                        self._applied_seq = seq
                return True
            except InjectedCrash:
                raise  # loop-fatal by contract: the supervisor's problem
            except Exception as e:  # noqa: BLE001 — policy boundary
                if attempt < policy.max_retries:
                    self._m_worker_retries.inc()
                    time.sleep(policy.backoff(attempt))
                    attempt += 1
                    continue
                self._m_worker_errors.inc()
                if policy.on_failure == "quarantine":
                    self._m_worker_poisoned.inc()
                    _log.warning(
                        "quarantining batch seq=%d after %d attempt(s): "
                        "%s: %s — stream continues",
                        seq, attempt + 1, type(e).__name__, e,
                    )
                    with self._cv:
                        self.poison.append(PoisonedBatch(
                            seq=seq, points=pts, cats=cats,
                            attempts=attempt + 1, error=e,
                        ))
                        if seq >= 0:
                            # the seq is consumed: a restored stream must
                            # skip it on replay to match this live one
                            self._poisoned_seqs.append(seq)
                            self._applied_seq = seq
                        self._pending -= 1
                        self._cv.notify_all()
                else:
                    with self._cv:
                        if self._worker_err is None:
                            self._worker_err = e
                        self._pending -= 1
                        self._cv.notify_all()
                return False

    def _raise_worker_error(self) -> None:
        if self._worker_err is not None:
            err = self._worker_err
            raise RuntimeError(
                "async ingest worker failed; no further batches were "
                "ingested"
            ) from err

    def flush(self, *, timeout: Optional[float] = 120.0) -> int:
        """Freshness barrier: wait until every batch submitted so far is
        ingested, force-publish, and return the epoch number — which then
        provably covers all of them (pass it as ``min_epoch`` to read
        your own writes)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._pending > 0:
                self._raise_worker_error()
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("flush timed out with batches pending")
                self._cv.wait(remaining)
            self._raise_worker_error()
            return self.refresh(force=True).epoch

    # ------------------------------------------------------------------
    # durability: checkpoint + restore
    # ------------------------------------------------------------------

    def _config_dict(self) -> dict:
        """JSON-serializable constructor config (everything but the host
        oracle and callbacks, which ``restore`` takes as arguments)."""
        return dict(
            spec=dict(
                kind=self.spec.kind,
                num_categories=self.spec.num_categories,
                gamma=self.spec.gamma,
            ),
            k=self.k,
            tau=self.tau,
            metric=str(self.metric),
            caps=None if self.caps is None else [int(c) for c in self.caps],
            slot_cap=self.slot_cap,
            variant=self.stream_variant,
            eps=self.eps,
            c_const=self.c_const,
            num_shards=self.num_shards,
            block_size=self.block_size,
            placement=self.placement,
            publish_every=self.publish_every,
            max_pending=int(self._queue.maxsize),
        )

    def _ckpt_meta(self) -> dict:
        return dict(
            version=1,
            kind=(
                "list" if isinstance(self._state, list)
                else "stacked" if self.num_shards > 1
                else "single"
            ),
            wal_seq=self._applied_seq,
            next_seq=self._next_seq,
            n_offered=self.n_offered,
            rr=self._rr,
            epoch=self.epochs_published,
            fingerprint=self._fingerprint,
            poisoned_seqs=list(self._poisoned_seqs),
            config=self._config_dict(),
        )

    def checkpoint(self, *, force: bool = True) -> Optional[str]:
        """Persist the scan state to the durability dir; returns the
        checkpoint path, or ``None`` when skipped (no durability
        configured, nothing ingested yet, or — with ``force=False``, the
        worker's cadence call — fewer than ``checkpoint_every`` batches
        applied since the last one).

        A failed save (including an injected ``checkpoint.write`` fault)
        is counted in ``serve.ckpt.failures`` and logged; serving
        continues and the previous checkpoint stays intact (saves are
        write-temp-then-rename). After a successful save, checkpoints
        beyond ``keep`` are pruned and the WAL is compacted to the oldest
        retained checkpoint's watermark.
        """
        dur = self.durability
        if dur is None:
            return None
        with self._cv:
            if self._state is None:
                return None
            if (
                not force
                and self._applied_seq - self._last_ckpt_seq
                < dur.checkpoint_every
            ):
                return None
            # host-materialize under the lock: the next ingest donates
            # the live buffers, so the copy must finish before it runs
            if isinstance(self._state, list):
                host_state: Union[list, object] = [
                    jax.tree_util.tree_map(np.asarray, st)
                    for st in self._state
                ]
            else:
                host_state = jax.tree_util.tree_map(
                    np.asarray, self._state
                )
            meta = self._ckpt_meta()
            path = checkpoint_path(
                dur.dir, self.n_offered, self._fingerprint
            )
            wal_seq = self._applied_seq
        try:
            save_checkpoint(
                path, host_state, meta,
                faults=self.faults, fsync=dur.fsync,
            )
        except Exception as e:  # noqa: BLE001 — counted, serving continues
            self._m_ckpt_failures.inc()
            _log.warning(
                "checkpoint save failed (%s: %s); serving continues on "
                "the previous checkpoint + WAL",
                type(e).__name__, e,
            )
            return None
        with self._cv:
            self._last_ckpt_seq = max(self._last_ckpt_seq, wal_seq)
        self._m_ckpt_saved.inc()
        self._m_ckpt_last_seq.set(wal_seq)
        floor = prune_checkpoints(dur.dir, dur.keep)
        if self._wal is not None and floor >= 0:
            try:
                self._wal.compact(floor)
            except Exception as e:  # noqa: BLE001 — counted; the
                # superset log replays correctly, compaction retries on
                # the next checkpoint cadence
                self.registry.counter("serve.wal.compact_errors").inc()
                _log.warning(
                    "WAL compaction failed (%s: %s); serving continues "
                    "on the uncompacted log", type(e).__name__, e,
                )
        return path

    @classmethod
    def restore(
        cls,
        durability: Union[DurabilityConfig, str],
        *,
        spec: Optional[MatroidSpec] = None,
        oracle=None,
        on_publish: Optional[Callable[[EpochSnapshot], None]] = None,
        registry: Optional[obs.MetricsRegistry] = None,
        durability_out: Optional[Union[DurabilityConfig, str]] = None,
        fault_policy: Optional[FaultPolicy] = None,
        faults: Optional[FaultPlan] = None,
        **overrides,
    ) -> "StreamRuntime":
        """Rebuild a runtime from its durability dir: load the newest
        valid checkpoint, then replay the WAL tail in submission order —
        the restored stream is bit-identical to the one that died
        (§3: the state is a pure fold over the batch sequence, and the
        scan is deterministic given the same config).

        The constructor config is read from the checkpoint; ``spec`` and
        keyword ``overrides`` (``k=``, ``tau=``, ...) take precedence and
        are *required* when no checkpoint exists yet (WAL-only restore).
        Host oracles and callbacks are not serializable — pass them
        again. Batches quarantined before the checkpoint are skipped on
        replay (matching the live post-quarantine stream); quarantined
        batches *newer* than the checkpoint are re-attempted (the failure
        was transient by definition — at-least-once, in order).

        The outcome is summarized in ``runtime.restore_report``
        (checkpoint path, replayed batches/points, wall time, recovered
        epoch fingerprint).
        """
        dur = (
            DurabilityConfig(dir=durability)
            if isinstance(durability, str) else durability
        )
        t0 = time.perf_counter()
        path = latest_checkpoint(dur.dir)
        state = None
        meta: Optional[dict] = None
        cfg: dict = {}
        if path is not None:
            state, meta = load_checkpoint(path)
            cfg = dict(meta["config"])
        if spec is None:
            if "spec" not in cfg:
                raise ValueError(
                    "no checkpoint to read the config from: WAL-only "
                    "restore needs spec= plus k=/tau=/... overrides"
                )
            spec = MatroidSpec(**cfg["spec"])
        kw = dict(
            k=cfg.get("k"),
            tau=cfg.get("tau"),
            metric=cfg.get("metric", "euclidean"),
            caps=cfg.get("caps"),
            slot_cap=cfg.get("slot_cap"),
            variant=cfg.get("variant", "radius"),
            eps=cfg.get("eps", 0.5),
            c_const=cfg.get("c_const", 32),
            num_shards=cfg.get("num_shards", 1),
            block_size=cfg.get("block_size", 128),
            placement=cfg.get("placement", "auto"),
            publish_every=cfg.get("publish_every", 8),
            max_pending=cfg.get("max_pending", 64),
        )
        kw.update(overrides)
        k = kw.pop("k")
        if k is None or kw["tau"] is None:
            raise ValueError(
                "no checkpoint to read the config from: WAL-only restore "
                "needs k= and tau= overrides"
            )
        caps = kw.pop("caps")
        rt = cls(
            spec, int(k),
            caps=None if caps is None else np.asarray(caps, np.int32),
            oracle=oracle, on_publish=on_publish, registry=registry,
            durability=dur, fault_policy=fault_policy, faults=faults,
            **kw,
        )
        if meta is not None:
            with rt._cv:
                if meta["kind"] == "list":
                    devs = jax.devices()
                    rt._state = [
                        jax.device_put(st, devs[i % len(devs)])
                        for i, st in enumerate(state)
                    ]
                    rt._fp_cache = None
                else:
                    rt._state = jax.tree_util.tree_map(jnp.asarray, state)
                rt.n_offered = int(meta["n_offered"])
                rt._rr = int(meta.get("rr", 0))
                rt.epochs_published = int(meta.get("epoch", 0))
                rt._next_seq = int(meta["next_seq"])
                rt._applied_seq = int(meta["wal_seq"])
                rt._last_ckpt_seq = rt._applied_seq
                rt._poisoned_seqs = [
                    int(s) for s in meta.get("poisoned_seqs", ())
                ]
                rt._fingerprint, rt._coreset_size = (
                    rt._fingerprint_and_size()
                )
                rt._fp_history.append((rt.n_offered, rt._fingerprint))
                rt._dirty = True
        # replay the WAL tail: records newer than the checkpoint's
        # watermark, in file order == submission order
        replayed = 0
        replayed_points = 0
        skipped = 0
        poisoned = set(rt._poisoned_seqs)
        rt._replaying = True
        try:
            for rec in rt._wal.replay(after_seq=rt._applied_seq):
                with rt._cv:
                    rt._next_seq = max(rt._next_seq, rec.seq + 1)
                    rt._applied_seq = rec.seq
                if rec.seq in poisoned:
                    skipped += 1
                    continue
                try:
                    rt.ingest(rec.points, rec.cats)
                except Exception as e:  # noqa: BLE001 — skip + count
                    rt.registry.counter("serve.wal.replay_errors").inc()
                    _log.warning(
                        "WAL replay of seq %d failed (%s: %s); skipped",
                        rec.seq, type(e).__name__, e,
                    )
                    continue
                replayed += 1
                replayed_points += int(rec.points.shape[0])
        finally:
            rt._replaying = False
        snap = rt.refresh(force=True) if rt._state is not None else None
        rt.restore_report = dict(
            checkpoint=path,
            replayed_batches=replayed,
            replayed_points=replayed_points,
            skipped_poisoned=skipped,
            restore_s=time.perf_counter() - t0,
            epoch=0 if snap is None else snap.epoch,
            fingerprint=None if snap is None else snap.fingerprint,
            n_offered=rt.n_offered,
        )
        return rt

    def close(
        self, *, drain: bool = True, timeout: Optional[float] = 30.0
    ) -> None:
        """Stop the async worker (idempotent).

        ``drain=True`` (default) first waits — up to ``timeout`` seconds
        — for every already-submitted batch to be ingested, so close
        never silently discards accepted work; on expiry it raises
        ``TimeoutError`` *without* closing (retry, or force with
        ``close(drain=False)``). ``drain=False`` stops immediately:
        still-queued batches are dropped, counted in
        ``serve.worker.dropped_batches{reason=close}``, and surfaced as
        a worker error to any later ``flush``/``acquire`` — they were
        accepted but never ingested (on a durable runtime they are in
        the WAL and come back on ``restore``).

        Synchronous ingestion and published epochs remain usable after
        close; further ``submit`` calls raise ``RuntimeError``.
        """
        if drain:
            deadline = (
                None if timeout is None else time.monotonic() + timeout
            )
            with self._cv:
                while (
                    not self._closed
                    and self._pending > 0
                    and self._worker_err is None
                ):
                    remaining = (
                        None if deadline is None
                        else deadline - time.monotonic()
                    )
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError(
                            f"close(drain=True) timed out with "
                            f"{self._pending} batch(es) pending; retry, "
                            f"or force-drop with close(drain=False)"
                        )
                    self._cv.wait(remaining)
        with self._cv:
            if self._closed:
                return
            self._closed = True
            if not drain:
                self._force_stop = True
            worker = self._worker
        if worker is not None:
            self._queue.put(_STOP)
            worker.join(timeout=60.0)
        if (
            self.durability is not None
            and self._applied_seq > self._last_ckpt_seq
        ):
            # parting save: a cleanly closed durable runtime restores
            # from its checkpoint alone, no config overrides needed
            self.checkpoint(force=True)
        if self._wal is not None:
            self._wal.close()

    def __enter__(self) -> "StreamRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
