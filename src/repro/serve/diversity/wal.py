"""Write-ahead log for the diversity stream.

The paper's §3 composability makes the stream itself the unit of
durability: a ``StreamState`` is a pure fold over the batch sequence, so
"what the service knows" is fully determined by (a serialized state, the
tail of batches after it). This module is the tail: an append-only
binary log of submitted batches, written *before* a batch is enqueued
for ingestion, so a crash between submit and ingest loses nothing the
caller was told was accepted.

Record framing (little-endian), after a one-line magic header:

    u64 seq | u32 n | u32 d | u32 gamma | u32 crc || f32[n,d] || i32[n,gamma]

``crc`` is ``zlib.crc32`` over the header prefix + payload, so replay
detects a torn tail (a crash mid-append) and stops cleanly at the last
whole record instead of feeding garbage to the scan — the torn record's
batch was never acknowledged as durable anyway (``append`` raises on
failure). ``gamma == 0`` encodes "no cats passed" (replay hands the
scan ``None``, exactly like the live call).

``seq`` is the runtime's submission ordinal: strictly increasing within
one log, possibly with gaps (a batch whose append failed burns its seq).
Replay yields records in file order = submission order, the order the
single ingest worker applies them — so checkpoint + replayed tail is
bit-identical to the uninterrupted stream. ``compact(upto_seq)``
atomically rewrites the log keeping only records after a checkpoint.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import struct
import threading
import zlib
from typing import Iterator, Optional

import numpy as np

from ... import obs

_MAGIC = b"DMMCWAL1\n"
_HDR = struct.Struct("<QIIII")  # seq, n, d, gamma, crc

_log = logging.getLogger("repro.serve.diversity.wal")


@dataclasses.dataclass(frozen=True)
class WalRecord:
    seq: int
    points: np.ndarray  # f32[n, d]
    cats: Optional[np.ndarray]  # i32[n, gamma] or None (gamma == 0)


class WalError(RuntimeError):
    """A WAL append failed: the batch is NOT durable (and was not
    enqueued). The submitter must retry or accept the loss."""


class WriteAheadLog:
    """Append-only batch log with CRC-framed records (thread-safe)."""

    def __init__(
        self,
        path: str,
        *,
        fsync: bool = False,
        faults=None,
        registry: Optional[obs.MetricsRegistry] = None,
    ):
        self.path = path
        self.fsync = bool(fsync)
        self.faults = faults
        self._mu = threading.Lock()
        self._f = None
        reg = registry if registry is not None else obs.default_registry()
        self._m_appends = reg.counter("serve.wal.appends")
        self._m_bytes = reg.counter("serve.wal.bytes")
        self._m_append_errors = reg.counter("serve.wal.append_errors")
        self._m_replayed = reg.counter("serve.wal.replayed")
        self._m_torn = reg.counter("serve.wal.torn_records")

    # -- writing -------------------------------------------------------

    def _ensure_open(self):
        if self._f is None:
            fresh = (
                not os.path.exists(self.path)
                or os.path.getsize(self.path) == 0
            )
            self._f = open(self.path, "ab")
            if fresh:
                self._f.write(_MAGIC)
                self._f.flush()

    def append(
        self, seq: int, points: np.ndarray, cats: Optional[np.ndarray]
    ) -> None:
        """Durably append one batch; raises ``WalError`` on any failure
        (injected or real) — the caller must treat the batch as not
        accepted."""
        pts = np.ascontiguousarray(points, np.float32)
        n, d = pts.shape
        if cats is None:
            cbytes, gamma = b"", 0
        else:
            carr = np.ascontiguousarray(cats, np.int32).reshape(n, -1)
            cbytes, gamma = carr.tobytes(), carr.shape[1]
        payload = pts.tobytes() + cbytes
        prefix = struct.pack("<QIII", seq, n, d, gamma)
        crc = zlib.crc32(prefix + payload) & 0xFFFFFFFF
        rec = _HDR.pack(seq, n, d, gamma, crc) + payload
        with self._mu:
            try:
                if self.faults is not None:
                    self.faults.check("wal.append")
                self._ensure_open()
                self._f.write(rec)
                self._f.flush()
                if self.fsync:
                    os.fsync(self._f.fileno())
            except Exception as e:
                self._m_append_errors.inc()
                raise WalError(
                    f"WAL append of batch seq={seq} failed; the batch is "
                    f"not durable and was not enqueued"
                ) from e
            self._m_appends.inc()
            self._m_bytes.inc(len(rec))

    # -- reading -------------------------------------------------------

    def replay(self, after_seq: int = -1) -> Iterator[WalRecord]:
        """Yield whole records with ``seq > after_seq`` in file order.

        Stops (with a warning + ``serve.wal.torn_records``) at the first
        truncated or CRC-corrupt record: that is the torn tail of a
        crash mid-append, never acknowledged to the submitter.
        """
        with self._mu:
            if self._f is not None:
                self._f.flush()
        yield from self._iter_records(after_seq)

    def _iter_records(self, after_seq: int) -> Iterator[WalRecord]:
        """Lock-free file scan (callers flush/serialize as needed)."""
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            magic = f.read(len(_MAGIC))
            if magic != _MAGIC:
                if magic:
                    self._m_torn.inc()
                    _log.warning("WAL %s: bad magic, ignoring log",
                                 self.path)
                return
            while True:
                hdr = f.read(_HDR.size)
                if not hdr:
                    return
                if len(hdr) < _HDR.size:
                    self._m_torn.inc()
                    _log.warning("WAL %s: torn header at tail", self.path)
                    return
                seq, n, d, gamma, crc = _HDR.unpack(hdr)
                nbytes = n * d * 4 + n * gamma * 4
                payload = f.read(nbytes)
                if len(payload) < nbytes:
                    self._m_torn.inc()
                    _log.warning("WAL %s: torn payload at seq %d",
                                 self.path, seq)
                    return
                prefix = struct.pack("<QIII", seq, n, d, gamma)
                if zlib.crc32(prefix + payload) & 0xFFFFFFFF != crc:
                    self._m_torn.inc()
                    _log.warning("WAL %s: CRC mismatch at seq %d",
                                 self.path, seq)
                    return
                if seq <= after_seq:
                    continue
                pts = np.frombuffer(
                    payload[: n * d * 4], np.float32
                ).reshape(n, d).copy()
                cats = None
                if gamma:
                    cats = np.frombuffer(
                        payload[n * d * 4:], np.int32
                    ).reshape(n, gamma).copy()
                self._m_replayed.inc()
                yield WalRecord(seq=int(seq), points=pts, cats=cats)

    def last_seq(self) -> int:
        """Highest whole-record seq in the log (-1 when empty)."""
        last = -1
        for rec in self.replay():
            last = rec.seq
        return last

    # -- compaction ----------------------------------------------------

    def compact(self, upto_seq: int) -> None:
        """Atomically drop records with ``seq <= upto_seq`` (they are
        covered by a checkpoint). The rewrite goes to a temp file that
        replaces the log in one ``os.replace`` — a crash mid-compaction
        leaves the old (superset) log, which replays correctly. The lock
        is held throughout, so a concurrent ``append`` can never land in
        the about-to-be-replaced file and get lost."""
        with self._mu:
            if self._f is not None:
                self._f.flush()
                self._f.close()
                self._f = None
            keep = list(self._iter_records(after_seq=upto_seq))
            tmp = self.path + ".compact"
            with open(tmp, "wb") as f:
                f.write(_MAGIC)
                for rec in keep:
                    pts = np.ascontiguousarray(rec.points, np.float32)
                    n, d = pts.shape
                    if rec.cats is None:
                        cbytes, gamma = b"", 0
                    else:
                        carr = np.ascontiguousarray(rec.cats, np.int32)
                        cbytes, gamma = carr.tobytes(), carr.shape[1]
                    payload = pts.tobytes() + cbytes
                    prefix = struct.pack("<QIII", rec.seq, n, d, gamma)
                    crc = zlib.crc32(prefix + payload) & 0xFFFFFFFF
                    f.write(_HDR.pack(rec.seq, n, d, gamma, crc) + payload)
                f.flush()
                if self.fsync:
                    os.fsync(f.fileno())
            # chaos site: at this point BOTH generations are on disk
            # (old log at self.path, replacement at tmp). A crash here
            # must restore bit-identically from either file.
            if self.faults is not None:
                self.faults.check("wal.compact")
            os.replace(tmp, self.path)

    def close(self) -> None:
        with self._mu:
            if self._f is not None:
                self._f.flush()
                self._f.close()
                self._f = None
