"""Tenant registry: many logical serving configurations over ONE stream.

The paper's §3 composability says the coreset is a *substrate*: any
``(matroid, tau, metric)`` view can be solved on it. The registry turns
that into serving fan-out — one physical scan feeds N tenants, each of
which owns

* a ``CacheKey`` (its ``(MatroidSpec, tau, metric)`` triple) naming its
  private ``DistanceCache`` entry — its own pdist matrix, invalidated only
  when the shared stream publishes a changed epoch;
* its own solver eligibility: the matroid spec/caps/oracle its queries are
  constrained by, dispatched through the ``core.solvers`` registry exactly
  like a single-tenant service.

Tenants with *identical* keys share one cache entry (the matrix depends
only on the coreset and the metric); tenants with different metrics get a
re-normalized copy of the epoch's points. Registering a tenant costs
nothing until its first query builds its entry — fan-out is cache-shaped,
not stream-shaped.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import numpy as np

from ...core import geometry
from ...core.matroid import MatroidSpec
from .cache import CacheKey

DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One logical serving configuration over the shared stream."""

    name: str
    spec: MatroidSpec
    tau: int
    metric: str
    caps: Optional[np.ndarray]
    oracle: object = None

    @property
    def key(self) -> CacheKey:
        return CacheKey(spec=self.spec, tau=self.tau, metric=self.metric)


class TenantRegistry:
    """Name -> ``Tenant`` map with the same admission rules as a
    single-tenant service (partition needs caps, general needs an
    oracle). Thread-safe; re-registering an identical configuration is a
    no-op, a conflicting one raises."""

    def __init__(self):
        self._tenants: dict[str, Tenant] = {}
        self._mu = threading.Lock()

    def register(
        self,
        name: str,
        *,
        spec: MatroidSpec,
        tau: int,
        metric: geometry.Metric,
        caps: Optional[np.ndarray] = None,
        oracle=None,
    ) -> Tenant:
        if spec.kind == "general" and oracle is None:
            raise ValueError(f"general-matroid tenant {name!r} needs an oracle")
        if spec.kind == "partition" and caps is None:
            raise ValueError(
                f"partition tenant {name!r} needs per-category caps"
            )
        t = Tenant(
            name=name,
            spec=spec,
            tau=int(tau),
            metric=str(metric),
            caps=None if caps is None else np.asarray(caps, np.int32),
            oracle=oracle,
        )
        with self._mu:
            old = self._tenants.get(name)
            if old is not None:
                same = (
                    old.spec == t.spec
                    and old.tau == t.tau
                    and old.metric == t.metric
                    and old.oracle is t.oracle
                    and (
                        (old.caps is None and t.caps is None)
                        or (
                            old.caps is not None
                            and t.caps is not None
                            and np.array_equal(old.caps, t.caps)
                        )
                    )
                )
                if same:
                    return old
                raise ValueError(
                    f"tenant {name!r} already registered with a different "
                    f"configuration"
                )
            self._tenants[name] = t
            return t

    def get(self, name: str) -> Tenant:
        with self._mu:
            try:
                return self._tenants[name]
            except KeyError:
                raise KeyError(
                    f"unknown tenant {name!r}; registered: "
                    f"{sorted(self._tenants)}"
                ) from None

    def names(self) -> list[str]:
        with self._mu:
            return sorted(self._tenants)

    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, name: str) -> bool:
        return name in self._tenants
