"""Replicated serving: WAL-shipped hot standbys + fingerprint-verified
failover.

The paper's composability argument (§3: the coreset scan is a pure fold
over the batch sequence) is exactly the property that makes state-machine
replication cheap. A ``ReplicaSet`` runs one *primary* ``StreamRuntime``
and one or more *standby* runtimes; every batch accepted by
``ReplicaSet.submit`` is

  1. appended to the primary's write-ahead log (``submit`` is
     log-then-enqueue, so once it returns the batch is durable),
  2. shipped — same seq, same bytes — into each standby's apply queue,
  3. acked to the submitter.

Each standby replays shipped records through its own supervised ingest
path (``StreamRuntime.submit``: worker thread, retry/quarantine policy,
its own WAL carrying the *same* seq numbers) and publishes its own
``EpochSnapshot``s — so a standby is a complete, query-able serving stack
at all times, not a cold spare.

Divergence detection is O(1) host sync: both replicas see the identical
batch sequence, so the ``n_offered`` watermark after each ingest is a
shared coordinate, and ``StreamRuntime.fingerprint_at(n)`` compares the
coreset content hashes recorded at that watermark. A standby whose
fingerprint disagrees with the primary's at any common watermark
*self-fences* (excluded from reads and from promotion) and is re-seeded
from the primary's latest checkpoint instead of ever serving a wrong
answer.

Failover promotes the most-caught-up healthy standby: its apply queue is
drained, the old primary's durable WAL tail (records the standby never
saw — acked batches survive there by construction) is replayed on top,
and only then does it start taking new submissions. In-window coalesced
query calls parked on the dead primary's frontend are drained un-failed
(``QueryFrontend.drain_pending``) and re-dispatched on the promoted
frontend (``adopt_pending``), so blocked callers get answers, not
"frontend closed" errors.

Chaos sites (see ``faults.py``): ``replication.ship`` (drop a shipped
record on the wire — the standby heals from the primary's WAL, or
re-seeds if compaction already folded the record into a checkpoint) and
``replica.crash`` (kill a standby's apply thread).

Metrics: ``serve.replication.shipped`` / ``ship_errors`` / ``applied`` /
``lag_batches`` (gauge per replica + histogram) / ``divergence`` /
``reseeds`` / ``failovers`` / ``failover_s`` / ``stale_reads``.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import os
import threading
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core.matroid import MatroidSpec
from .checkpoint import DurabilityConfig, latest_checkpoint, load_checkpoint
from .faults import FaultPlan, FaultPolicy, InjectedCrash, InjectedFault
from .frontend import QueryFrontend
from .runtime import StreamRuntime
from .wal import WalError, WalRecord

_log = logging.getLogger("repro.serve.diversity.replication")


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Knobs for a ``ReplicaSet``.

    apply_poll_s              standby apply-thread wakeup cadence while idle;
    promote_timeout_s         bound on the promoted standby's queue-drain +
                              WAL-tail replay + flush during failover;
    saturation_active_calls   route deadline-free reads to a standby when
                              the primary frontend has at least this many
                              calls in flight (stale-but-consistent reads);
    fence_on_divergence       a fingerprint mismatch fences the standby;
    reseed_on_divergence      a fenced standby is automatically re-seeded
                              from the primary's latest checkpoint on the
                              next ``verify_standbys``/``repair`` pass;
    max_read_lag_batches      a standby more than this many acked batches
                              behind is skipped for stale reads.
    """

    apply_poll_s: float = 0.05
    promote_timeout_s: float = 30.0
    saturation_active_calls: int = 4
    fence_on_divergence: bool = True
    reseed_on_divergence: bool = True
    max_read_lag_batches: int = 64


@dataclasses.dataclass
class Replica:
    """One serving stack (runtime + frontend) inside a ``ReplicaSet``."""

    name: str
    runtime: StreamRuntime
    frontend: QueryFrontend


class ReplicationGap(RuntimeError):
    """Shipped records were lost AND already compacted out of the
    primary's WAL — the standby cannot catch up by tail replay and must
    re-seed from a checkpoint."""


class Standby:
    """A hot standby: wraps a full serving stack plus the apply thread
    that replays shipped WAL records through it in seq order.

    The standby's runtime should be *durable* (its own WAL/checkpoint
    dir): applied records land in its log under the primary's seq
    numbers, which is what makes it promotable with full durability.
    """

    def __init__(
        self,
        name: str,
        runtime: StreamRuntime,
        frontend: QueryFrontend,
        *,
        config: Optional[ReplicationConfig] = None,
        fetch_tail: Optional[Callable[[int, int], "list[WalRecord]"]] = None,
        ckpt_floor: Optional[Callable[[], int]] = None,
        faults: Optional[FaultPlan] = None,
    ):
        self.name = name
        self.runtime = runtime
        self.frontend = frontend
        self.config = config if config is not None else ReplicationConfig()
        self.faults = faults if faults is not None else runtime.faults
        self._fetch_tail = fetch_tail
        self._ckpt_floor = ckpt_floor
        self._q: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self.dead = False  # apply thread crashed (replica.crash)
        self.fenced = False
        self.fence_reason: Optional[str] = None
        self.quarantined = False  # set by the integrity auditor
        self.needs_reseed = False
        self.applied_upto = -1  # newest seq fed into the supervised path
        self.shipped_upto = -1  # newest seq enqueued by ship()
        self.verified_at = -1  # newest watermark with confirmed parity
        reg = runtime.registry
        self._m_applied = reg.counter(
            "serve.replication.applied", replica=name
        )
        self._m_gap_heals = reg.counter(
            "serve.replication.gap_heals", replica=name
        )
        self._m_crashes = reg.counter(
            "serve.replication.apply_crashes", replica=name
        )
        self._thread: Optional[threading.Thread] = threading.Thread(
            target=self._apply_loop, name=f"standby-{name}", daemon=True
        )
        self._thread.start()

    # -- shipping side -------------------------------------------------

    def ship(self, rec: WalRecord) -> None:
        """Enqueue one primary WAL record for apply (never blocks)."""
        with self._cv:
            self._q.append(rec)
            self.shipped_upto = max(self.shipped_upto, rec.seq)
            self._cv.notify_all()

    @property
    def lag_batches(self) -> int:
        """Shipped-but-unapplied record count (the queue view of lag;
        the ``ReplicaSet`` computes acked-vs-applied lag on top)."""
        with self._cv:
            return len(self._q)

    @property
    def promotable(self) -> bool:
        return not (self.dead or self.fenced or self.quarantined)

    # -- apply side ----------------------------------------------------

    def _apply_loop(self) -> None:
        try:
            while True:
                with self._cv:
                    while (not self._q or self.fenced) and not self._closed:
                        self._cv.wait(self.config.apply_poll_s)
                    if self._closed:
                        return
                    if self.fenced:
                        continue
                    rec = self._q.popleft()
                try:
                    self._apply_record(rec)
                except InjectedFault as e:
                    # transient apply failure: the record stays
                    # unapplied — the next shipped record's gap fetch
                    # recovers it from the primary's WAL
                    _log.warning(
                        "standby %s apply of seq %d failed "
                        "(injected, will gap-heal): %s",
                        self.name, rec.seq, e,
                    )
        except InjectedCrash:
            self.dead = True
            self._m_crashes.inc()
            _log.warning("standby %s apply thread killed (injected)",
                         self.name)
        except Exception as e:  # noqa: BLE001 — a dead standby is a
            # health condition, not a crash of the whole set
            self.dead = True
            self._m_crashes.inc()
            _log.warning("standby %s apply thread died: %s: %s",
                         self.name, type(e).__name__, e)

    def _apply_record(self, rec: WalRecord) -> None:
        if self.faults is not None:
            # "crash" kills the apply thread (caught in _apply_loop);
            # "error" is a transient apply failure -> the record stays
            # unapplied and the gap heals from the primary's WAL later
            self.faults.check("replica.crash")
        if rec.seq <= self.applied_upto:
            return  # already covered (reseed raced a queued record)
        expect = self.applied_upto + 1
        if rec.seq > expect and self._fetch_tail is not None:
            # ship gap (a dropped record): recover the missing span from
            # the primary's durable log. Seqs absent from the log that a
            # checkpoint may cover force a re-seed; seqs absent and NOT
            # checkpoint-covered were burned (append failed, never
            # acked) and are safely skipped.
            recs = self._fetch_tail(self.applied_upto, rec.seq - 1)
            got = {r.seq for r in recs}
            missing = [s for s in range(expect, rec.seq) if s not in got]
            floor = self._ckpt_floor() if self._ckpt_floor else -1
            if any(s <= floor for s in missing):
                self._fence(
                    f"wal gap: seqs {missing} already compacted into a "
                    f"checkpoint (floor={floor})"
                )
                self.needs_reseed = True
                return
            for r in recs:
                self._apply_one(r)
                self._m_gap_heals.inc()
        self._apply_one(rec)

    def _apply_one(self, rec: WalRecord) -> None:
        rt = self.runtime
        with rt._cv:
            # force the standby's own WAL to carry the primary's seq: the
            # two logs stay record-for-record identical
            rt._next_seq = rec.seq
        rt.submit(rec.points, rec.cats)
        self.applied_upto = rec.seq
        self._m_applied.inc()

    # -- divergence ----------------------------------------------------

    def verify(self, primary_rt: StreamRuntime) -> Optional[bool]:
        """O(1) parity check: compare this standby's newest recorded
        ``(n_offered, fingerprint)`` against the primary's fingerprint at
        the same watermark. Returns ``True`` (parity), ``False``
        (divergence — the standby fences itself), or ``None`` when no
        common watermark exists yet."""
        rt = self.runtime
        with rt._cv:
            hist_s = list(rt._fp_history)
        if not hist_s:
            return None
        with primary_rt._cv:
            hist_p = dict(primary_rt._fp_history)
            n_p = primary_rt.n_offered
            min_p = min(hist_p, default=0)
        # newest standby watermark the primary can judge. The primary
        # records EVERY ingest boundary, so within [min_p, n_p] its
        # history coverage is contiguous — a standby watermark in that
        # range that the primary never recorded means the standby folded
        # a batch boundary the primary never had (itself divergence).
        for ns, fps in reversed(hist_s):
            if ns > n_p:
                continue  # primary hasn't reached this watermark yet
            if ns < min_p:
                return None  # aged out of the primary's bounded history
            fpp = hist_p.get(ns)
            if fpp == fps:
                self.verified_at = max(self.verified_at, ns)
                return True
            if self.config.fence_on_divergence:
                if fpp is None:
                    self._fence(
                        f"watermark misalignment at n_offered={ns}: the "
                        f"primary never ingested to that boundary"
                    )
                else:
                    self._fence(
                        f"fingerprint divergence at n_offered={ns}: "
                        f"primary={fpp:#x} standby={fps:#x}"
                    )
                self.needs_reseed = True
            return False
        return None

    def _fence(self, reason: str) -> None:
        with self._cv:
            if not self.fenced:
                self.fenced = True
                self.fence_reason = reason
                self.runtime.registry.counter(
                    "serve.replication.divergence", replica=self.name
                ).inc()
                _log.warning("standby %s fenced: %s", self.name, reason)

    # -- lifecycle -----------------------------------------------------

    def stop(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the apply thread; with ``drain=True`` any backlog still
        queued is applied inline by the caller (promotion path). Records
        that fail to apply here are recovered by the promoted runtime's
        WAL-tail replay, so a fault mid-drain cannot lose acked data."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join(timeout)
        self._thread = None
        if not drain:
            return
        while True:
            with self._cv:
                if not self._q:
                    return
                rec = self._q.popleft()
            try:
                self._apply_record(rec)
            except (InjectedCrash, Exception):  # noqa: BLE001 — see above
                continue

    def close(self) -> None:
        self.stop(drain=False)
        self.frontend.close()
        try:
            self.runtime.close(drain=False)
        except BaseException:  # noqa: BLE001 — best-effort teardown
            pass


class ReplicaSet:
    """Façade over a primary + standbys: every write is WAL-appended on
    the primary, shipped to all standbys, then acked; reads go to the
    primary unless it is saturated (deadline-free reads may fall back to
    a caught-up standby); primary death promotes the most-caught-up
    standby after replaying its WAL tail. See the module docstring for
    the durability argument.
    """

    def __init__(
        self,
        primary: Replica,
        standbys: Sequence[Standby],
        *,
        config: Optional[ReplicationConfig] = None,
        faults: Optional[FaultPlan] = None,
        registry: Optional[obs.MetricsRegistry] = None,
    ):
        self.config = config if config is not None else ReplicationConfig()
        self.faults = faults if faults is not None else (
            primary.runtime.faults
        )
        self.registry = registry if registry is not None else (
            primary.runtime.registry
        )
        self._mu = threading.RLock()
        self._primary = primary
        self._standbys: list[Standby] = list(standbys)
        for sb in self._standbys:
            if sb._fetch_tail is None:
                sb._fetch_tail = self._tail_records
            if sb._ckpt_floor is None:
                sb._ckpt_floor = self._primary_ckpt_floor
        self._retired: list[Replica] = []
        self._acked_seq = -1
        self._acked_batches = 0
        self._acked_points = 0
        self._closed = False
        self.last_failover: Optional[dict] = None
        reg = self.registry
        self._m_shipped = reg.counter("serve.replication.shipped")
        self._m_ship_errors = reg.counter("serve.replication.ship_errors")
        self._m_acked = reg.counter("serve.replication.acked_batches")
        self._m_failovers = reg.counter("serve.replication.failovers")
        self._m_failover_s = reg.histogram("serve.replication.failover_s")
        self._m_reseeds = reg.counter("serve.replication.reseeds")
        self._m_stale_reads = reg.counter("serve.replication.stale_reads")
        self._m_lag_hist = reg.histogram("serve.replication.lag_batches")

    # -- construction --------------------------------------------------

    @classmethod
    def create(
        cls,
        spec: MatroidSpec,
        k: int,
        *,
        dir: str,
        n_standbys: int = 1,
        caps: Optional[np.ndarray] = None,
        oracle=None,
        registry: Optional[obs.MetricsRegistry] = None,
        config: Optional[ReplicationConfig] = None,
        faults: Optional[FaultPlan] = None,
        standby_faults: Optional[FaultPlan] = None,
        fault_policy: Optional[FaultPolicy] = None,
        durability: Optional[DurabilityConfig] = None,
        coalesce=None,
        **runtime_kw,
    ) -> "ReplicaSet":
        """Build a primary + ``n_standbys`` identically configured
        serving stacks under ``dir`` (each replica gets its own
        WAL/checkpoint subdirectory). ``faults`` instruments the
        primary, ``standby_faults`` the standbys; ``runtime_kw`` is
        forwarded to every ``StreamRuntime``."""
        reg = registry if registry is not None else obs.default_registry()
        cfg = config if config is not None else ReplicationConfig()

        def _dur(sub: str) -> DurabilityConfig:
            base = durability if durability is not None else (
                DurabilityConfig(dir="")
            )
            return dataclasses.replace(base, dir=os.path.join(dir, sub))

        def _stack(name: str, plan) -> tuple[StreamRuntime, QueryFrontend]:
            rt = StreamRuntime(
                spec, k, caps=caps, oracle=oracle, registry=reg,
                durability=_dur(name), faults=plan,
                fault_policy=fault_policy, **runtime_kw,
            )
            fe = QueryFrontend(rt, registry=reg, coalesce=coalesce)
            return rt, fe

        prt, pfe = _stack("primary", faults)
        primary = Replica(name="primary", runtime=prt, frontend=pfe)
        standbys = []
        for i in range(n_standbys):
            srt, sfe = _stack(f"standby-{i}", standby_faults)
            standbys.append(Standby(
                f"standby-{i}", srt, sfe, config=cfg,
                faults=standby_faults,
            ))
        return cls(
            primary, standbys, config=cfg, faults=faults, registry=reg,
        )

    # -- topology ------------------------------------------------------

    @property
    def primary(self) -> Replica:
        return self._primary

    @property
    def standbys(self) -> "list[Standby]":
        return list(self._standbys)

    @property
    def acked_seq(self) -> int:
        return self._acked_seq

    def register_tenant(self, name: str, **kw):
        """Register a tenant on every replica's frontend (so stale reads
        and post-failover serving see the same tenant set). Returns the
        primary's ``Tenant`` handle."""
        with self._mu:
            t = self._primary.frontend.register_tenant(name, **kw)
            for sb in self._standbys:
                sb.frontend.register_tenant(name, **kw)
            return t

    # -- write path ----------------------------------------------------

    def submit(
        self, points: np.ndarray, cats: Optional[np.ndarray] = None
    ) -> int:
        """Durably accept one batch: primary WAL append (log-then-
        enqueue), ship to every standby, then ack. Once this returns,
        the batch survives the death of the primary *process* (its WAL
        row) and of the primary *runtime* (the shipped copies + failover
        tail replay). If the primary is already unhealthy the set fails
        over and the batch is accepted by the promoted primary instead —
        the caller never has to know."""
        if self._closed:
            raise RuntimeError("replica set is closed")
        with self._mu:
            last_err: Optional[BaseException] = None
            for _attempt in range(2):
                p = self._primary
                try:
                    seq = p.runtime.submit(points, cats)
                    break
                except (WalError, ValueError):
                    raise  # durable-append failure / nonfinite: caller's
                except RuntimeError as e:
                    # dead worker / closed runtime: promote and retry once
                    last_err = e
                    self._failover_locked(
                        expect=p, reason=f"submit failed: {e}"
                    )
            else:
                raise RuntimeError(
                    "submit failed on primary and on the promoted standby"
                ) from last_err
            rec = WalRecord(
                seq=seq,
                points=np.asarray(points, np.float32),
                cats=None if cats is None else np.asarray(cats, np.int32),
            )
            for sb in self._standbys:
                self._ship(sb, rec)
            self._acked_seq = max(self._acked_seq, seq)
            self._acked_batches += 1
            self._acked_points += int(rec.points.shape[0])
            self._m_acked.inc()
            return seq

    def _ship(self, sb: Standby, rec: WalRecord) -> None:
        if self.faults is not None:
            try:
                self.faults.check("replication.ship")
            except InjectedFault as e:
                # dropped on the wire: the standby heals from the
                # primary's WAL (gap fetch) or re-seeds
                self._m_ship_errors.inc()
                _log.warning("ship seq %d -> %s dropped: %s",
                             rec.seq, sb.name, e)
                return
        sb.ship(rec)
        self._m_shipped.inc()

    def ingest(
        self, points: np.ndarray, cats: Optional[np.ndarray] = None
    ) -> int:
        """Alias of ``submit`` — all writes to a replica set go through
        the replicated path (a direct ``runtime.ingest`` would bypass
        shipping and diverge the standbys)."""
        return self.submit(points, cats)

    # -- read path -----------------------------------------------------

    def query_batch(
        self,
        queries,
        *,
        tenant=None,
        engine: str = "auto",
        min_epoch: Optional[int] = None,
        deadline_s: Optional[float] = None,
        allow_stale: bool = True,
    ):
        """Primary read, with two availability escapes: (1) when the
        primary frontend is saturated and the call has no freshness
        requirement (``min_epoch is None``), a caught-up healthy standby
        answers instead — stale-but-consistent, never torn; (2) a read
        that dies because the primary was being failed over retries once
        on the promoted primary."""
        p = self._primary
        if (
            allow_stale
            and min_epoch is None
            and p.frontend.active_calls()
            >= self.config.saturation_active_calls
        ):
            sb = self._pick_read_standby()
            if sb is not None:
                self._m_stale_reads.inc()
                return sb.frontend.query_batch(
                    queries, tenant=tenant, engine=engine,
                    deadline_s=deadline_s,
                )
        try:
            return p.frontend.query_batch(
                queries, tenant=tenant, engine=engine,
                min_epoch=min_epoch, deadline_s=deadline_s,
            )
        except RuntimeError:
            with self._mu:
                promoted = self._primary is not p
            if not promoted:
                raise
            return self._primary.frontend.query_batch(
                queries, tenant=tenant, engine=engine,
                min_epoch=min_epoch, deadline_s=deadline_s,
            )

    def query(self, q, **kw):
        return self.query_batch([q], **kw)[0]

    def _pick_read_standby(self) -> Optional[Standby]:
        best = None
        for sb in self._standbys:
            if not sb.promotable:
                continue
            if sb.runtime.latest() is None:
                continue
            lag = self._acked_seq - sb.applied_upto
            if lag > self.config.max_read_lag_batches:
                continue
            if best is None or sb.applied_upto > best.applied_upto:
                best = sb
        return best

    # -- fingerprint exchange + repair ---------------------------------

    def verify_standbys(self) -> dict:
        """One fingerprint-exchange round: each standby's newest
        watermark is compared against the primary (O(1) per standby —
        no flush, no coreset shipping). Divergent standbys fence; with
        ``reseed_on_divergence`` they are re-seeded immediately.
        Returns ``{standby name: True | False | None}``."""
        out = {}
        with self._mu:
            prt = self._primary.runtime
            for sb in self._standbys:
                if sb.dead:
                    out[sb.name] = None
                    continue
                out[sb.name] = sb.verify(prt)
            if self.config.reseed_on_divergence:
                self._repair_locked()
        return out

    def repair(self) -> int:
        """Re-seed every fenced standby from the primary's latest
        checkpoint. Returns the number of standbys repaired."""
        with self._mu:
            return self._repair_locked()

    def _repair_locked(self) -> int:
        n = 0
        for sb in self._standbys:
            if sb.fenced and sb.needs_reseed and not sb.dead:
                self._reseed_locked(sb)
                n += 1
        return n

    def _reseed_locked(self, sb: Standby) -> None:
        """Install the primary's latest checkpoint into a fenced standby
        and resume shipping past its watermark — the replication analogue
        of ``StreamRuntime.restore`` without a process restart."""
        p = self._primary
        path = p.runtime.checkpoint(force=True)
        if path is None:
            path = latest_checkpoint(p.runtime.durability.dir)
        if path is None:
            _log.warning("reseed %s: primary has no checkpoint", sb.name)
            return
        state, meta = load_checkpoint(path)
        rt = sb.runtime
        rt.flush(timeout=self.config.promote_timeout_s)
        with rt._cv:
            if meta["kind"] == "list":
                devs = jax.devices()
                rt._state = [
                    jax.device_put(st, devs[i % len(devs)])
                    for i, st in enumerate(state)
                ]
                rt._fp_cache = None
            else:
                rt._state = jax.tree_util.tree_map(jnp.asarray, state)
            rt.n_offered = int(meta["n_offered"])
            rt._rr = int(meta.get("rr", 0))
            rt._next_seq = int(meta["next_seq"])
            rt._applied_seq = int(meta["wal_seq"])
            rt._poisoned_seqs = [
                int(s) for s in meta.get("poisoned_seqs", ())
            ]
            rt._fingerprint, rt._coreset_size = rt._fingerprint_and_size()
            rt._fp_history.append((rt.n_offered, rt._fingerprint))
            rt._dirty = True
        rt.refresh(force=True)
        watermark = int(meta["wal_seq"])
        with sb._cv:
            sb.applied_upto = max(sb.applied_upto, watermark)
            sb._q = collections.deque(
                r for r in sb._q if r.seq > watermark
            )
            sb.fenced = False
            sb.fence_reason = None
            sb.needs_reseed = False
            sb._cv.notify_all()
        self._m_reseeds.inc()
        _log.info("standby %s re-seeded from %s (watermark=%d)",
                  sb.name, path, watermark)

    def _tail_records(
        self, after_seq: int, upto_seq: int
    ) -> "list[WalRecord]":
        """Primary WAL records with ``after_seq < seq <= upto_seq`` (the
        standby gap-heal fetch). Deliberately lock-free w.r.t. the set
        mutex: failover joins apply threads while holding it."""
        p = self._primary
        wal = p.runtime._wal
        if wal is None:
            return []
        out = []
        for rec in wal.replay(after_seq=after_seq):
            if rec.seq > upto_seq:
                break
            out.append(rec)
        return out

    def _primary_ckpt_floor(self) -> int:
        return self._primary.runtime._last_ckpt_seq

    # -- failover ------------------------------------------------------

    def check_primary(self) -> Optional[str]:
        """Cheap liveness probe of the primary (no failover): returns
        ``None`` when healthy, else the failure reason. The
        ``health.heartbeat`` chaos site fires here."""
        p = self._primary
        rt = p.runtime
        try:
            if self.faults is not None:
                self.faults.check("health.heartbeat")
            if rt._closed:
                return "primary runtime closed"
            with rt._cv:
                rt._raise_worker_error()
            return None
        except InjectedCrash as e:
            return f"heartbeat crashed: {e}"
        except Exception as e:  # noqa: BLE001 — any probe failure counts
            return f"{type(e).__name__}: {e}"

    def failover(self, *, reason: str = "manual",
                 expect: Optional[Replica] = None) -> str:
        """Promote the most-caught-up promotable standby. Returns the
        promoted replica's name. Raises when no standby is promotable."""
        with self._mu:
            return self._failover_locked(expect=expect, reason=reason)

    def _failover_locked(
        self, *, expect: Optional[Replica], reason: str
    ) -> str:
        old = self._primary
        if expect is not None and old is not expect:
            return old.name  # somebody already failed over
        t0 = time.perf_counter()
        with obs.span("failover", cat="replication", reason=reason):
            cands = [sb for sb in self._standbys if sb.promotable]
            if not cands:
                raise RuntimeError(
                    f"failover ({reason}): no promotable standby "
                    f"(of {len(self._standbys)})"
                )
            # 1. stop the old intake; park in-window coalesced calls
            try:
                drained = old.frontend.drain_pending()
            except BaseException:  # noqa: BLE001
                drained = []
            # 2. most-caught-up standby wins
            sb = max(cands, key=lambda s: (s.applied_upto, s.shipped_upto))
            # 3. replay its WAL tail: first its own apply queue, then
            #    whatever the old primary's durable log still holds
            #    beyond it — this is what makes acked == durable across
            #    the failover
            sb.stop(drain=True, timeout=self.config.promote_timeout_s)
            old_wal = old.runtime._wal
            if old_wal is not None:
                try:
                    for rec in old_wal.replay(after_seq=sb.applied_upto):
                        sb._apply_one(rec)
                except Exception as e:  # noqa: BLE001 — a torn old log
                    # tail ends the replay at the last whole record
                    _log.warning("failover tail replay stopped: %s", e)
            sb.runtime.flush(timeout=self.config.promote_timeout_s)
            # 4. retire the old primary (WAL read is done; close frees it)
            try:
                old.runtime.close(drain=False)
            except BaseException:  # noqa: BLE001 — it was dying anyway
                pass
            promoted = Replica(
                name=sb.name, runtime=sb.runtime, frontend=sb.frontend
            )
            self._standbys.remove(sb)
            self._retired.append(old)
            self._primary = promoted
            # 5. release callers parked on the dead frontend
            if drained:
                promoted.frontend.adopt_pending(drained)
        dt = time.perf_counter() - t0
        self._m_failovers.inc()
        self._m_failover_s.observe(dt)
        self.last_failover = dict(
            reason=reason,
            promoted=sb.name,
            retired=old.name,
            duration_s=dt,
            acked_seq=self._acked_seq,
            applied_seq=sb.applied_upto,
            drained_calls=len(drained),
            fingerprint=self._primary.runtime.fingerprint,
        )
        _log.warning("failover (%s): promoted %s in %.3fs",
                     reason, sb.name, dt)
        return sb.name

    # -- barriers + stats ----------------------------------------------

    def flush(self, *, timeout: Optional[float] = 120.0) -> int:
        """Primary freshness barrier (see ``StreamRuntime.flush``). A
        primary that died with acked batches still queued fails this
        barrier — the set promotes (the WAL-tail replay recovers those
        batches) and the flush lands on the new primary."""
        last_err: Optional[BaseException] = None
        for _attempt in range(2):
            p = self._primary
            try:
                return p.runtime.flush(timeout=timeout)
            except RuntimeError as e:
                last_err = e
                with self._mu:
                    self._failover_locked(
                        expect=p, reason=f"flush failed: {e}"
                    )
        raise RuntimeError(
            "flush failed on primary and on the promoted standby"
        ) from last_err

    def sync(self, *, timeout: float = 60.0) -> None:
        """Replication barrier: primary flushed AND every live standby
        has applied everything acked so far."""
        deadline = time.monotonic() + timeout
        self.flush(timeout=timeout)
        acked = self._acked_seq
        for sb in list(self._standbys):
            if not sb.promotable:
                continue
            while sb.applied_upto < acked:
                if sb.dead or time.monotonic() > deadline:
                    raise TimeoutError(
                        f"standby {sb.name} stuck at seq "
                        f"{sb.applied_upto} < acked {acked}"
                    )
                time.sleep(0.002)
            try:
                sb.runtime.flush(
                    timeout=max(0.1, deadline - time.monotonic())
                )
            except RuntimeError as e:
                # a standby whose own worker died is no longer a replica
                sb.dead = True
                _log.warning("standby %s failed sync flush: %s",
                             sb.name, e)

    def observe_lag(self) -> dict:
        """Record per-standby replication lag (acked - applied, in
        batches) into the gauge + histogram; returns the snapshot."""
        out = {}
        acked = self._acked_seq
        for sb in self._standbys:
            lag = max(0, acked - sb.applied_upto)
            out[sb.name] = lag
            self.registry.gauge(
                "serve.replication.lag_batches", replica=sb.name
            ).set(float(lag))
            self._m_lag_hist.observe(float(lag))
        return out

    def stats(self) -> dict:
        return dict(
            primary=self._primary.name,
            acked_seq=self._acked_seq,
            acked_batches=self._acked_batches,
            acked_points=self._acked_points,
            failovers=int(self._m_failovers.value),
            reseeds=int(self._m_reseeds.value),
            lag=self.observe_lag(),
            standbys=[
                dict(
                    name=sb.name,
                    applied_seq=sb.applied_upto,
                    shipped_seq=sb.shipped_upto,
                    verified_at=sb.verified_at,
                    fenced=sb.fenced,
                    fence_reason=sb.fence_reason,
                    dead=sb.dead,
                    quarantined=sb.quarantined,
                )
                for sb in self._standbys
            ],
            last_failover=self.last_failover,
        )

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._mu:
            for sb in self._standbys:
                sb.close()
            self._primary.frontend.close()
            try:
                self._primary.runtime.close(drain=True)
            except BaseException:  # noqa: BLE001 — best-effort teardown
                pass
            for r in self._retired:
                try:
                    r.frontend.close()
                except BaseException:  # noqa: BLE001
                    pass
