"""Online diversity serving stack (the paper's web-search/recommendation
workload, §1): keep a small (1-eps)-coreset as *the* serving state, ingest
the stream incrementally, answer many heterogeneous queries against cached
coreset distance matrices — never touching the full dataset.

Layered runtime (write path / read path / fan-out):

    rt = StreamRuntime(spec, k=10, tau=64, caps=caps)     # one stream
    fe = QueryFrontend(rt)                                # reads epochs
    rt.submit(batch, cats)                # async: background ingest loop
    fe.register_tenant("cosine", metric="cosine")         # cache fan-out
    res = fe.query(DiversityQuery(k=10), tenant="cosine")
    e = fe.flush()                        # freshness barrier -> epoch
    fe.query(DiversityQuery(k=10), min_epoch=e)   # read your own writes

Single-tenant façade (the historical API, unchanged):

    svc = DiversityService(spec, k=10, tau=64, caps=caps, metric="cosine")
    svc.ingest(batch, cats=batch_cats)          # any number of times
    res = svc.query(DiversityQuery(k=10))       # engine="auto": host parity
    out = svc.query_batch([q1, q2, ...])        # partitioned across engines

Queries dispatch through the ``core.solvers`` engine registry —
``engine="auto"`` (the default everywhere) batches sum queries under
uniform/partition/transversal matroids onto the vmapped jit solver and
keeps everything else on the host reference solvers, so every answer
matches ``solve_dmmc`` on the same coreset. See README "Serving
architecture" and "Solver engines".

Fault tolerance (README "Fault tolerance"): ``durability=`` adds a
write-ahead log + periodic checkpoints (``StreamRuntime.restore`` /
``DiversityService.restore`` rebuild a bit-identical stream),
``fault_policy=FaultPolicy(...)`` supervises the ingest worker
(retry/backoff, poison-queue quarantine, crash restarts),
``query_batch(deadline_s=...)`` degrades or sheds instead of queuing
unboundedly, and ``faults=FaultPlan(...)`` arms the deterministic
chaos-testing harness.

Replication (README "Replication & failover"): ``ReplicaSet`` ships the
primary's WAL records to hot standbys that replay them through their own
supervised ingest (bit-identical by the §3 pure-fold argument), verifies
parity by O(1) fingerprint exchange (divergent standbys fence + re-seed
from the primary's checkpoint), serves stale-but-consistent reads from
standbys under saturation, and promotes the most-caught-up standby on
primary death with acked-batch durability. ``HealthMonitor`` drives the
heartbeat/lag/parity probes; ``IntegrityAuditor`` spot-checks published
coreset invariants off the hot path and quarantines failing replicas.
"""
from .cache import CacheKey, CacheStats, CoresetEntry, DistanceCache
from .checkpoint import (
    DurabilityConfig,
    checkpoint_watermark,
    latest_checkpoint,
    list_checkpoints,
    load_checkpoint,
    save_checkpoint,
)
from .faults import (
    FaultPlan,
    FaultPolicy,
    FaultRule,
    InjectedCrash,
    InjectedFault,
)
from .audit import AuditConfig, AuditReport, IntegrityAuditor
from .coalesce import CoalesceConfig, Coalescer
from .frontend import QueryFrontend
from .health import HealthConfig, HealthMonitor
from .query import DiversityQuery, QueryResult
from .replication import (
    Replica,
    ReplicaSet,
    ReplicationConfig,
    ReplicationGap,
    Standby,
)
from .runtime import (
    EpochSnapshot,
    IngestReport,
    PoisonedBatch,
    StreamRuntime,
)
from .service import DiversityService
from .tenants import DEFAULT_TENANT, Tenant, TenantRegistry
from .wal import WalError, WalRecord, WriteAheadLog

__all__ = [
    "CacheKey", "CacheStats", "CoresetEntry", "DistanceCache",
    "DiversityQuery", "QueryResult", "DiversityService", "IngestReport",
    "EpochSnapshot", "StreamRuntime", "QueryFrontend",
    "CoalesceConfig", "Coalescer",
    "Tenant", "TenantRegistry", "DEFAULT_TENANT",
    "DurabilityConfig", "checkpoint_watermark", "latest_checkpoint",
    "list_checkpoints", "load_checkpoint", "save_checkpoint",
    "FaultPlan", "FaultPolicy", "FaultRule",
    "InjectedCrash", "InjectedFault", "PoisonedBatch",
    "WalError", "WalRecord", "WriteAheadLog",
    "Replica", "ReplicaSet", "ReplicationConfig", "ReplicationGap",
    "Standby", "HealthConfig", "HealthMonitor",
    "AuditConfig", "AuditReport", "IntegrityAuditor",
]
