"""Online diversity query service (the paper's web-search/recommendation
workload, §1): keep a small (1-eps)-coreset as *the* serving state, ingest
the stream incrementally, answer many heterogeneous queries against a cached
coreset distance matrix — never touching the full dataset.

    svc = DiversityService(spec, k=10, tau=64, caps=caps, metric="cosine")
    svc.ingest(batch, cats=batch_cats)          # any number of times
    res = svc.query(DiversityQuery(k=10))       # engine="auto": host parity
    out = svc.query_batch([q1, q2, ...])        # partitioned across engines

Queries dispatch through the ``core.solvers`` engine registry —
``engine="auto"`` (the default everywhere) batches sum queries under
uniform/partition/transversal matroids onto the vmapped jit solver and
keeps everything else on the host reference solvers, so every answer
matches ``solve_dmmc`` on the same coreset. See README "Solver engines".
"""
from .cache import CacheKey, CacheStats, CoresetEntry, DistanceCache
from .query import DiversityQuery, QueryResult
from .service import DiversityService, IngestReport

__all__ = [
    "CacheKey", "CacheStats", "CoresetEntry", "DistanceCache",
    "DiversityQuery", "QueryResult", "DiversityService", "IngestReport",
]
