"""Checkpoint/restore for ``StreamRuntime``: serialized scan states +
enough metadata to resume the stream bit-identically.

A checkpoint is one ``.npz`` file holding

* the serialized ``StreamState``(s) under every placement drive — a
  single state, a stacked (vmap/shard_map) state, or the pipeline
  placement's per-shard list (``core.streaming.state_to_arrays``);
* a JSON metadata blob: stream position (``n_offered``, pipeline
  round-robin cursor), WAL watermark (``wal_seq`` — every WAL record at
  or below it is folded into the state), poisoned seqs (skipped on
  replay so a restored stream matches the live post-quarantine stream),
  epoch counter, the coreset fingerprint at save time, and the runtime's
  construction config (so ``restore`` can rebuild the runtime without
  the caller re-specifying it — host oracles and callbacks are the only
  non-serializable pieces and are re-passed at restore time).

Files are written to a temp name and ``os.replace``d — a crash (or an
injected ``checkpoint.write`` fault) mid-save never corrupts an existing
checkpoint; ``latest_checkpoint`` skips unreadable files. Names carry
the stream position and epoch fingerprint
(``ckpt-<n_offered>-<fingerprint>.npz``) so the newest valid checkpoint
is the one with the largest position.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import tempfile
from typing import Optional, Union

import numpy as np

from ...core.streaming import StreamState, state_from_arrays, state_to_arrays

_log = logging.getLogger("repro.serve.diversity.checkpoint")

CKPT_PREFIX = "ckpt-"
WAL_NAME = "wal.log"


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Where and how often a runtime persists itself.

    dir               directory holding the WAL (``wal.log``) and the
                      checkpoint files;
    checkpoint_every  applied batches between automatic checkpoints
                      (taken by the ingest worker after publishing);
    fsync             fsync WAL appends and checkpoint files (durable
                      against power loss, not just process death);
    keep              retained checkpoints; older ones are pruned after
                      each successful save, and the WAL is compacted to
                      the *oldest retained* checkpoint's watermark so
                      any retained checkpoint can still replay forward.
    """

    dir: str
    checkpoint_every: int = 32
    fsync: bool = False
    keep: int = 3

    def __post_init__(self):
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if self.keep < 1:
            raise ValueError("keep must be >= 1")

    @property
    def wal_path(self) -> str:
        return os.path.join(self.dir, WAL_NAME)


def _fp_token(fingerprint: Optional[int]) -> str:
    return format((fingerprint or 0) & 0xFFFFFFFFFFFFFFFF, "016x")


def checkpoint_path(dir: str, n_offered: int,
                    fingerprint: Optional[int]) -> str:
    return os.path.join(
        dir, f"{CKPT_PREFIX}{n_offered:014d}-{_fp_token(fingerprint)}.npz"
    )


def save_checkpoint(
    path: str,
    state: Union[StreamState, list],
    meta: dict,
    *,
    faults=None,
    fsync: bool = False,
) -> str:
    """Write one atomic checkpoint file; returns ``path``.

    Raises on failure (injected ``checkpoint.write`` faults included) —
    the caller counts/logs and keeps serving; any previous checkpoint is
    untouched because the write lands on a temp name first.
    """
    if faults is not None:
        faults.check("checkpoint.write")
    arrays: dict = {}
    if isinstance(state, list):
        meta = dict(meta, kind="list", num_states=len(state))
        for i, st in enumerate(state):
            for f, a in state_to_arrays(st).items():
                arrays[f"s{i}.{f}"] = a
    else:
        meta = dict(
            meta,
            kind=meta.get("kind", "single"),
            num_states=1,
        )
        for f, a in state_to_arrays(state).items():
            arrays[f"s0.{f}"] = a
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), np.uint8
    )
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            if fsync:
                os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str) -> tuple[Union[StreamState, list], dict]:
    """Load one checkpoint file -> (state(s), meta). The state comes
    back as a ``StreamState`` (single/stacked) or a list of them
    (pipeline); the caller re-pins list entries to devices."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode("utf-8"))
        n_states = int(meta.get("num_states", 1))
        states = []
        for i in range(n_states):
            pre = f"s{i}."
            states.append(state_from_arrays(
                {f: z[pre + f] for f in StreamState._fields}
            ))
    if meta.get("kind") == "list":
        return states, meta
    return states[0], meta


def read_meta(path: str) -> dict:
    with np.load(path) as z:
        return json.loads(bytes(z["__meta__"]).decode("utf-8"))


def list_checkpoints(dir: str) -> list[str]:
    """Checkpoint files in ``dir``, oldest stream position first
    (unreadable/foreign files skipped)."""
    if not os.path.isdir(dir):
        return []
    out = []
    for name in os.listdir(dir):
        if name.startswith(CKPT_PREFIX) and name.endswith(".npz"):
            out.append(os.path.join(dir, name))
    return sorted(out)  # the zero-padded position prefix sorts correctly


def latest_checkpoint(dir: str) -> Optional[str]:
    """Newest *valid* checkpoint (largest stream position whose metadata
    loads); corrupt files are skipped with a warning, so a fault during
    one save never blocks restore from an earlier good checkpoint."""
    for path in reversed(list_checkpoints(dir)):
        try:
            read_meta(path)
            return path
        except Exception:
            _log.warning("skipping unreadable checkpoint %s", path)
    return None


def checkpoint_watermark(dir: str) -> "tuple[Optional[str], int, int]":
    """``(path, wal_seq, n_offered)`` of the newest valid checkpoint —
    the resume coordinate replication re-seeds and failover reports work
    from. ``(None, -1, 0)`` when the dir has no readable checkpoint."""
    path = latest_checkpoint(dir)
    if path is None:
        return None, -1, 0
    meta = read_meta(path)
    return path, int(meta.get("wal_seq", -1)), int(meta.get("n_offered", 0))


def prune_checkpoints(dir: str, keep: int) -> int:
    """Delete all but the newest ``keep`` checkpoints; returns the
    lowest retained WAL watermark (-1 when none carry one), which is
    how far the WAL may safely be compacted."""
    ckpts = list_checkpoints(dir)
    for path in ckpts[:-keep] if keep > 0 else ckpts:
        try:
            os.unlink(path)
        except OSError:
            _log.warning("could not prune checkpoint %s", path)
    floor = -1
    for path in list_checkpoints(dir):
        try:
            seq = int(read_meta(path).get("wal_seq", -1))
        except Exception:
            continue
        floor = seq if floor < 0 else min(floor, seq)
    return floor
