"""Query/result types for the diversity service.

The batched solvers that used to live here moved to
``core.solvers.jit_sum`` (and grew transversal support) when the
final-stage solving stack became the registry-dispatched
``core.solvers`` package; ``solve_sum_batch`` is re-exported for
back-compat. A query can nudge engine selection with ``engine_hint``
(e.g. ``"jit_greedy"`` to trade the exact star/tree answer for the fast
vmapped greedy); hints that don't apply fall back to the auto policy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ...core.diversity import Variant
from ...core.solvers.jit_sum import solve_sum_batch  # noqa: F401  (back-compat)


@dataclasses.dataclass(frozen=True)
class DiversityQuery:
    """One diversity request against the current coreset.

    caps         per-query partition caps override (defaults to the service's)
    allowed_cats restrict candidates to points carrying one of these categories
    gamma        local-search improvement threshold (sum variant only)
    engine_hint  prefer this registry engine for this query (soft: ignored
                 when ineligible; engines without the host-parity guarantee,
                 like "jit_greedy", are only ever used via a hint or an
                 explicit engine= argument)
    """

    k: int
    variant: Variant = "sum"
    caps: Optional[tuple[int, ...]] = None
    allowed_cats: Optional[frozenset[int]] = None
    gamma: float = 0.0
    engine_hint: Optional[str] = None


@dataclasses.dataclass
class QueryResult:
    indices: np.ndarray  # selected global stream ids (solver order)
    local_indices: np.ndarray  # rows of the cached coreset matrix
    diversity: float
    variant: str
    engine: str  # registry engine name ("jit_sum", "host_exhaustive", ...)
    coreset_size: int
    from_cache: bool
    # which published EpochSnapshot answered (-1: pre-epoch caller) and
    # which tenant's cache entry served it — the freshness/fan-out audit
    # trail of the multi-tenant runtime
    epoch: int = -1
    tenant: Optional[str] = None
    # deadline-aware admission outcomes (query_batch(deadline_s=...)):
    # degraded — answered by a faster non-parity engine (jit_greedy)
    # because the exact engine's predicted latency missed the deadline;
    # the answer is a valid independent set, its diversity value is the
    # greedy approximation, not the exact optimum. shed — not solved at
    # all (indices empty, engine="shed"): no engine was predicted to
    # finish in time. Both always within-deadline, never queued unboundedly.
    degraded: bool = False
    shed: bool = False


def candidate_mask(
    cats: np.ndarray, allowed: Optional[frozenset[int]]
) -> np.ndarray:
    """bool[m] mask of coreset rows passing the query's category filter."""
    m, _ = cats.shape
    if allowed is None:
        return np.ones((m,), bool)
    hit = np.isin(cats, np.fromiter(allowed, np.int32, len(allowed)))
    return np.any(hit & (cats >= 0), axis=1)
