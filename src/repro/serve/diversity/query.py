"""Query/result types + the vectorized batched final-stage solver.

``solve_sum_batch`` answers a batch of heterogeneous sum-diversity queries
(per-query k, category caps, candidate filters) against ONE cached coreset
distance matrix: a vmapped greedy seeding + masked first-improvement local
search, mirroring ``core.local_search.local_search_sum`` step for step
(same greedy gains, same (v, u) scan order, same incremental swap value, X
kept in insertion order) so the fast path lands on the same local optimum as
the host solver on the same matrix.

Everything is masked to static shapes: queries are padded to the batch's
``kmax``; infeasible queries simply stop early (nsel < k) like the host
solver does.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core.diversity import Variant


@dataclasses.dataclass(frozen=True)
class DiversityQuery:
    """One diversity request against the current coreset.

    caps         per-query partition caps override (defaults to the service's)
    allowed_cats restrict candidates to points carrying one of these categories
    gamma        local-search improvement threshold (sum variant only)
    """

    k: int
    variant: Variant = "sum"
    caps: Optional[tuple[int, ...]] = None
    allowed_cats: Optional[frozenset[int]] = None
    gamma: float = 0.0


@dataclasses.dataclass
class QueryResult:
    indices: np.ndarray  # selected global stream ids (solver order)
    local_indices: np.ndarray  # rows of the cached coreset matrix
    diversity: float
    variant: str
    engine: str  # "host" | "vmap"
    coreset_size: int
    from_cache: bool


def candidate_mask(
    cats: np.ndarray, allowed: Optional[frozenset[int]]
) -> np.ndarray:
    """bool[m] mask of coreset rows passing the query's category filter."""
    m, _ = cats.shape
    if allowed is None:
        return np.ones((m,), bool)
    hit = np.isin(cats, np.fromiter(allowed, np.int32, len(allowed)))
    return np.any(hit & (cats >= 0), axis=1)


# --------------------------------------------------------------------------
# vmapped sum-variant solver (uniform/partition matroids, gamma == 1)
# --------------------------------------------------------------------------


def _greedy_seed(D, cats, caps, allow, k, kmax):
    """Mirror of local_search.greedy_init: max marginal-gain candidate per
    step (first index wins ties), partition feasibility via counts<caps."""
    m = D.shape[0]
    h = caps.shape[0]
    rowsum_all = jnp.sum(D, axis=1)  # gain of the very first pick

    def body(i, carry):
        sel, selmask, counts, nsel = carry
        can = allow & ~selmask & (counts[cats] < caps[cats])
        gains = jnp.where(
            nsel == 0, rowsum_all, D @ selmask.astype(jnp.float32)
        )
        v = jnp.argmax(jnp.where(can, gains, -jnp.inf))
        take = (i < k) & jnp.any(can)

        def add(c):
            sel, selmask, counts, nsel = c
            return (
                sel.at[nsel].set(v),
                selmask.at[v].set(True),
                counts.at[cats[v]].add(1),
                nsel + 1,
            )

        return jax.lax.cond(take, add, lambda c: c, carry)

    init = (
        jnp.full((kmax,), -1, jnp.int32),
        jnp.zeros((m,), bool),
        jnp.zeros((h,), jnp.int32),
        jnp.int32(0),
    )
    return jax.lax.fori_loop(0, kmax, body, init)


def _solve_sum_one(D, cats, caps, allow, k, gamma, *, kmax, max_sweeps):
    """Single-query greedy + first-improvement local search over cached D."""
    m = D.shape[0]
    sel, selmask, counts, nsel = _greedy_seed(D, cats, caps, allow, k, kmax)
    selm_f = selmask.astype(jnp.float32)
    div0 = 0.5 * jnp.dot(selm_f, D @ selm_f)
    slots = jnp.arange(kmax, dtype=jnp.int32)

    def v_body(v, st):
        sel, selmask, counts, rowX, div, improved = st
        u = jnp.maximum(sel, 0)  # (kmax,) slot -> local id (garbage past k)
        # div(X - u + v) = div - row[u] + dv - d(u, v)   (host's identity)
        new_div = div - rowX[u] + rowX[v] - D[u, v]
        cat_v = cats[v]
        ok_cap = counts[cat_v] - (cats[u] == cat_v) + 1 <= caps[cat_v]
        improving = (
            (slots < nsel)
            & (new_div > div * (1.0 + gamma))
            & (new_div > div)
            & ok_cap
        )
        any_imp = allow[v] & ~selmask[v] & jnp.any(improving)
        ui = jnp.argmax(improving)  # first improving u in X order

        def do_swap(st):
            sel, selmask, counts, rowX, div, improved = st
            uold = sel[ui]
            # host order: X = [w for w in X if w != u] + [v]
            src = jnp.where(slots >= ui, jnp.minimum(slots + 1, kmax - 1), slots)
            sel2 = sel[src].at[nsel - 1].set(v)
            selmask2 = selmask.at[uold].set(False).at[v].set(True)
            counts2 = counts.at[cats[uold]].add(-1).at[cat_v].add(1)
            rowX2 = D @ selmask2.astype(jnp.float32)
            return sel2, selmask2, counts2, rowX2, new_div[ui], True

        return jax.lax.cond(any_imp, do_swap, lambda s: s, st)

    def sweep_cond(carry):
        st, sweeps = carry
        return st[-1] & (sweeps < max_sweeps)

    def sweep_body(carry):
        st, sweeps = carry
        st = (*st[:-1], False)
        st = jax.lax.fori_loop(0, m, v_body, st)
        return st, sweeps + 1

    rowX0 = D @ selm_f
    ls0 = ((sel, selmask, counts, rowX0, div0, nsel == k), jnp.int32(0))
    (sel, selmask, counts, _rowX, div, _imp), _ = jax.lax.while_loop(
        sweep_cond, sweep_body, ls0
    )
    return sel, nsel, div


@functools.partial(jax.jit, static_argnames=("kmax", "max_sweeps"))
def solve_sum_batch(
    D: jnp.ndarray,  # (m, m) cached coreset distances
    cats: jnp.ndarray,  # (m,) int32 single-label categories (zeros: uniform)
    caps: jnp.ndarray,  # (B, h) per-query caps
    allow: jnp.ndarray,  # (B, m) per-query candidate masks
    ks: jnp.ndarray,  # (B,)
    gammas: jnp.ndarray,  # (B,)
    *,
    kmax: int,
    max_sweeps: int = 64,
):
    """Batch of sum-DMMC queries on one matrix. Returns (sel (B, kmax) local
    ids -1-padded, nsel (B,), div (B,))."""
    f = functools.partial(_solve_sum_one, kmax=kmax, max_sweeps=max_sweeps)
    return jax.vmap(f, in_axes=(None, None, 0, 0, 0, 0))(
        D, cats, caps, allow, ks, gammas
    )
