"""Deterministic fault-injection harness for the serving stack.

Chaos testing only earns its keep when a failure reproduces: a fault
plan here is a *seeded schedule*, not a random monkey. Every
instrumented site in the runtime calls ``plan.check(site)`` on each pass
through; the plan counts the hit and consults its rules — each rule owns
an independent ``numpy`` Generator seeded from ``(seed, site, rule
index)``, so whether hit #7 of ``"worker.ingest"`` fires is a pure
function of the plan's seed and that site's hit ordinal, regardless of
what any other site or thread is doing. The same seed therefore replays
the same fault schedule, which is what lets the chaos suite assert
exact post-fault state (bit-identical streams, exact retry counts).

Instrumented sites (see ``StreamRuntime``/``WriteAheadLog``/
``checkpoint``):

``worker.loop``        once per dequeued batch, *outside* the per-batch
                       error handling — a ``kind="crash"`` rule here
                       raises ``InjectedCrash`` (a ``BaseException``)
                       that kills the worker thread itself, exercising
                       the supervisor restart path;
``worker.ingest``      once per ingest *attempt* (so retries re-hit it)
                       — ``kind="error"`` raises the retryable
                       ``InjectedFault``, ``kind="delay"`` injects a
                       slow ingest;
``wal.append``         before each WAL record write;
``checkpoint.write``   before each checkpoint file write;
``wal.compact``        mid-compaction, *after* the replacement log is
                       fully written but *before* the atomic swap —
                       both generations exist on disk, either must
                       restore bit-identically;
``replication.ship``   once per record shipped primary -> standby — an
                       ``"error"`` drops the record on the wire (the
                       standby falls behind and must catch up from the
                       primary's WAL or re-seed);
``replica.crash``      once per record applied by a standby's apply
                       thread — ``kind="crash"`` kills the standby;
``health.heartbeat``   once per health-monitor heartbeat probe of the
                       primary — ``"error"`` makes the probe fail,
                       driving the failure-threshold -> failover path.

Clock skew: ``plan.monotonic()`` is ``time.monotonic() +
clock_skew_s``; the runtime stamps epochs and staleness with it, so a
skewed plan proves the staleness accounting only ever compares
timestamps from the same clock.

Fault *handling* policy lives in ``FaultPolicy`` (how many retries, what
backoff, quarantine vs truncate, how many worker restarts) — the plan
decides what breaks, the policy decides how the runtime survives it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Optional, Sequence

import numpy as np


class InjectedFault(RuntimeError):
    """A planned transient failure (an ``Exception``: the per-batch
    retry/quarantine machinery handles it like any real ingest error)."""


class InjectedCrash(BaseException):
    """A planned worker-thread death. Deliberately NOT an ``Exception``:
    it escapes the per-batch handler and kills the worker loop itself,
    the way a real thread-fatal condition would — only the supervisor
    catches it."""


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One line of a fault schedule.

    Of the hits at ``site``: skip the first ``after``, then consider
    every ``every``-th; fire at most ``times`` of those (``None`` =
    unbounded), each with probability ``p`` (drawn from the rule's own
    seeded generator, so the decision sequence is reproducible).
    """

    site: str
    kind: str = "error"  # "error" | "crash" | "delay"
    after: int = 0
    every: int = 1
    times: Optional[int] = 1
    p: float = 1.0
    delay_s: float = 0.0
    message: str = ""

    def __post_init__(self):
        if self.kind not in ("error", "crash", "delay"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.every < 1:
            raise ValueError("every must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """How the ingest worker survives failures (the defaults reproduce
    the historical semantics: no retries, fail-fast truncation).

    max_retries          ingest attempts after the first failure of a
                         batch before it is declared failed;
    backoff_s            first retry delay; doubles per attempt, capped
                         at ``backoff_cap_s`` (capped exponential);
    on_failure           ``"truncate"``: record the error, drop this and
                         every later batch, surface on the next
                         submit/flush (the historical contract) —
                         ``"quarantine"``: move the batch to the poison
                         queue (counted + logged, re-submittable from
                         ``StreamRuntime.poison``) and keep ingesting
                         later batches;
    max_worker_restarts  times the supervisor will respawn a crashed
                         worker thread before giving up and recording
                         the crash as a worker error.
    """

    max_retries: int = 0
    backoff_s: float = 0.05
    backoff_cap_s: float = 2.0
    on_failure: str = "truncate"
    max_worker_restarts: int = 5

    def __post_init__(self):
        if self.on_failure not in ("truncate", "quarantine"):
            raise ValueError(
                f"on_failure must be 'truncate' or 'quarantine', got "
                f"{self.on_failure!r}"
            )

    def backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (0-based): capped exponential."""
        return min(self.backoff_cap_s, self.backoff_s * (2.0 ** attempt))


class _RuleState:
    __slots__ = ("rule", "rng", "fired", "considered")

    def __init__(self, rule: FaultRule, seed: int, index: int):
        self.rule = rule
        # independent per-rule stream: the draw sequence depends only on
        # (plan seed, site, rule index) and this rule's own hit ordinals.
        # crc32, not hash(): str hashing is salted per process, and the
        # whole point is that one seed replays one schedule across runs.
        self.rng = np.random.default_rng(
            np.random.SeedSequence(
                entropy=seed,
                spawn_key=(zlib.crc32(rule.site.encode()), index),
            )
        )
        self.fired = 0
        self.considered = 0


class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Thread-safe: rule bookkeeping runs under one lock; the decision for
    a given (site, hit ordinal) never depends on other sites' traffic.
    """

    def __init__(
        self,
        seed: int = 0,
        rules: Sequence[FaultRule] = (),
        *,
        clock_skew_s: float = 0.0,
    ):
        self.seed = int(seed)
        self.clock_skew_s = float(clock_skew_s)
        self._mu = threading.Lock()
        self._hits: dict[str, int] = {}
        self._rules: dict[str, list[_RuleState]] = {}
        self._fires: list[dict] = []
        for i, r in enumerate(rules):
            self._rules.setdefault(r.site, []).append(
                _RuleState(r, self.seed, i)
            )

    # -- the injection point ------------------------------------------

    def check(self, site: str) -> None:
        """Count one hit at ``site``; raise/sleep if a rule fires."""
        with self._mu:
            h = self._hits.get(site, 0) + 1
            self._hits[site] = h
            fire: Optional[FaultRule] = None
            for st in self._rules.get(site, ()):
                r = st.rule
                if h <= r.after:
                    continue
                st.considered += 1
                if (st.considered - 1) % r.every != 0:
                    continue
                if r.times is not None and st.fired >= r.times:
                    continue
                if r.p < 1.0 and float(st.rng.random()) >= r.p:
                    continue
                st.fired += 1
                fire = r
                self._fires.append(
                    dict(site=site, kind=r.kind, hit=h,
                         t=time.monotonic())
                )
                break
        if fire is None:
            return
        msg = fire.message or (
            f"injected {fire.kind} at {site!r} (hit {h}, seed {self.seed})"
        )
        if fire.kind == "delay":
            time.sleep(fire.delay_s)
            return
        if fire.kind == "crash":
            raise InjectedCrash(msg)
        raise InjectedFault(msg)

    # -- skewed clock --------------------------------------------------

    def monotonic(self) -> float:
        return time.monotonic() + self.clock_skew_s

    # -- introspection (what the chaos tests assert on) ----------------

    def hits(self, site: str) -> int:
        with self._mu:
            return self._hits.get(site, 0)

    def fired(self, site: Optional[str] = None) -> int:
        with self._mu:
            return sum(
                1 for f in self._fires
                if site is None or f["site"] == site
            )

    def fires(self) -> list[dict]:
        with self._mu:
            return list(self._fires)

    def snapshot(self) -> dict:
        with self._mu:
            return {
                "seed": self.seed,
                "clock_skew_s": self.clock_skew_s,
                "hits": dict(self._hits),
                "fires": list(self._fires),
            }
