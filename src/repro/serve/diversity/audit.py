"""Online integrity auditor: verify published coreset invariants off the
hot path.

The streaming scan (Alg. 2) maintains invariants that are cheap to spot-
check on a host copy of the state but would be catastrophic to violate
silently in serving:

  center budget   at most ``tau + 1`` valid centers per shard (the
                  restructure trigger);
  coverage        radius variant: every delegate sits within ``2R`` of
                  its center — the HANDLE threshold opens a new center at
                  ``2R``, and each restructure halves-then-extends the
                  bound (``a/2 + 1``) back under 2, so ``dist(delegate,
                  center) <= 2R`` holds at every step (skipped for the
                  diameter variant, whose per-center slack is
                  ``eps``-scaled, and while ``R == 0``);
  independence    uniform/partition: each center's delegate set is
                  independent in the matroid (HANDLE enforces the count
                  and per-category caps); transversal: the slot cap
                  bounds the delegate count (independence is certified
                  downstream by the matching solver);
  snapshot        published epochs carry finite points and in-range,
                  duplicate-free source indices;
  pdist cache     sampled entries of each tenant's cached distance
                  matrix match a host recomputation;
  fingerprint     the state copy the audit read re-hashes to the
                  fingerprint the runtime reported at copy time (a torn
                  copy or corrupted buffer fails this).

``IntegrityAuditor`` samples these on demand (``audit_once``) or on a
background cadence (``start``). Against a ``ReplicaSet`` it audits the
primary and every standby and *quarantines* a standby that fails —
excluded from stale reads and from promotion — because a replica serving
corrupt answers is strictly worse than one fewer replica.

Metrics: ``serve.audit.runs`` / ``serve.audit.violations{check=}`` /
``serve.audit.quarantined`` / ``serve.audit.last_ok`` gauge.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ...core.matroid import make_host_matroid
from ...core.streaming import epoch_fingerprint

_log = logging.getLogger("repro.serve.diversity.audit")


@dataclasses.dataclass(frozen=True)
class AuditConfig:
    """``pdist_samples`` sampled matrix entries per cached tenant entry;
    ``rel_tol`` f32 relative tolerance for distance/coverage checks;
    ``interval_s`` background cadence; ``quarantine`` whether a failing
    ``ReplicaSet`` standby is quarantined; ``seed`` for the sampling
    rng (deterministic audits)."""

    pdist_samples: int = 32
    rel_tol: float = 1e-3
    interval_s: float = 0.25
    quarantine: bool = True
    seed: int = 0


@dataclasses.dataclass
class AuditReport:
    replica: str
    fingerprint: Optional[int]
    n_offered: int
    checks: int  # individual assertions evaluated
    violations: "list[str]" = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations


def _iter_shard_states(host_state):
    """Yield per-shard host ``StreamState``s from any placement's state:
    a single state, a stacked (leading shard dim) state, or a list."""
    if host_state is None:
        return
    if isinstance(host_state, list):
        for st in host_state:
            yield st
        return
    R = np.asarray(host_state.R)
    if R.ndim == 0:
        yield host_state
        return
    S = R.shape[0]
    for s in range(S):
        yield type(host_state)(*(np.asarray(f)[s] for f in host_state))


def audit_state(
    st,
    *,
    spec,
    k: int,
    tau: int,
    caps=None,
    variant: str = "radius",
    oracle=None,
    rel_tol: float = 1e-3,
) -> "tuple[int, list[str]]":
    """Invariant checks on ONE host shard state. Returns
    ``(checks_evaluated, violations)``."""
    checks = 0
    v: "list[str]" = []
    cvalid = np.asarray(st.cvalid, bool)
    centers = np.asarray(st.centers, np.float32)
    dp = np.asarray(st.dp, np.float32)
    dv = np.asarray(st.dv, bool)
    dc = np.asarray(st.dc, np.int32)
    R = float(np.asarray(st.R))
    slot_cap = dp.shape[1]
    live = np.nonzero(cvalid)[0]
    checks += 1
    if live.size > tau + 1:
        v.append(
            f"center budget: {live.size} valid centers > tau+1 = {tau + 1}"
        )
    lim = 2.0 * R * (1.0 + rel_tol) + 1e-5
    for z in live:
        rows = np.nonzero(dv[z])[0]
        checks += 1
        if rows.size > slot_cap:
            v.append(
                f"slots: center {z} has {rows.size} delegates > slot "
                f"cap {slot_cap}"
            )
        if rows.size == 0:
            continue
        if variant == "radius" and R > 0.0:
            checks += 1
            dists = np.linalg.norm(dp[z][rows] - centers[z], axis=1)
            worst = float(dists.max())
            if worst > lim:
                v.append(
                    f"coverage: center {z} delegate at dist "
                    f"{worst:.6g} > 2R = {2.0 * R:.6g}"
                )
        if spec.kind in ("uniform", "partition"):
            checks += 1
            m = make_host_matroid(
                spec, dc[z][rows], caps, int(rows.size), k, oracle
            )
            if not m.is_independent(list(range(int(rows.size)))):
                v.append(
                    f"independence: center {z} delegate set of size "
                    f"{rows.size} is dependent under {spec.kind}"
                )
    return checks, v


def audit_snapshot(snap, n_offered: int) -> "tuple[int, list[str]]":
    """Published-epoch checks: finite points, in-range unique src_idx."""
    checks = 0
    v: "list[str]" = []
    if snap is None:
        return checks, v
    pts = np.asarray(snap.points)
    src = np.asarray(snap.src_idx)
    checks += 1
    if pts.size and not bool(np.isfinite(pts).all()):
        v.append(f"snapshot: epoch {snap.epoch} non-finite coreset points")
    checks += 1
    if src.size and (src.min() < 0 or src.max() >= max(1, n_offered)):
        v.append(
            f"snapshot: epoch {snap.epoch} src_idx outside [0, "
            f"{n_offered})"
        )
    checks += 1
    if src.size != np.unique(src).size:
        v.append(f"snapshot: epoch {snap.epoch} duplicate src_idx")
    return checks, v


class IntegrityAuditor:
    """Audit a ``ReplicaSet``, a ``(runtime, frontend)`` service stack,
    or a bare ``StreamRuntime``. See the module docstring for the
    invariants."""

    def __init__(
        self,
        target,
        *,
        config: Optional[AuditConfig] = None,
        registry: Optional[obs.MetricsRegistry] = None,
    ):
        self.target = target
        self.config = config if config is not None else AuditConfig()
        reg = registry
        if reg is None:
            reg = getattr(target, "registry", None)
        self.registry = reg if reg is not None else obs.default_registry()
        self._rng = np.random.default_rng(self.config.seed)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.total_checks = 0
        self.total_violations = 0
        self.reports: "list[AuditReport]" = []
        self._m_runs = self.registry.counter("serve.audit.runs")
        self._g_ok = self.registry.gauge("serve.audit.last_ok")

    # -- one audit pass ------------------------------------------------

    def audit_once(self) -> "list[AuditReport]":
        """Audit every replica of the target once. Updates metrics,
        quarantines failing ``ReplicaSet`` standbys, returns the
        reports."""
        reports = []
        for name, rt, fe, standby in self._replicas():
            rep = self._audit_replica(name, rt, fe)
            reports.append(rep)
            if not rep.ok:
                for viol in rep.violations:
                    check = viol.split(":", 1)[0].strip()
                    self.registry.counter(
                        "serve.audit.violations", check=check,
                        replica=name,
                    ).inc()
                if (
                    standby is not None
                    and self.config.quarantine
                    and not standby.quarantined
                ):
                    standby.quarantined = True
                    self.registry.counter(
                        "serve.audit.quarantined", replica=name
                    ).inc()
                    _log.warning(
                        "replica %s quarantined by audit: %s",
                        name, "; ".join(rep.violations),
                    )
        self._m_runs.inc()
        ok = all(r.ok for r in reports)
        self._g_ok.set(1.0 if ok else 0.0)
        self.total_checks += sum(r.checks for r in reports)
        self.total_violations += sum(len(r.violations) for r in reports)
        self.reports = reports
        return reports

    def _replicas(self):
        """Yield ``(name, runtime, frontend | None, standby | None)``."""
        t = self.target
        if hasattr(t, "primary") and hasattr(t, "standbys"):
            p = t.primary
            yield p.name, p.runtime, p.frontend, None
            for sb in t.standbys:
                if sb.dead:
                    continue
                yield sb.name, sb.runtime, sb.frontend, sb
        elif hasattr(t, "runtime") and hasattr(t, "frontend"):
            yield "service", t.runtime, t.frontend, None
        elif hasattr(t, "runtime"):
            yield "frontend", t.runtime, t, None
        else:
            yield "runtime", t, None, None

    def _audit_replica(self, name, rt, fe) -> AuditReport:
        cfg = self.config
        with obs.span("audit", cat="audit", replica=name):
            # one consistent cut of the live state: copy + fingerprint
            # under the runtime lock, verify outside it
            with rt._cv:
                fp = rt._fingerprint
                n_offered = rt.n_offered
                state = rt._state
                if state is None:
                    host = None
                elif isinstance(state, list):
                    host = [
                        jax.tree_util.tree_map(np.asarray, st)
                        for st in state
                    ]
                else:
                    host = jax.tree_util.tree_map(np.asarray, state)
            rep = AuditReport(
                replica=name, fingerprint=fp, n_offered=n_offered,
                checks=0,
            )
            for st in _iter_shard_states(host):
                c, v = audit_state(
                    st,
                    spec=rt.spec, k=rt.k, tau=rt.tau, caps=rt.caps,
                    variant=rt.stream_variant, oracle=rt.oracle,
                    rel_tol=cfg.rel_tol,
                )
                rep.checks += c
                rep.violations.extend(v)
            if host is not None and fp is not None:
                rep.checks += 1
                fp2 = self._refingerprint(host)
                if fp2 != fp:
                    rep.violations.append(
                        f"fingerprint: state copy re-hashes to {fp2:#x}, "
                        f"runtime reported {fp:#x}"
                    )
            c, v = audit_snapshot(rt.latest(), n_offered)
            rep.checks += c
            rep.violations.extend(v)
            if fe is not None:
                c, v = self._audit_cache(fe)
                rep.checks += c
                rep.violations.extend(v)
            return rep

    @staticmethod
    def _refingerprint(host) -> int:
        """Mirror ``StreamRuntime._fingerprint_and_size`` on a host
        copy."""
        if isinstance(host, list):
            fps = [
                epoch_fingerprint(jax.tree_util.tree_map(jnp.asarray, st))
                for st in host
            ]
            return hash(tuple(fp for fp, _sz in fps))
        fp, _sz = epoch_fingerprint(
            jax.tree_util.tree_map(jnp.asarray, host)
        )
        return fp

    def _audit_cache(self, fe) -> "tuple[int, list[str]]":
        """Spot-check cached pdist matrices against host recomputation."""
        cfg = self.config
        checks = 0
        v: "list[str]" = []
        cache = fe.cache
        with cache._mu:
            entries = list(cache._entries.items())
        for key, e in entries:
            m = int(e.points.shape[0])
            if m < 2:
                continue
            s = min(cfg.pdist_samples, m * m)
            ii = self._rng.integers(0, m, size=s)
            jj = self._rng.integers(0, m, size=s)
            # solvers never consult self-distances, and the builder's
            # norm-expansion (|a|^2+|b|^2-2ab) leaves f32 noise on the
            # diagonal — sample strictly off-diagonal entries
            off = ii != jj
            ii, jj = ii[off], jj[off]
            if ii.size == 0:
                continue
            pts = np.asarray(e.points, np.float32)
            want = np.linalg.norm(pts[ii] - pts[jj], axis=1)
            got = np.asarray(e.D)[ii, jj]
            checks += 1
            tol = cfg.rel_tol * np.maximum(1.0, np.abs(want)) + 1e-4
            bad = np.abs(got - want) > tol
            if bool(bad.any()):
                b = int(np.nonzero(bad)[0][0])
                v.append(
                    f"pdist: entry {key.spec.kind}/tau={key.tau} "
                    f"D[{ii[b]},{jj[b]}] = {got[b]:.6g}, recomputed "
                    f"{want[b]:.6g}"
                )
        return checks, v

    # -- background cadence --------------------------------------------

    def start(self) -> "IntegrityAuditor":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="integrity-audit", daemon=True
        )
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.config.interval_s):
            try:
                self.audit_once()
            except Exception as e:  # noqa: BLE001 — the auditor must
                # outlive any single pass's failure
                _log.warning("audit error: %s: %s", type(e).__name__, e)

    def close(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=5.0)
        self._thread = None
