"""Serving layer: batched LM engine + online diversity query service."""
