"""Span-based request tracing for the serving stack.

A *span* is one timed region of host work (``with span("solve", ...)``); a
*trace* is the set of spans sharing one trace ID — one request's journey
through the stack. ``QueryFrontend.query_batch`` opens a trace per request
batch; ``StreamRuntime.submit`` opens one per submitted batch and the
ingest worker re-enters it when it actually ingests/publishes, so a
single trace covers submit -> ingest -> publish even across threads.

Propagation is a ``contextvars.ContextVar``: spans opened anywhere below
``trace()`` on the same thread (or under an explicitly resumed ID, see
``resume_trace``) carry the same 16-hex-digit trace ID in their args.

Storage is a fixed-size ring buffer: records are written at
``next(itertools.count()) % capacity`` — the counter is a C-level atomic
under the GIL, so concurrent writers never lock and never block; under
overload the buffer keeps the newest ``capacity`` spans and drops the
oldest, which is the correct failure mode for always-on tracing.

Export is Chrome ``trace_event`` JSON (``dump(path)`` /
``obs.dump_trace(path)``): open the file at ``chrome://tracing`` or
https://ui.perfetto.dev. Spans are complete events (``"ph": "X"``) with
microsecond timestamps on a shared wall-clock anchor, one row per thread.

Like metrics, spans are host-side only and guarded against leaking into a
jit trace (``TracerLeakError``), and a disabled buffer costs two attribute
loads per span.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import json
import os
import threading
import time
import uuid
from typing import Optional

from .metrics import assert_host_side

# wall-clock anchor: perf_counter deltas (monotonic, high-res) mapped onto
# the epoch so trace timestamps from every thread share one axis
_ANCHOR_WALL = time.time()
_ANCHOR_PERF = time.perf_counter()


def now_us() -> float:
    return (_ANCHOR_WALL + (time.perf_counter() - _ANCHOR_PERF)) * 1e6


_trace_id: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "repro_obs_trace_id", default=None
)


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


def current_trace_id() -> Optional[str]:
    return _trace_id.get()


@contextlib.contextmanager
def trace(trace_id: Optional[str] = None):
    """Establish a trace ID for every span opened underneath. Re-entrant:
    if a trace is already active and no explicit ID is given, it is
    reused (nested ``query_batch`` style calls join the caller's trace).
    Yields the active ID."""
    cur = _trace_id.get()
    if trace_id is None and cur is not None:
        yield cur
        return
    tid = trace_id if trace_id is not None else new_trace_id()
    token = _trace_id.set(tid)
    try:
        yield tid
    finally:
        _trace_id.reset(token)


@contextlib.contextmanager
def resume_trace(trace_id: Optional[str]):
    """Re-enter an existing trace on another thread (the ingest worker
    resumes the submitting caller's trace). ``None`` is a no-op."""
    if trace_id is None:
        yield None
        return
    token = _trace_id.set(trace_id)
    try:
        yield trace_id
    finally:
        _trace_id.reset(token)


@dataclasses.dataclass
class SpanRecord:
    name: str
    cat: str
    trace_id: Optional[str]
    ts_us: float
    dur_us: float
    tid: int
    args: dict

    def to_chrome(self) -> dict:
        args = {"trace_id": self.trace_id, **self.args}
        return {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": self.ts_us,
            "dur": self.dur_us,
            "pid": os.getpid(),
            "tid": self.tid,
            "args": args,
        }


class TraceBuffer:
    """Lock-free ring buffer of ``SpanRecord``s + Chrome export."""

    def __init__(self, capacity: int = 1 << 16, *, enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self._buf: list[Optional[SpanRecord]] = [None] * capacity
        self._next = itertools.count()  # GIL-atomic increment, no lock

    def record(self, rec: SpanRecord) -> None:
        if not self.enabled:
            return
        self._buf[next(self._next) % self.capacity] = rec

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serve", **args):
        """Time one host-side region; records on exit (exceptions
        included — a span that died still shows its duration)."""
        if not self.enabled:
            yield None
            return
        assert_host_side(f"span({name!r})")
        ts = now_us()
        t0 = time.perf_counter()
        try:
            yield None
        finally:
            self.record(SpanRecord(
                name=name,
                cat=cat,
                trace_id=_trace_id.get(),
                ts_us=ts,
                dur_us=(time.perf_counter() - t0) * 1e6,
                tid=threading.get_ident(),
                args=args,
            ))

    def drain(self) -> list[SpanRecord]:
        """Recorded spans, oldest first (non-destructive). Every record is
        wall-clock stamped, so ring order is recovered by timestamp."""
        out = [r for r in self._buf if r is not None]
        out.sort(key=lambda r: r.ts_us)
        return out

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._next = itertools.count()

    def chrome_trace(self) -> dict:
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [r.to_chrome() for r in self.drain()],
        }

    def dump(self, path: str) -> str:
        """Write Chrome ``trace_event`` JSON; open at chrome://tracing."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


_default: Optional[TraceBuffer] = None
_default_mu = threading.Lock()


def default_buffer() -> TraceBuffer:
    global _default
    if _default is None:
        with _default_mu:
            if _default is None:
                _default = TraceBuffer()
    return _default


def span(name: str, cat: str = "serve", **args):
    """Span on the process-default buffer (the call sites' spelling)."""
    return default_buffer().span(name, cat, **args)


def dump_trace(path: str) -> str:
    return default_buffer().dump(path)
