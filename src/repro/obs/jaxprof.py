"""JAX-aware profiling hooks: named scopes, a recompile counter keyed by
bucketed shape, and opt-in ``jax.profiler`` trace capture.

Recompile semantics: every XLA backend compile in the process fires
``/jax/core/compile/backend_compile_duration`` through ``jax.monitoring``.
A ``RecompileWatch`` subscribes once (one process-global listener fanning
out to every live watch) and attributes each compile to the *compile
region* active on the compiling thread — a ``contextvars`` label the
serving call sites set around their jit entry points, carrying the
bucketed shape key (``ingest[pipeline b=512]``, ``solve[jit_sum B=32
kmax=8]``). Compiles with no active region land under ``"unattributed"``
(jnp helpers, library warmup, other subsystems).

That attribution is what makes "did this change introduce steady-state
recompiles?" a measurable, gateable quantity: the serve bench resets a
watch after its warmup rounds and asserts the measured rounds compiled
*nothing* (``steady_state_recompiles == 0`` — enforced by
``benchmarks.run --check``). Because the shape key IS the bucket, a
recompile that should have been absorbed by pow-2 bucketing shows up
under the exact bucket label that failed to hold.

``named_scope`` is re-exported here as the one sanctioned *in-trace*
annotation: it tags HLO ops with their source region so profiler traces
and compiled-module dumps read as ``dmmc/blocked_scan``,
``dmmc/precheck``, ``solver/jit_sum`` instead of fusion soup. It is
metadata only — safe under jit/vmap/scan, zero runtime cost.

``profiler_trace`` wraps ``jax.profiler.start_trace/stop_trace`` as an
opt-in context manager (explicit ``enabled=True`` or the
``REPRO_OBS_PROFILE=dir`` environment knob) that never lets profiler
failures take down serving.
"""
from __future__ import annotations

import contextlib
import contextvars
import os
import threading
from typing import Optional

import jax

try:
    from jax import named_scope  # re-export: the in-trace annotation
except ImportError:  # pragma: no cover - ancient jax
    @contextlib.contextmanager
    def named_scope(name):  # type: ignore[misc]
        yield

BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
UNATTRIBUTED = "unattributed"

_compile_key: contextvars.ContextVar[Optional[str]] = contextvars.ContextVar(
    "repro_obs_compile_key", default=None
)


@contextlib.contextmanager
def compile_region(key: str):
    """Attribute any backend compile triggered inside to ``key`` (use the
    bucketed shape as the key so a counter > 0 names the bucket that
    failed to hold). Nested regions: innermost wins."""
    token = _compile_key.set(key)
    try:
        yield
    finally:
        _compile_key.reset(token)


def current_compile_region() -> Optional[str]:
    return _compile_key.get()


_watches: list["RecompileWatch"] = []
_listener_installed = False
_install_mu = threading.Lock()


def _listener(event: str, duration, **kwargs) -> None:
    # jax.monitoring listeners run inside the compile path: never raise.
    if event != BACKEND_COMPILE_EVENT:
        return
    key = _compile_key.get() or UNATTRIBUTED
    for w in tuple(_watches):
        try:
            w._on_compile(key, float(duration))
        except Exception:  # pragma: no cover - defensive
            pass


def _install_listener() -> None:
    global _listener_installed
    with _install_mu:
        if _listener_installed:
            return
        jax.monitoring.register_event_duration_secs_listener(_listener)
        _listener_installed = True


class RecompileWatch:
    """Counts backend compiles per compile-region key.

    ``reset()`` opens a measurement window; ``total()`` / ``by_key()``
    read it. Independent watches over the same process stream count
    independently (the bench keeps one never-reset watch for the full-run
    compile census and one windowed watch for the steady-state gate)."""

    def __init__(self):
        self._mu = threading.Lock()
        self._counts: dict[str, int] = {}
        self._secs: dict[str, float] = {}
        _install_listener()
        _watches.append(self)

    def _on_compile(self, key: str, duration: float) -> None:
        with self._mu:
            self._counts[key] = self._counts.get(key, 0) + 1
            self._secs[key] = self._secs.get(key, 0.0) + duration

    def total(self, *, include_unattributed: bool = True) -> int:
        with self._mu:
            return sum(
                c for k, c in self._counts.items()
                if include_unattributed or k != UNATTRIBUTED
            )

    def by_key(self) -> dict[str, int]:
        with self._mu:
            return dict(self._counts)

    def seconds_by_key(self) -> dict[str, float]:
        with self._mu:
            return dict(self._secs)

    def reset(self) -> None:
        with self._mu:
            self._counts.clear()
            self._secs.clear()

    def close(self) -> None:
        """Stop receiving events (the global listener stays installed —
        jax.monitoring has no per-listener removal — but this watch
        drops out of the fan-out)."""
        try:
            _watches.remove(self)
        except ValueError:
            pass


_default_watch: Optional[RecompileWatch] = None
_default_watch_mu = threading.Lock()


def recompile_watch() -> RecompileWatch:
    """The process-default watch (created + subscribed on first use)."""
    global _default_watch
    if _default_watch is None:
        with _default_watch_mu:
            if _default_watch is None:
                _default_watch = RecompileWatch()
    return _default_watch


@contextlib.contextmanager
def profiler_trace(logdir: Optional[str] = None, *,
                   enabled: Optional[bool] = None):
    """Opt-in ``jax.profiler`` capture around a region (ingest/solve
    sections in the bench). Default resolves from ``REPRO_OBS_PROFILE``:
    unset -> disabled; set -> enabled, its value the log directory unless
    ``logdir`` overrides. Yields True iff a capture is running; profiler
    errors (double-start, unsupported backend) disable the capture
    rather than failing the caller."""
    env = os.environ.get("REPRO_OBS_PROFILE", "")
    on = bool(env) if enabled is None else enabled
    where = logdir or env or "/tmp/repro-jax-trace"
    if not on:
        yield False
        return
    started = False
    try:
        jax.profiler.start_trace(where)
        started = True
    except Exception:
        pass
    try:
        yield started
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:  # pragma: no cover - defensive
                pass
