"""Thread-safe metrics registry for the serving stack.

Three instrument kinds, all host-side, all O(1) memory per series:

* ``Counter``    monotonically increasing int (``inc``);
* ``Gauge``      last-write-wins float (``set``/``inc``/``dec``);
* ``Histogram``  bounded log2-bucket distribution — 96 fixed buckets
                 spanning ``[1e-9, 1e-9 * 2**96)`` (sub-nanosecond to
                 ~10**19), so any latency/size this stack can produce
                 lands in a bucket without ever allocating. Quantiles
                 (p50/p95/p99) are read off the bucket boundaries with
                 at most one-bucket (2x) resolution error — the right
                 trade for a registry that must never grow under load.

Series are keyed by ``(name, sorted labels)``: the same call site can fan
out per tenant/engine/placement without pre-declaring anything
(``registry.counter("serve.query.requests", tenant="cosine")``). Snapshot
and JSONL/stdout exporters render a series as ``name{k=v,...}``.

Concurrency model: instrument *creation* takes the registry lock once;
every mutation takes only that instrument's own lock (a few tens of ns —
the jit side never holds or waits on any of these, because the jit side
is forbidden from calling in at all, see below). Reads (``value``,
``snapshot``) are lock-free and may observe a mid-update tear across
fields of one histogram — fine for monitoring, never corrupting.

Tracer-leak guard: every mutating operation asserts it is running as real
host Python, not inside a ``jax.jit`` trace. A metric call that lands in a
trace would silently execute once at trace time and never again — the
worst kind of observability bug (a counter that reads 1 forever). The
guard turns that into a loud ``TracerLeakError`` at trace time, which is
what ``tests/test_obs.py`` pins. Disabling a registry (``enabled=False``)
short-circuits mutations *before* the guard, so a disabled registry is a
couple of attribute loads per call — that is the A/B the serve bench
measures as ``obs_overhead``.
"""
from __future__ import annotations

import json
import math
import sys
import threading
from typing import Optional

try:  # the guard's "am I inside a jit trace?" probe
    from jax.core import trace_state_clean as _trace_state_clean
except ImportError:  # pragma: no cover - ancient/absent jax
    def _trace_state_clean() -> bool:
        return True


class TracerLeakError(RuntimeError):
    """A host-side metric mutation was attempted inside a jit trace."""


def assert_host_side(what: str) -> None:
    """Raise ``TracerLeakError`` if called while a jit trace is active on
    this thread. Host-side observability must never leak into traced
    code: it would run once at trace time and never again."""
    if not _trace_state_clean():
        raise TracerLeakError(
            f"metric operation {what!r} called inside a jit trace; "
            "observability is host-side only — move the call outside the "
            "jit'd function (jax.named_scope is the in-trace annotation)"
        )


def series_key(name: str, labels: tuple) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class _Instrument:
    kind = "?"

    def __init__(self, registry: "MetricsRegistry", name: str, labels: tuple):
        self._registry = registry
        self.name = name
        self.labels = labels
        self.key = series_key(name, labels)
        self._mu = threading.Lock()

    def _on(self, what: str) -> bool:
        """Shared mutation preamble: disabled -> no-op, traced -> raise."""
        if not self._registry.enabled:
            return False
        assert_host_side(what)
        return True

    def describe(self) -> dict:
        raise NotImplementedError


class Counter(_Instrument):
    kind = "counter"

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._n = 0

    def inc(self, n: int = 1) -> None:
        if not self._on(self.key):
            return
        with self._mu:
            self._n += n

    @property
    def value(self) -> int:
        return self._n

    def reset(self) -> None:
        with self._mu:
            self._n = 0

    def describe(self) -> dict:
        return {"type": "counter", "value": self._n}


class Gauge(_Instrument):
    kind = "gauge"

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._v = 0.0

    def set(self, v: float) -> None:
        if not self._on(self.key):
            return
        with self._mu:
            self._v = float(v)

    def inc(self, dv: float = 1.0) -> None:
        if not self._on(self.key):
            return
        with self._mu:
            self._v += dv

    def dec(self, dv: float = 1.0) -> None:
        self.inc(-dv)

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        with self._mu:
            self._v = 0.0

    def describe(self) -> dict:
        return {"type": "gauge", "value": self._v}


# log2 histogram geometry: bucket i spans [LO * 2**i, LO * 2**(i+1))
_HIST_LO = 1e-9
_HIST_NB = 96
# frexp(LO) = (0.5..., -29): cache the exponent offset once
_HIST_E0 = math.frexp(_HIST_LO)[1]


def bucket_index(v: float) -> int:
    """Bucket of value ``v`` (values <= LO clamp to 0, huge clamp to last)."""
    if v <= _HIST_LO:
        return 0
    e = math.frexp(v)[1] - _HIST_E0
    return min(_HIST_NB - 1, max(0, e))


def bucket_lo(i: int) -> float:
    return _HIST_LO * 2.0 ** i


class Histogram(_Instrument):
    """Bounded log2-bucket histogram: O(1) memory, 2x quantile resolution."""

    kind = "histogram"

    def __init__(self, registry, name, labels):
        super().__init__(registry, name, labels)
        self._counts = [0] * _HIST_NB
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        if not self._on(self.key):
            return
        v = float(v)
        i = bucket_index(v)
        with self._mu:
            self._counts[i] += 1
            self._n += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        """q in [0, 1]; geometric midpoint of the bucket holding rank
        ceil(q * count) (one-bucket resolution), clamped to observed
        min/max so a single-sample histogram reports the sample itself."""
        n = self._n
        if n == 0:
            return math.nan
        rank = max(1, math.ceil(q * n))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                mid = math.sqrt(bucket_lo(i) * bucket_lo(i + 1))
                return min(max(mid, self._min), self._max)
        return self._max  # pragma: no cover - rank <= n always hits above

    def reset(self) -> None:
        with self._mu:
            self._counts = [0] * _HIST_NB
            self._n = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf

    def describe(self) -> dict:
        n = self._n
        d = {
            "type": "histogram",
            "count": n,
            "sum": self._sum,
            "min": self._min if n else None,
            "max": self._max if n else None,
            "avg": (self._sum / n) if n else None,
            "p50": self.quantile(0.50) if n else None,
            "p95": self.quantile(0.95) if n else None,
            "p99": self.quantile(0.99) if n else None,
        }
        d["buckets"] = {
            f"{bucket_lo(i):.3g}": c
            for i, c in enumerate(self._counts)
            if c
        }
        return d


class MetricsRegistry:
    """Get-or-create instrument store; the process-global default lives in
    ``repro.obs`` (``default_registry()``). Components accept a
    ``registry=`` argument so tests can count in isolation."""

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._mu = threading.Lock()
        self._metrics: dict[tuple, _Instrument] = {}

    def _get(self, cls, name: str, labels: dict) -> _Instrument:
        key = (name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)  # lock-free fast path (GIL-atomic read)
        if m is None:
            with self._mu:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(self, name, key[1])
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {series_key(name, key[1])!r} already registered "
                f"as {m.kind}, requested {cls.kind}"
            )
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def series(self) -> list[_Instrument]:
        with self._mu:
            return sorted(self._metrics.values(), key=lambda m: m.key)

    def snapshot(self) -> dict:
        """``{series_key: describe()}`` for every registered series."""
        return {m.key: m.describe() for m in self.series()}

    def write_jsonl(self, path: str) -> None:
        """One JSON object per line per series (the exporter format the
        bench/CI artifacts and the example use)."""
        with open(path, "w") as f:
            for m in self.series():
                rec = {"series": m.key, "name": m.name,
                       "labels": dict(m.labels), **m.describe()}
                f.write(json.dumps(rec) + "\n")

    def dump(self, stream=None) -> None:
        """Human-oriented stdout exporter (one line per series)."""
        stream = stream if stream is not None else sys.stdout
        for m in self.series():
            d = m.describe()
            if d["type"] == "histogram":
                if d["count"]:
                    stream.write(
                        f"{m.key} count={d['count']} avg={d['avg']:.3g} "
                        f"p50={d['p50']:.3g} p95={d['p95']:.3g} "
                        f"p99={d['p99']:.3g}\n"
                    )
                else:
                    stream.write(f"{m.key} count=0\n")
            else:
                stream.write(f"{m.key} {d['value']}\n")

    def reset(self) -> None:
        """Zero every series (the series themselves stay registered, so
        instrument handles held by components remain valid)."""
        for m in self.series():
            m.reset()


_default: Optional[MetricsRegistry] = None
_default_mu = threading.Lock()


def default_registry() -> MetricsRegistry:
    global _default
    if _default is None:
        with _default_mu:
            if _default is None:
                _default = MetricsRegistry()
    return _default
