"""Exporters + one-call observability snapshots.

The registry/tracing modules own their own serialization
(``MetricsRegistry.write_jsonl``/``dump``, ``TraceBuffer.dump``); this
module is the batteries-included layer the bench, the example, and CI
use: grab *everything* (metrics + recompile census + trace) in one call,
against the process defaults or explicit instances.
"""
from __future__ import annotations

from typing import Optional

from .jaxprof import RecompileWatch, recompile_watch
from .metrics import MetricsRegistry, default_registry
from .tracing import TraceBuffer, default_buffer


def metrics_snapshot(registry: Optional[MetricsRegistry] = None) -> dict:
    return (registry or default_registry()).snapshot()


def write_metrics_jsonl(path: str,
                        registry: Optional[MetricsRegistry] = None) -> str:
    (registry or default_registry()).write_jsonl(path)
    return path


def dump_metrics(registry: Optional[MetricsRegistry] = None, stream=None):
    (registry or default_registry()).dump(stream)


def write_chrome_trace(path: str,
                       buffer: Optional[TraceBuffer] = None) -> str:
    return (buffer or default_buffer()).dump(path)


def observability_report(
    registry: Optional[MetricsRegistry] = None,
    watch: Optional[RecompileWatch] = None,
) -> dict:
    """Everything the artifacts embed: the metrics snapshot plus the
    recompile census of the default (or given) watch."""
    w = watch or recompile_watch()
    return {
        "metrics": metrics_snapshot(registry),
        "recompiles_by_key": w.by_key(),
        "recompile_seconds_by_key": {
            k: round(v, 6) for k, v in w.seconds_by_key().items()
        },
    }
