"""``repro.obs`` — the end-to-end observability layer of the serving
stack: metrics registry, span-based request tracing, and JAX-aware
profiling hooks. See README "Observability" for the metrics catalog and
usage; ``tests/test_obs.py`` pins the contracts.

Three pillars, one import:

* **metrics** — thread-safe counters/gauges/log-bucket histograms with
  labels (tenant/engine/placement), O(1) memory per series, p50/p95/p99
  off bucket boundaries, JSONL/stdout exporters, and a tracer-leak guard
  (`TracerLeakError`) so no host-side metric call can ever land inside a
  jit trace;
* **tracing** — ``span()`` context managers with per-request trace IDs
  propagated from ``QueryFrontend.query_batch`` down through tenant
  resolution, epoch acquire, cache build, engine solve, and device sync
  (and across threads from ``submit`` to the ingest worker), recorded in
  a lock-free ring buffer and exported as Chrome ``trace_event`` JSON
  (``dump_trace(path)`` -> chrome://tracing / ui.perfetto.dev);
* **jaxprof** — ``named_scope`` (the sanctioned *in-trace* annotation),
  ``compile_region``/``RecompileWatch`` turning XLA recompiles into a
  per-bucketed-shape counter (the ``steady_state_recompiles == 0`` bench
  gate), and opt-in ``jax.profiler`` capture (``profiler_trace``).

Module-level conveniences operate on the process-global defaults;
every component also accepts explicit ``registry=``/buffer instances so
tests can count in isolation. ``set_enabled(False)`` turns the whole
layer into a few attribute loads per call — the A/B the serve bench
records as ``obs_overhead``.
"""
from __future__ import annotations

from typing import Optional

from .export import (
    dump_metrics,
    metrics_snapshot,
    observability_report,
    write_chrome_trace,
    write_metrics_jsonl,
)
from .jaxprof import (
    BACKEND_COMPILE_EVENT,
    UNATTRIBUTED,
    RecompileWatch,
    compile_region,
    current_compile_region,
    named_scope,
    profiler_trace,
    recompile_watch,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    TracerLeakError,
    assert_host_side,
    default_registry,
)
from .tracing import (
    SpanRecord,
    TraceBuffer,
    current_trace_id,
    default_buffer,
    dump_trace,
    new_trace_id,
    resume_trace,
    span,
    trace,
)

__all__ = [
    "BACKEND_COMPILE_EVENT", "UNATTRIBUTED",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "TracerLeakError",
    "RecompileWatch", "SpanRecord", "TraceBuffer",
    "assert_host_side", "compile_region", "counter",
    "current_compile_region", "current_trace_id", "default_buffer",
    "default_registry", "dump_metrics", "dump_trace", "gauge", "histogram",
    "metrics_snapshot", "named_scope", "new_trace_id",
    "observability_report", "profiler_trace", "recompile_watch", "reset",
    "resume_trace", "set_enabled", "span", "trace", "write_chrome_trace",
    "write_metrics_jsonl",
]


def counter(name: str, **labels) -> Counter:
    return default_registry().counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return default_registry().gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return default_registry().histogram(name, **labels)


def set_enabled(on: bool) -> None:
    """Enable/disable the process-default registry AND trace buffer in
    one switch (disabled ops are a couple of attribute loads)."""
    default_registry().enabled = on
    default_buffer().enabled = on


def reset(*, trace_too: bool = True) -> None:
    """Zero the default registry (and clear the default trace buffer):
    the bench calls this at the top so artifacts start from zero."""
    default_registry().reset()
    if trace_too:
        default_buffer().clear()
