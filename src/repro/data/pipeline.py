"""Deterministic, seekable, sharded data pipeline with DMMC-based
diversity-maximized batch selection (the paper's technique as a first-class
training feature).

Determinism/seekability: every (step, shard) pair maps to a PRNG key via
fold_in, so a restart at step s reproduces the exact stream — the
fault-tolerance contract of launch/train.py. Straggler mitigation: work
units are over-decomposed (``overdecompose`` candidate pools per step); a
slow/failed shard's pool is simply dropped from the union (composability
makes the remaining union a valid coreset of the surviving candidates).

Selection: each step draws a candidate pool C x (seq domains + embeddings),
builds a partition matroid over domains (balance caps), runs the jit'd
SeqCoreset, then greedily picks the batch from the coreset maximizing
min-distance spread under the caps — a farthest-first proxy of sum-DMMC
that runs entirely inside jit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.coreset import seq_coreset
from ..core.matroid import MatroidSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_domains: int = 16
    candidates_per_batch: int = 4  # pool = candidates_per_batch * batch
    embed_dim: int = 32
    selector_tau: int = 32
    seed: int = 0
    diverse_selection: bool = True


def _candidate_pool(cfg: DataConfig, step: int):
    """Deterministic candidate pool for a step: tokens, domains, embeddings."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    C = cfg.global_batch * cfg.candidates_per_batch
    domains = jax.random.randint(k1, (C,), 0, cfg.num_domains)
    # domain-conditioned token distribution (unigram shift per domain)
    shift = domains[:, None] * (cfg.vocab // cfg.num_domains)
    tokens = (
        jax.random.randint(k2, (C, cfg.seq_len), 0, cfg.vocab // 2) + shift // 2
    ) % cfg.vocab
    # cheap embedding: hashed unigram features (domain structure + noise)
    centers = jax.random.normal(
        jax.random.PRNGKey(cfg.seed + 1), (cfg.num_domains, cfg.embed_dim)
    )
    emb = centers[domains] + 0.3 * jax.random.normal(k3, (C, cfg.embed_dim))
    return tokens.astype(jnp.int32), domains.astype(jnp.int32), emb


@functools.partial(jax.jit, static_argnames=("k", "tau", "h", "cap_total"))
def _diverse_pick(points, cats, caps, k: int, tau: int, h: int,
                  cap_total: int):
    """SeqCoreset + greedy farthest-first selection under partition caps.

    Returns indices (k,) into points.
    """
    n = points.shape[0]
    spec = MatroidSpec("partition", num_categories=h, gamma=1)
    cs, _res, _ovf = seq_coreset(
        points, cats, jnp.ones((n,), bool), spec, caps, k, tau,
        cap=cap_total,
    )
    m = cs.points.shape[0]
    big = jnp.float32(1e30)

    def body(i, state):
        chosen, counts, min_d = state
        c = cs.cats[:, 0]
        ok = cs.valid & (counts[c] < caps[c]) & (min_d > -1.0)
        score = jnp.where(ok, min_d, -big)
        j = jnp.argmax(score)
        chosen = chosen.at[i].set(cs.src_idx[j])
        counts = counts.at[c[j]].add(1)
        d = jnp.sqrt(
            jnp.maximum(jnp.sum((cs.points - cs.points[j]) ** 2, -1), 0.0)
        )
        min_d = jnp.minimum(min_d, d).at[j].set(-2.0)  # never repick
        return chosen, counts, min_d

    chosen0 = jnp.zeros((k,), jnp.int32)
    counts0 = jnp.zeros((h,), jnp.int32)
    mind0 = jnp.full((m,), big)
    chosen, _, _ = jax.lax.fori_loop(0, k, body, (chosen0, counts0, mind0))
    return chosen


class Pipeline:
    """step -> batch dict. Stateless w.r.t. step (seekable)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        h = cfg.num_domains
        B = cfg.global_batch
        # balance caps: ceil(B / h) * 2 slack
        self.caps = jnp.full((h,), max(1, (B + h - 1) // h * 2), jnp.int32)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        tokens, domains, emb = _candidate_pool(cfg, step)
        if cfg.diverse_selection:
            idx = _diverse_pick(
                emb.astype(jnp.float32), domains[:, None], self.caps,
                cfg.global_batch, cfg.selector_tau, cfg.num_domains,
                cap_total=cfg.global_batch * cfg.selector_tau,
            )
            idx = jnp.maximum(idx, 0)
        else:
            idx = jnp.arange(cfg.global_batch)
        return {
            "tokens": tokens[idx],
            "domains": domains[idx],
            "step": step,
        }
