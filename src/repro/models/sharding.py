"""PartitionSpec rules: FSDP over the data axes x TP/EP over the model axis.

Parameters are *fully sharded*: every matmul weight has one dim on the
model axis (tensor/expert parallel) and one on the data axes (ZeRO-3-style
storage sharding — GSPMD inserts the just-in-time all-gathers). Optimizer
state inherits the param specs. Activations shard batch over the data axes;
long KV caches shard the *sequence* dim over the model axis (decode
attention's softmax reductions over the sharded axis become the collective
term in the roofline — see EXPERIMENTS.md).

``fsdp``: tuple of mesh axis names for data parallelism, e.g. ("data",) or
("pod", "data"). ``tp``: the model axis name.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

FSDP = ("data",)
TP = "model"

# ---------------------------------------------------------------------------
# activation sharding constraints
#
# GSPMD does not reliably propagate batch/head shardings *into* scan bodies
# (measured: flash-attention loops ran fully replicated without these — see
# EXPERIMENTS.md §Perf iteration 1). The launcher pins the ambient axes via
# set_activation_mesh(); model code sprinkles constrain(x, (...)) where 'dp'
# / 'tp' name the data-parallel axes / tensor-parallel axis. When no mesh is
# configured (unit tests, CPU runs) constrain() is a no-op.
# ---------------------------------------------------------------------------

_ACT: dict = {"dp": None, "tp": None}


def set_activation_mesh(dp: Optional[Sequence[str]], tp: Optional[str]):
    _ACT["dp"] = tuple(dp) if dp else None
    _ACT["tp"] = tp


def clear_activation_mesh():
    set_activation_mesh(None, None)


def constrain(x, dims: tuple):
    """dims: per-axis entries in {'dp', 'tp', None}."""
    if _ACT["dp"] is None and _ACT["tp"] is None:
        return x
    spec = []
    for d in dims:
        if d == "dp" and _ACT["dp"]:
            spec.append(_ACT["dp"] if len(_ACT["dp"]) > 1 else _ACT["dp"][0])
        elif d == "tp" and _ACT["tp"]:
            spec.append(_ACT["tp"])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _spec_for(path: tuple[str, ...], ndim: int, fsdp, tp) -> P:
    name = path[-1]
    joined = "/".join(path)

    def pad(spec_dims: list) -> P:
        extra = ndim - len(spec_dims)
        return P(*([None] * extra + spec_dims))

    if name == "embed":
        return pad([tp, fsdp])  # (V, d)
    if name == "lm_head":
        return pad([fsdp, tp])  # (d, V)
    if name in ("wq", "wk", "wv"):
        return pad([fsdp, tp])
    if name == "wo":
        return pad([tp, fsdp])
    if name in ("w_in", "w_gate", "w_out"):
        if "moe" in joined:
            if name == "w_out":
                return pad([tp, None, fsdp])  # (E, f, d)
            return pad([tp, fsdp, None])  # (E, d, f)
        if name == "w_out":
            return pad([tp, fsdp])  # (f, d)
        return pad([fsdp, tp])  # (d, f)
    if name == "router":
        return pad([fsdp, None])
    if name == "in_proj":
        return pad([fsdp, tp])
    if name == "out_proj":
        return pad([tp, fsdp])
    if name == "conv_w":
        return pad([None, tp])
    if name in ("conv_b",):
        return pad([tp])
    if name in ("A_log", "D", "dt_bias"):
        return pad([tp])
    if name == "norm" and "mamba" in joined:
        return pad([tp])
    # norms and other small vectors: replicated
    return P(*([None] * ndim))


def _path_names(key_path) -> tuple[str, ...]:
    names = []
    for k in key_path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(abstract_params: Any, fsdp: Sequence[str] = FSDP,
                tp: Optional[str] = TP) -> Any:
    """PartitionSpec pytree matching an (abstract) param pytree.
    tp=None (single-axis data mesh) drops the tensor-parallel dims."""
    fsdp_t = tuple(fsdp)
    fa = fsdp_t if len(fsdp_t) > 1 else fsdp_t[0]

    def rule(key_path, leaf):
        return _spec_for(_path_names(key_path), leaf.ndim, fa, tp)

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def batch_spec(batch_shardable: bool, fsdp: Sequence[str] = FSDP) -> P:
    fsdp_t = tuple(fsdp)
    fa = fsdp_t if len(fsdp_t) > 1 else fsdp_t[0]
    return P(fa) if batch_shardable else P(None)


def cache_specs(lm, fsdp: Sequence[str] = FSDP, tp: str = TP,
                batch_shardable: bool = True, mode: str = "auto",
                tp_size: int = 16) -> list:
    """Spec pytree mirroring LM.init_caches structure.

    Attention KV caches (count[, inner], B, S, KV, hd): batch over fsdp and
    ONE of {kv-heads, head-dim, sequence} over tp:
      heads — fully local decode attention (preferred; needs KV % tp == 0);
      hd    — local scores with a small per-layer all-reduce (hd % tp == 0);
      seq   — sequence-parallel softmax (always legal, but the decode-write
              DUS on the sharded dim costs ~2x cache in temps: §Perf it. 4).
    mode="auto" picks heads > hd > seq by divisibility.
    Mamba caches: ssm (count[, inner], B, H, P, N) — heads over tp;
    conv (count[, inner], B, K-1, C) — channels over tp.
    """
    fsdp_t = tuple(fsdp)
    fa = (fsdp_t if len(fsdp_t) > 1 else fsdp_t[0]) if batch_shardable else None
    cfg = lm.cfg
    if mode == "auto":
        if cfg.n_kv and cfg.n_kv % tp_size == 0:
            mode = "heads"
        elif cfg.hd % tp_size == 0:
            mode = "hd"
        else:
            mode = "seq"

    def attn_spec(extra: int):
        lead = [None] * extra
        if mode == "heads":
            sp = P(*lead, fa, None, tp, None)
        elif mode == "hd":
            sp = P(*lead, fa, None, None, tp)
        else:
            sp = P(*lead, fa, tp, None, None)
        return (sp, sp)

    def cross_spec(extra: int):
        lead = [None] * extra
        # image KV is short: shard kv-heads dim over tp only if divisible
        return (P(*lead, fa, None, None, None), P(*lead, fa, None, None, None))

    def mamba_spec(extra: int):
        lead = [None] * extra
        return (P(*lead, fa, tp, None, None), P(*lead, fa, None, tp))

    specs = []
    for kind, _count in lm.plan:
        if kind in ("dense", "moe"):
            specs.append(attn_spec(1))
        elif kind == "moe_pair":
            specs.append({"dense": attn_spec(1), "moe": attn_spec(1)})
        elif kind == "mamba":
            specs.append(mamba_spec(1))
        elif kind == "zamba_super":
            specs.append({"mamba": mamba_spec(2), "attn": attn_spec(1)})
        elif kind == "vlm_super":
            specs.append({"dense": attn_spec(2), "cross": cross_spec(1)})
        else:
            raise ValueError(kind)
    return specs
