"""Mixture-of-Experts FFN with group-local capacity dispatch.

Dispatch is scatter-based with per-sequence groups: positions/capacity are
computed *within each sequence* (group = batch row), so the one-hot cumsum
never crosses the data-sharded token axis — no cross-device cumsum, and the
(B, E, C, d) dispatch buffer shards as P('data', 'expert=model', None, None).
Expert matmuls are batched einsums over the expert dim (EP on the model
axis). Top-1 (llama4-style) and top-2 (phi-3.5-style) routing; standard
load-balancing aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import constrain


def moe_init(rng, d: int, f: int, n_experts: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / f) ** 0.5
    return {
        "router": (jax.random.normal(k1, (d, n_experts)) * 0.02).astype(
            jnp.float32
        ),
        "w_in": (jax.random.normal(k2, (n_experts, d, f)) * s_in).astype(dtype),
        "w_gate": (jax.random.normal(k3, (n_experts, d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k4, (n_experts, f, d)) * s_out).astype(dtype),
    }


def moe_apply(
    x: jnp.ndarray,  # (B, S, d)
    p: dict,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    cap = max(1, int(S * top_k * capacity_factor / E + 0.999))

    logits = (x.astype(jnp.float32) @ p["router"])  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)  # (B,S,K)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, per sequence group
    flat_e = eidx.reshape(B, S * top_k)  # (B, T)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, T, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot  # exclusive count
    pos = jnp.sum(pos * onehot, axis=-1)  # (B, T)
    keep = pos < cap  # capacity drop
    pos_c = jnp.minimum(pos, cap - 1)

    # scatter tokens into (B, E, C, d)
    xr = jnp.repeat(x, top_k, axis=1)  # (B, T, d) token per choice
    w = keep.astype(x.dtype)[..., None]
    buf = jnp.zeros((B, E, cap, d), x.dtype)
    b_idx = jnp.arange(B)[:, None]
    buf = buf.at[b_idx, flat_e, pos_c].add(xr * w)
    buf = constrain(buf, ("dp", "tp", None, None))

    # expert computation (batched over E -> EP over the model axis)
    up = jnp.einsum("becd,edf->becf", buf, p["w_in"])
    gt = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    h = jax.nn.silu(gt.astype(jnp.float32)).astype(x.dtype) * up
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_out"])  # (B,E,C,d)
    out_buf = constrain(out_buf, ("dp", "tp", None, None))

    # combine: gather each (token, choice) result and mix by gate
    yg = out_buf[b_idx, flat_e, pos_c]  # (B, T, d)
    yg = yg * w * gate.reshape(B, S * top_k, 1).astype(x.dtype)
    y = jnp.sum(yg.reshape(B, S, top_k, d), axis=2)

    # load-balance aux loss (Shazeer): E * sum_e f_e * p_e
    density = jnp.mean(
        jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    p_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * p_mean)
    return y, aux
