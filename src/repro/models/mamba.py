"""Mamba2 block (state-space duality, arXiv:2405.21060) in chunked matmul
form — the TPU-native phrasing (intra-chunk work is MXU matmuls; the
inter-chunk recurrence is a tiny scan over (H, P, N) states).

The per-(chunk, head) intra-chunk math is exactly kernels/ssd.py's Pallas
kernel; this module uses broadcast-friendly einsums (ngroups=1 shares B/C
across heads without materializing per-head copies) and is tied to the
kernel + recurrent oracle by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import rms_norm
from .sharding import constrain


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_state


def mamba_init(rng, cfg, dtype) -> dict:
    d = cfg.d_model
    d_inner, H, N = mamba_dims(cfg)
    conv_ch = d_inner + 2 * N
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    proj_out = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    s = (1.0 / d) ** 0.5
    return {
        "in_proj": (jax.random.normal(k1, (d, proj_out)) * s).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.d_conv, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(
            jax.random.uniform(k3, (H,), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(jax.random.uniform(k4, (H,), jnp.float32, 1e-3, 0.1)) - 1.0
        ),
        "norm": jnp.ones((d_inner,), dtype),
        "out_proj": (
            jax.random.normal(k5, (d_inner, d)) * (1.0 / d_inner) ** 0.5
        ).astype(dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(
        pad[:, j : j + x.shape[1], :] * w[j][None, None, :] for j in range(K)
    )
    return y + b[None, None, :]


def ssd_chunked(
    xbar: jnp.ndarray,  # (B, S, H, P) dt-scaled inputs
    loga: jnp.ndarray,  # (B, S, H) log decays (<= 0)
    Bm: jnp.ndarray,  # (B, S, N)
    Cm: jnp.ndarray,  # (B, S, N)
    chunk: int,
    s0: jnp.ndarray | None = None,  # (B, H, P, N)
):
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = xbar.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc, Q = S // chunk, chunk
    f32 = jnp.float32

    xb = xbar.reshape(Bsz, nc, Q, H, P).astype(f32)
    la = loga.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(f32)

    cum = jnp.cumsum(la, axis=2)  # (B,nc,Q,H)
    cumT = cum.transpose(0, 1, 3, 2)  # (B,nc,H,Q)
    diff = cumT[..., :, None] - cumT[..., None, :]  # (B,nc,H,Q,Q)
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tril, jnp.exp(diff), 0.0)
    G = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)  # (B,nc,Q,Q)
    M = G[:, :, None] * L  # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchts,bcshp->bcthp", M, xb)

    decay_end = jnp.exp(cumT[..., -1:] - cumT)  # (B,nc,H,Q)
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn", Bc, decay_end, xb)
    total = jnp.exp(cumT[..., -1])  # (B,nc,H)

    if s0 is None:
        s0 = jnp.zeros((Bsz, H, P, N), f32)

    def step(s, inp):
        st_c, tot_c = inp  # (B,H,P,N), (B,H)
        s_next = s * tot_c[:, :, None, None] + st_c
        return s_next, s  # emit state *entering* the chunk

    s_fin, s_prev = jax.lax.scan(
        step,
        s0.astype(f32),
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    decay_start = jnp.exp(cumT)  # (B,nc,H,Q)
    y_off = jnp.einsum("bctn,bchpn,bcht->bcthp", Cc, s_prev, decay_start)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(xbar.dtype), s_fin


def _split_proj(zxbcdt, d_inner, N, H):
    z = zxbcdt[..., :d_inner]
    xc = zxbcdt[..., d_inner : 2 * d_inner]
    Bc = zxbcdt[..., 2 * d_inner : 2 * d_inner + N]
    Cc = zxbcdt[..., 2 * d_inner + N : 2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N :]
    return z, xc, Bc, Cc, dt


def mamba_apply(
    x: jnp.ndarray,  # (B, S, d)
    p: dict,
    cfg,
    *,
    chunk: int = 256,
    want_cache: bool = False,
):
    """Full-sequence Mamba2 block. Returns (y, cache | None).

    cache = (ssm_state (B,H,P,N) f32, conv_cache (B, d_conv-1, conv_ch)).
    """
    B, S, d = x.shape
    d_inner, H, N = mamba_dims(cfg)
    P = cfg.ssm_head_dim
    zxbcdt = constrain(x @ p["in_proj"], ("dp", None, "tp"))
    z, xc, Bc, Cc, dt = _split_proj(zxbcdt, d_inner, N, H)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    ).astype(x.dtype)
    xc = conv_out[..., :d_inner]
    Bc = conv_out[..., d_inner : d_inner + N]
    Cc = conv_out[..., d_inner + N :]

    xh = constrain(xc.reshape(B, S, H, P), ("dp", None, "tp", None))
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    loga = -jnp.exp(p["A_log"])[None, None] * dtf
    xbar = xh.astype(jnp.float32) * dtf[..., None]

    c = min(chunk, S)
    while S % c:
        c //= 2
    y, s_fin = ssd_chunked(xbar, loga, Bc, Cc, c)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = constrain(y.reshape(B, S, d_inner), ("dp", None, "tp"))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"])
    out = y @ p["out_proj"]
    if not want_cache:
        return out, None
    conv_cache = conv_in[:, S - (cfg.d_conv - 1) :, :]
    return out, (s_fin, conv_cache)


def mamba_decode(
    x: jnp.ndarray,  # (B, 1, d)
    p: dict,
    cfg,
    cache,  # (ssm_state (B,H,P,N), conv_cache (B, d_conv-1, conv_ch))
):
    B, _, d = x.shape
    d_inner, H, N = mamba_dims(cfg)
    P = cfg.ssm_head_dim
    ssm, conv_cache = cache
    zxbcdt = x @ p["in_proj"]
    z, xc, Bc, Cc, dt = _split_proj(zxbcdt[:, 0], d_inner, N, H)
    conv_new = jnp.concatenate([xc, Bc, Cc], axis=-1)  # (B, conv_ch)
    win = jnp.concatenate([conv_cache, conv_new[:, None]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xc = conv_out[..., :d_inner]
    Bc = conv_out[..., d_inner : d_inner + N].astype(jnp.float32)
    Cc = conv_out[..., d_inner + N :].astype(jnp.float32)

    xh = xc.reshape(B, H, P).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"])[None] * dtf)  # (B,H)
    xbar = xh * dtf[..., None]
    ssm = ssm * a[..., None, None] + xbar[..., None] * Bc[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cc) + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, (ssm, win[:, 1:])
