"""LM assembly for every assigned architecture family.

A model is a sequence of *segments*; each segment is ``count`` identical
blocks whose parameters are stacked on a leading axis and applied with
``lax.scan`` (+ per-block remat in training) so the HLO stays compact even
for 100-layer configs — essential for the 512-device dry-run compiles.

Families -> layer plans:
  dense/audio   [("dense", L)]
  moe           [("moe", L)] or [("moe_pair", L/2)] (interleaved, llama4)
  ssm           [("mamba", L)]
  hybrid        [("zamba_super", L//e), ("mamba", L%e)]   e = shared_attn_every
                (each super = e mamba blocks + ONE shared attn block whose
                 single weight set is closed over, zamba2-style)
  vlm           [("vlm_super", L//e)]                      e = cross_attn_every
                (each super = e-1 self-attn blocks + 1 cross-attn block
                 attending to stub image embeddings)

Three entry points per model: ``forward`` (train / prefill — prefill also
emits KV/SSM caches), ``decode_step`` (single token against caches), and
``loss`` (next-token CE + MoE aux).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import (
    attn_init,
    attn_qkv,
    blockwise_attention,
    decode_attention,
    mlp_apply,
    mlp_init,
    rms_norm,
    rope,
)
from .sharding import constrain
from .mamba import mamba_apply, mamba_decode, mamba_dims, mamba_init
from .moe import moe_apply, moe_init

Params = Any
Cache = Any


def layer_plan(cfg: ArchConfig) -> list[tuple[str, int]]:
    if cfg.family in ("dense", "audio"):
        return [("dense", cfg.n_layers)]
    if cfg.family == "moe":
        if cfg.moe_every == 1:
            return [("moe", cfg.n_layers)]
        assert cfg.moe_every == 2, cfg.moe_every
        return [("moe_pair", cfg.n_layers // 2)]
    if cfg.family == "ssm":
        return [("mamba", cfg.n_layers)]
    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        supers, tail = divmod(cfg.n_layers, e)
        plan: list[tuple[str, int]] = [("zamba_super", supers)]
        if tail:
            plan.append(("mamba", tail))
        return plan
    if cfg.family == "vlm":
        e = cfg.cross_attn_every
        assert cfg.n_layers % e == 0
        return [("vlm_super", cfg.n_layers // e)]
    raise ValueError(cfg.family)


# --------------------------------------------------------------------------
# sub-layer init
# --------------------------------------------------------------------------


def _dense_block_init(rng, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
    }


def _moe_block_init(rng, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "moe": moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype),
    }


def _mamba_block_init(rng, cfg: ArchConfig, dtype):
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "mamba": mamba_init(rng, cfg, dtype),
    }


def block_init(kind: str, rng, cfg: ArchConfig, dtype):
    if kind == "dense":
        return _dense_block_init(rng, cfg, dtype)
    if kind == "moe":
        return _moe_block_init(rng, cfg, dtype)
    if kind == "moe_pair":
        k1, k2 = jax.random.split(rng)
        return {
            "dense": _dense_block_init(k1, cfg, dtype),
            "moe": _moe_block_init(k2, cfg, dtype),
        }
    if kind == "mamba":
        return _mamba_block_init(rng, cfg, dtype)
    if kind == "zamba_super":
        ks = jax.random.split(rng, cfg.shared_attn_every)
        return {
            "mamba": jax.vmap(
                lambda r: _mamba_block_init(r, cfg, dtype)
            )(ks),
        }
    if kind == "vlm_super":
        e = cfg.cross_attn_every
        ks = jax.random.split(rng, e)
        return {
            "dense": jax.vmap(
                lambda r: _dense_block_init(r, cfg, dtype)
            )(ks[: e - 1]),
            "cross": _dense_block_init(ks[e - 1], cfg, dtype),
        }
    raise ValueError(kind)


# --------------------------------------------------------------------------
# sub-layer apply (full-sequence: train & prefill)
# --------------------------------------------------------------------------


def _self_attn_full(p, x, positions, cfg: ArchConfig, want_cache, skip_masked):
    h = rms_norm(x, p["ln1"])
    q, k, v = attn_qkv(h, p["attn"], cfg.n_heads, cfg.n_kv, cfg.hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    cache = (k, v) if want_cache else None
    # expand GQA KV to full heads for the sequence path: k/v then shard over
    # TP exactly like q (the emitted cache stays GQA-compact). Costs a rep-x
    # larger k/v activation, consumed blockwise by flash attention.
    rep = cfg.n_heads // cfg.n_kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, "tp", None))
    v = constrain(v, ("dp", None, "tp", None))
    o = blockwise_attention(
        q, k, v, causal=True,
        q_block=cfg.q_block, kv_block=cfg.kv_block,
        skip_masked_blocks=skip_masked,
    )
    o = constrain(o, ("dp", None, "tp", None))
    B, S = x.shape[:2]
    x = x + o.reshape(B, S, -1) @ p["attn"]["wo"]
    return constrain(x, ("dp", None, None)), cache


def _cross_attn_full(p, x, img, cfg: ArchConfig, want_cache):
    h = rms_norm(x, p["ln1"])
    B, S, _ = x.shape
    q = (h @ p["attn"]["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    ni = img.shape[1]
    k = (img @ p["attn"]["wk"]).reshape(B, ni, cfg.n_kv, cfg.hd)
    v = (img @ p["attn"]["wv"]).reshape(B, ni, cfg.n_kv, cfg.hd)
    cache = (k, v) if want_cache else None
    rep = cfg.n_heads // cfg.n_kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, "tp", None))
    v = constrain(v, ("dp", None, "tp", None))
    o = blockwise_attention(
        q, k, v, causal=False, q_block=cfg.q_block, kv_block=cfg.kv_block
    )
    o = constrain(o, ("dp", None, "tp", None))
    x = x + o.reshape(B, S, -1) @ p["attn"]["wo"]
    return constrain(x, ("dp", None, None)), cache


def _mlp_sub(p, x, cfg: ArchConfig):
    return x + mlp_apply(rms_norm(x, p["ln2"]), p["mlp"], cfg.mlp)


def _moe_sub(p, x, cfg: ArchConfig):
    y, aux = moe_apply(
        rms_norm(x, p["ln2"]), p["moe"],
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
    )
    return x + y, aux


def block_apply_full(
    kind, p, x, ctx, *, want_cache: bool
) -> tuple[jnp.ndarray, jnp.ndarray, Cache]:
    """Returns (x, aux, cache). ctx: dict(positions, img, shared, cfg, ...)."""
    cfg: ArchConfig = ctx["cfg"]
    zero = jnp.zeros((), jnp.float32)
    if kind == "dense":
        x, cache = _self_attn_full(
            p, x, ctx["positions"], cfg, want_cache, ctx["skip_masked"]
        )
        x = _mlp_sub(p, x, cfg)
        return x, zero, cache
    if kind == "moe":
        x, cache = _self_attn_full(
            p, x, ctx["positions"], cfg, want_cache, ctx["skip_masked"]
        )
        x, aux = _moe_sub(p, x, cfg)
        return x, aux, cache
    if kind == "moe_pair":
        x, aux1, c1 = block_apply_full(
            "dense", p["dense"], x, ctx, want_cache=want_cache
        )
        x, aux2, c2 = block_apply_full(
            "moe", p["moe"], x, ctx, want_cache=want_cache
        )
        return x, aux1 + aux2, {"dense": c1, "moe": c2}
    if kind == "mamba":
        h = rms_norm(x, p["ln"])
        y, cache = mamba_apply(
            h, p["mamba"], cfg, chunk=cfg.ssd_chunk, want_cache=want_cache
        )
        return x + y, zero, cache
    if kind == "zamba_super":
        def inner(xc, pl):
            xc, _, cache = block_apply_full(
                "mamba", pl, xc, ctx, want_cache=want_cache
            )
            return xc, cache
        x, mcaches = jax.lax.scan(inner, x, p["mamba"])
        x, _, acache = block_apply_full(
            "dense", ctx["shared"], x, ctx, want_cache=want_cache
        )
        return x, zero, {"mamba": mcaches, "attn": acache}
    if kind == "vlm_super":
        def inner(xc, pl):
            xc, _, cache = block_apply_full(
                "dense", pl, xc, ctx, want_cache=want_cache
            )
            return xc, cache
        x, dcaches = jax.lax.scan(inner, x, p["dense"])
        x, ccache = _cross_attn_full(
            p["cross"], x, ctx["img"], cfg, want_cache
        )
        x = _mlp_sub(p["cross"], x, cfg)
        return x, zero, {"dense": dcaches, "cross": ccache}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# sub-layer apply (single-token decode against caches)
# --------------------------------------------------------------------------


def _self_attn_decode(p, x, pos, cache, cfg: ArchConfig):
    kc, vc = cache
    h = rms_norm(x, p["ln1"])
    q, k, v = attn_qkv(h, p["attn"], cfg.n_heads, cfg.n_kv, cfg.hd)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
    o = decode_attention(q, kc, vc, pos)
    x = x + o.reshape(B, 1, -1) @ p["attn"]["wo"]
    return x, (kc, vc)


def _cross_attn_decode(p, x, cache, cfg: ArchConfig):
    kc, vc = cache  # static image KV from prefill
    h = rms_norm(x, p["ln1"])
    B = x.shape[0]
    q = (h @ p["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
    o = decode_attention(q, kc, vc, jnp.int32(kc.shape[1] - 1))
    x = x + o.reshape(B, 1, -1) @ p["attn"]["wo"]
    return x, (kc, vc)


def block_apply_decode(kind, p, x, cache, ctx):
    cfg: ArchConfig = ctx["cfg"]
    pos = ctx["pos"]
    if kind == "dense":
        x, cache = _self_attn_decode(p, x, pos, cache, cfg)
        x = _mlp_sub(p, x, cfg)
        return x, cache
    if kind == "moe":
        x, cache = _self_attn_decode(p, x, pos, cache, cfg)
        x, _aux = _moe_sub(p, x, cfg)
        return x, cache
    if kind == "moe_pair":
        x, c1 = block_apply_decode("dense", p["dense"], x, cache["dense"], ctx)
        x, c2 = block_apply_decode("moe", p["moe"], x, cache["moe"], ctx)
        return x, {"dense": c1, "moe": c2}
    if kind == "mamba":
        h = rms_norm(x, p["ln"])
        y, cache = mamba_decode(h, p["mamba"], cfg, cache)
        return x + y, cache
    if kind == "zamba_super":
        def inner(xc, inp):
            pl, cl = inp
            xc, cl = block_apply_decode("mamba", pl, xc, cl, ctx)
            return xc, cl
        x, mcaches = jax.lax.scan(inner, x, (p["mamba"], cache["mamba"]))
        x, acache = block_apply_decode(
            "dense", ctx["shared"], x, cache["attn"], ctx
        )
        return x, {"mamba": mcaches, "attn": acache}
    if kind == "vlm_super":
        def inner(xc, inp):
            pl, cl = inp
            xc, cl = block_apply_decode("dense", pl, xc, cl, ctx)
            return xc, cl
        x, dcaches = jax.lax.scan(inner, x, (p["dense"], cache["dense"]))
        x, ccache = _cross_attn_decode(p["cross"], x, cache["cross"], cfg)
        x = _mlp_sub(p["cross"], x, cfg)
        return x, {"dense": dcaches, "cross": ccache}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# the model
# --------------------------------------------------------------------------


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.plan = layer_plan(cfg)
        self.dtype = jnp.dtype(cfg.dtype)

    # ---- parameters ----

    def init(self, rng) -> Params:
        cfg = self.cfg
        keys = jax.random.split(rng, len(self.plan) + 3)
        vp = cfg.vocab_padded
        params: dict = {
            "embed": (
                jax.random.normal(keys[0], (vp, cfg.d_model)) * 0.02
            ).astype(self.dtype),
            "final_norm": jnp.ones((cfg.d_model,), self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (
                jax.random.normal(keys[1], (cfg.d_model, vp)) * 0.02
            ).astype(self.dtype)
        if cfg.family == "hybrid":
            params["shared"] = _dense_block_init(keys[2], cfg, self.dtype)
        for si, (kind, count) in enumerate(self.plan):
            ks = jax.random.split(keys[3 + si], count)
            params[f"seg{si}"] = jax.vmap(
                lambda r: block_init(kind, r, cfg, self.dtype)
            )(ks)
        return params

    def abstract_params(self, seed: int = 0) -> Params:
        return jax.eval_shape(
            lambda: self.init(jax.random.PRNGKey(seed))
        )

    # ---- forward (train / prefill) ----

    def _ctx(self, positions, img, params, skip_masked):
        return dict(
            cfg=self.cfg,
            positions=positions,
            img=img,
            shared=params.get("shared"),
            skip_masked=skip_masked,
        )

    def forward(
        self,
        params: Params,
        tokens: jnp.ndarray,  # (B, S) int32
        img: Optional[jnp.ndarray] = None,  # (B, n_img, d) stub embeddings
        *,
        want_caches: bool = False,
        remat: bool = True,
        skip_masked: bool = False,
    ):
        """Returns (logits (B,S,V), aux scalar, caches list | None)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, ("dp", None, None))
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        ctx = self._ctx(positions, img, params, skip_masked)

        caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for si, (kind, _count) in enumerate(self.plan):
            def body(xc, pl, _kind=kind):
                xn, aux, cache = block_apply_full(
                    _kind, pl, xc, ctx, want_cache=want_caches
                )
                return xn, (aux, cache)

            if remat and not want_caches:
                body = jax.checkpoint(body)
            x, (auxs, cache) = jax.lax.scan(body, x, params[f"seg{si}"])
            aux_total = aux_total + jnp.sum(auxs)
            caches.append(cache)

        x = rms_norm(x, params["final_norm"])
        if want_caches:
            # prefill only needs next-token logits: never materialize (B,S,V)
            x = x[:, -1:]
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        )
        logits = x @ head
        return logits, aux_total, (caches if want_caches else None)

    # ---- losses ----

    def loss(self, params, tokens, img=None, *, remat=True, skip_masked=False):
        logits, aux, _ = self.forward(
            params, tokens, img, remat=remat, skip_masked=skip_masked
        )
        tgt = tokens[:, 1:]
        lg = logits[:, :-1]
        # shard-local CE: the gold logit is picked with a vocab-mask reduce
        # (stays sharded over the vocab/tp axis; a gather here all-gathers
        # the full logits — §Perf iteration 2), logsumexp reduces with f32
        # accumulation without materializing an f32 copy of the logits.
        vocab_ids = jnp.arange(lg.shape[-1], dtype=tgt.dtype)
        onehot = vocab_ids[None, None, :] == tgt[..., None]
        gold = jnp.sum(
            jnp.where(onehot, lg, 0).astype(jnp.float32), axis=-1
        )
        m = jnp.max(lg, axis=-1).astype(jnp.float32)
        logz = m + jnp.log(
            jnp.sum(
                jnp.exp(lg.astype(jnp.float32) - m[..., None]), axis=-1
            )
        )
        ce = jnp.mean(logz - gold)
        return ce + 0.01 * aux, dict(ce=ce, aux=aux)

    # ---- serving ----

    def prefill(self, params, tokens, img=None):
        logits, _aux, caches = self.forward(
            params, tokens, img, want_caches=True, remat=False
        )
        return logits[:, -1], caches

    def decode_step(self, params, token, caches, pos, img=None):
        """token: (B, 1) int32; pos: scalar int32 (write position).

        Returns (logits (B, V), new caches).
        """
        cfg = self.cfg
        B = token.shape[0]
        x = jnp.take(params["embed"], token, axis=0)
        ctx = self._ctx(None, img, params, False)
        ctx["pos"] = pos

        new_caches = []
        for si, (kind, count) in enumerate(self.plan):
            pstack = params[f"seg{si}"]

            # fori_loop with in-place dynamic updates on the cache carry:
            # a scan emitting updated caches as ys would double-buffer the
            # whole KV stack (measured ~2.5x cache in temps — §Perf it. 4)
            def body(i, carry, _kind=kind, _pstack=pstack):
                xc, cache = carry
                pl = jax.tree.map(
                    lambda s: jax.lax.dynamic_index_in_dim(
                        s, i, 0, keepdims=False
                    ),
                    _pstack,
                )
                cl = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, i, 0, keepdims=False
                    ),
                    cache,
                )
                xn, cl_new = block_apply_decode(_kind, pl, xc, cl, ctx)
                cache = jax.tree.map(
                    lambda c, u: jax.lax.dynamic_update_index_in_dim(
                        c, u.astype(c.dtype), i, 0
                    ),
                    cache,
                    cl_new,
                )
                return (xn, cache)

            x, cache = jax.lax.fori_loop(0, count, body, (x, caches[si]))
            new_caches.append(cache)

        x = rms_norm(x, params["final_norm"])
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        )
        logits = (x @ head)[:, 0]
        return logits, new_caches

    # ---- cache allocation (decode dry-run entry) ----

    def init_caches(self, batch: int, seq_len: int) -> list:
        """Abstract-friendly cache pytree for a cache of ``seq_len``."""
        cfg = self.cfg
        d_inner, H, N = (
            mamba_dims(cfg) if cfg.ssm_state else (0, 0, 0)
        )
        P = cfg.ssm_head_dim
        conv_ch = d_inner + 2 * N

        def attn_cache(count_shape):
            shp = (*count_shape, batch, seq_len, cfg.n_kv, cfg.hd)
            return (
                jnp.zeros(shp, self.dtype),
                jnp.zeros(shp, self.dtype),
            )

        def mamba_cache(count_shape):
            return (
                jnp.zeros((*count_shape, batch, H, P, N), jnp.float32),
                jnp.zeros(
                    (*count_shape, batch, cfg.d_conv - 1, conv_ch), self.dtype
                ),
            )

        caches = []
        for kind, count in self.plan:
            if kind in ("dense", "moe"):
                caches.append(attn_cache((count,)))
            elif kind == "moe_pair":
                caches.append(
                    {"dense": attn_cache((count,)), "moe": attn_cache((count,))}
                )
            elif kind == "mamba":
                caches.append(mamba_cache((count,)))
            elif kind == "zamba_super":
                caches.append(
                    {
                        "mamba": mamba_cache((count, cfg.shared_attn_every)),
                        "attn": attn_cache((count,)),
                    }
                )
            elif kind == "vlm_super":
                e = cfg.cross_attn_every
                caches.append(
                    {
                        "dense": attn_cache((count, e - 1)),
                        "cross": (
                            jnp.zeros(
                                (count, batch, cfg.n_img_tokens, cfg.n_kv, cfg.hd),
                                self.dtype,
                            ),
                            jnp.zeros(
                                (count, batch, cfg.n_img_tokens, cfg.n_kv, cfg.hd),
                                self.dtype,
                            ),
                        ),
                    }
                )
            else:
                raise ValueError(kind)
        return caches

    def param_count(self) -> int:
        import math

        shapes = jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))
        return sum(
            math.prod(l.shape)
            for l in jax.tree_util.tree_leaves(shapes)
        )

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (6*N_active*D accounting)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        # subtract inactive experts' FFN params
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe_layers = cfg.n_layers // cfg.moe_every
        inactive = (cfg.n_experts - cfg.top_k) * per_expert * n_moe_layers
        return total - inactive
