"""Model zoo: the assigned architecture pool as composable JAX modules."""
from .model import LM, layer_plan

__all__ = ["LM", "layer_plan"]
