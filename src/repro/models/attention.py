"""Flash attention in pure JAX with a custom VJP.

Forward: online-softmax over kv blocks (scan), emitting per-row logsumexp.
Backward: recomputes score blocks (never materializing S_q x S_kv), scanning
kv blocks and accumulating dq into a full buffer while emitting dk/dv per
block. Residuals saved: (q, k, v, out, lse) — O(S * d), NOT O(S^2).

This is the production-critical piece for train_4k/prefill_32k memory: the
naive scan-based online softmax keeps O(S^2 / bk) probability blocks alive
for autodiff, which at 32k blows past HBM (measured: 143 GiB/device for a
135M model before this — EXPERIMENTS.md §Perf).

``bound_blocks(causal, skip)``: with skip=True the kv-scan for q-block i is
python-unrolled to [0 .. ceil((i+1) bq / bk)] (and the mirrored bound in the
backward), eliminating the ~2x causal-FLOPs waste of mask-everything
schedules. Exposed as the beyond-paper §Perf optimization.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_for(qpos, kpos, skv_real, causal):
    m = kpos[None, :] < skv_real
    if causal:
        m = m & (qpos[:, None] >= kpos[None, :])
    else:
        m = jnp.broadcast_to(m, (qpos.shape[0], kpos.shape[0]))
    return m


def _fwd_qblock(qb, kr, vr, qpos, nk_for_qi, *, bk, skv_real, causal, scale):
    B, KV, rep, bq, hd = qb.shape

    def kv_body(carry, kj):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kr, kj * bk, bk, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vr, kj * bk, bk, axis=2)
        s = jax.lax.dot_general(
            qb, kb, (((4,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        ) * scale
        kpos = kj * bk + jnp.arange(bk)
        mask = _mask_for(qpos, kpos, skv_real, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(vb.dtype), vb, (((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        )
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, KV, rep, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
    a0 = jnp.zeros((B, KV, rep, bq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        kv_body, (m0, l0, a0), jnp.arange(nk_for_qi)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
    return out, lse


def _nk_for(qi, bq, bk, nk, causal, skip):
    if not (causal and skip):
        return nk
    hi = ((qi + 1) * bq + bk - 1) // bk
    return max(1, min(nk, hi))


def _nq_lo_for(kj, bq, bk, nq, causal, skip):
    """First q block that sees kv block kj (mirrored bound for backward)."""
    if not (causal and skip):
        return 0
    return min(nq - 1, (kj * bk) // bq)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, q_offset, bq, bk, skv_real, skip):
    out, _res = _flash_fwd(q, k, v, causal, q_offset, bq, bk, skv_real, skip)
    return out


def _flash_fwd(q, k, v, causal, q_offset, bq, bk, skv_real, skip):
    # q: (B, KV, rep, Sq, hd); k/v: (B, KV, Skv, hd) — pre-blocked layout
    B, KV, rep, Sq, hd = q.shape
    Skv = k.shape[2]
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / (hd ** 0.5)

    def q_body(qi, nk_qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=3)
        qpos = q_offset + qi * bq + jnp.arange(bq)
        return _fwd_qblock(
            qb, k, v, qpos, nk_qi, bk=bk, skv_real=skv_real,
            causal=causal, scale=scale,
        )

    if causal and skip:
        outs, lses = [], []
        for qi in range(nq):
            o, s = q_body(qi, _nk_for(qi, bq, bk, nk, causal, skip))
            outs.append(o)
            lses.append(s)
        out = jnp.concatenate(outs, axis=3)
        lse = jnp.concatenate(lses, axis=3)
    else:
        _, (ob, sb) = jax.lax.scan(
            lambda _, qi: (None, q_body(qi, nk)), None, jnp.arange(nq)
        )
        out = jnp.moveaxis(ob, 0, 3).reshape(B, KV, rep, Sq, hd)
        lse = jnp.moveaxis(sb, 0, 3).reshape(B, KV, rep, Sq)

    out = out.astype(q.dtype)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, bq, bk, skv_real, skip, res, dout):
    q, k, v, out, lse = res
    B, KV, rep, Sq, hd = q.shape
    Skv = k.shape[2]
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / (hd ** 0.5)
    do = dout.astype(jnp.float32)
    D = jnp.sum(do * out.astype(jnp.float32), axis=-1)  # (B,KV,rep,Sq)

    def q_inner(kj, kb, vb, kpos, qi, dq_acc):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, axis=3)
        dob = jax.lax.dynamic_slice_in_dim(do, qi * bq, bq, axis=3)
        lseb = jax.lax.dynamic_slice_in_dim(lse, qi * bq, bq, axis=3)
        Db = jax.lax.dynamic_slice_in_dim(D, qi * bq, bq, axis=3)
        qpos = q_offset + qi * bq + jnp.arange(bq)
        s = jax.lax.dot_general(
            qb, kb, (((4,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        ) * scale
        mask = _mask_for(qpos, kpos, skv_real, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])  # (B,KV,rep,bq,bk)
        # dv_j += p^T dO ; dp = dO v^T
        dv_c = jax.lax.dot_general(
            p, dob, (((3,), (3,)), ((0, 1, 2), (0, 1, 2))),
            preferred_element_type=jnp.float32,
        )  # (B,KV,rep,bk,hd)
        dp = jax.lax.dot_general(
            dob, vb.astype(jnp.float32), (((4,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        )  # (B,KV,rep,bq,bk)
        ds = p * (dp - Db[..., None]) * scale
        dq_b = jax.lax.dot_general(
            ds, kb.astype(jnp.float32), (((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        )  # (B,KV,rep,bq,hd)
        dk_c = jax.lax.dot_general(
            ds, qb.astype(jnp.float32), (((3,), (3,)), ((0, 1, 2), (0, 1, 2))),
            preferred_element_type=jnp.float32,
        )  # (B,KV,rep,bk,hd)
        prev = jax.lax.dynamic_slice_in_dim(dq_acc, qi * bq, bq, axis=3)
        dq_acc = jax.lax.dynamic_update_slice_in_dim(
            dq_acc, prev + dq_b, qi * bq, axis=3
        )
        return dq_acc, dk_c, dv_c

    def kv_body(dq_acc, kj_static_range):
        kj, lo = kj_static_range

        kb = jax.lax.dynamic_slice_in_dim(k, kj * bk, bk, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(v, kj * bk, bk, axis=2)
        kpos = kj * bk + jnp.arange(bk)

        def scan_qi(carry, qi):
            dq_acc, dk_j, dv_j = carry
            dq_acc, dk_c, dv_c = q_inner(kj, kb, vb, kpos, qi, dq_acc)
            return (dq_acc, dk_j + dk_c, dv_j + dv_c), None

        dk0 = jnp.zeros((B, KV, rep, bk, hd), jnp.float32)
        dv0 = jnp.zeros((B, KV, rep, bk, hd), jnp.float32)
        (dq_acc, dk_j, dv_j), _ = jax.lax.scan(
            scan_qi, (dq_acc, dk0, dv0), jnp.arange(lo, nq)
        )
        return dq_acc, (dk_j, dv_j)

    dq = jnp.zeros((B, KV, rep, Sq, hd), jnp.float32)
    if causal and skip:
        dks, dvs = [], []
        for kj in range(nk):
            lo = _nq_lo_for(kj, bq, bk, nq, causal, skip)
            dq, (dk_j, dv_j) = kv_body(dq, (kj, lo))
            dks.append(dk_j)
            dvs.append(dv_j)
        dk_all = jnp.stack(dks)  # (nk, B,KV,rep,bk,hd)
        dv_all = jnp.stack(dvs)
    else:
        def scan_kj(dq_acc, kj):
            dq_acc, (dk_j, dv_j) = kv_body(dq_acc, (kj, 0))
            return dq_acc, (dk_j, dv_j)

        dq, (dk_all, dv_all) = jax.lax.scan(
            scan_kj, dq, jnp.arange(nk)
        )

    # (nk, B, KV, rep, bk, hd) -> sum rep -> (B, KV, Skv, hd)
    dk = jnp.moveaxis(dk_all.sum(axis=3), 0, 2).reshape(B, KV, Skv, hd)
    dv = jnp.moveaxis(dv_all.sum(axis=3), 0, 2).reshape(B, KV, Skv, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    skip_masked_blocks: bool = False,
) -> jnp.ndarray:
    B, Sq0, H, hd = q.shape
    _, Skv0, KV, _ = k.shape
    rep = H // KV
    bq = min(q_block, Sq0)
    bk = min(kv_block, Skv0)
    pq = -Sq0 % bq
    pkv = -Skv0 % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    Sq = Sq0 + pq
    qr = q.reshape(B, Sq, KV, rep, hd).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)
    out = _flash(
        qr, kr, vr, causal, q_offset, bq, bk, Skv0, skip_masked_blocks
    )
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out[:, :Sq0]
