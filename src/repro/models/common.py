"""Shared transformer building blocks: RMSNorm, RoPE, GQA attention
(blockwise online-softmax for long sequences), SwiGLU/GELU MLPs.

All matmul-heavy paths accumulate in f32 (preferred_element_type) and keep
activations in the config dtype (bf16 by default).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32. Rotates in f32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def _block_scores(q, k, scale):
    # q: (B, KV, rep, bq, hd), k: (B, KV, bk, hd) -> (B, KV, rep, bq, bk)
    return jax.lax.dot_general(
        q, k,
        (((4,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32,
    ) * scale


def blockwise_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    causal: bool,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    skip_masked_blocks: bool = False,
) -> jnp.ndarray:
    """Flash attention (custom-VJP; O(S*d) residuals). See attention.py."""
    from .attention import flash_attention

    return flash_attention(
        q, k, v, causal=causal, q_offset=q_offset, q_block=q_block,
        kv_block=kv_block, skip_masked_blocks=skip_masked_blocks,
    )


def blockwise_attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    causal: bool,
    q_offset: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    skip_masked_blocks: bool = False,
) -> jnp.ndarray:
    """Reference online-softmax blockwise attention (plain autodiff).

    Numerically identical to blockwise_attention but keeps O(S^2/bk)
    residuals under autodiff — used only as the test oracle.
    """
    B, Sq0, H, hd = q.shape
    _, Skv0, KV, _ = k.shape
    rep = H // KV
    scale = 1.0 / (hd ** 0.5)
    bq = min(q_block, Sq0)
    bk = min(kv_block, Skv0)
    # pad sequences to block multiples; padded kv positions are masked out
    pq = -Sq0 % bq
    pkv = -Skv0 % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    Sq, Skv = Sq0 + pq, Skv0 + pkv
    nq, nk = Sq // bq, Skv // bk

    qr = q.reshape(B, Sq, KV, rep, hd).transpose(0, 2, 3, 1, 4)  # B,KV,rep,Sq,hd
    kr = k.transpose(0, 2, 1, 3)  # B,KV,Skv,hd
    vr = v.transpose(0, 2, 1, 3)

    def kv_body(carry, kj, qb, qpos):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kr, kj * bk, bk, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(vr, kj * bk, bk, axis=2)
        s = _block_scores(qb, kb, scale)  # (B,KV,rep,bq,bk) f32
        kpos = kj * bk + jnp.arange(bk)
        mask = kpos[None, :] < Skv0  # kv padding
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        else:
            mask = jnp.broadcast_to(mask, (bq, bk))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(vb.dtype), vb,
            (((4,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32,
        )  # (B,KV,rep,bq,hd)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    def q_body(qi, nk_for_qi):
        qb = jax.lax.dynamic_slice_in_dim(qr, qi * bq, bq, axis=3)
        qpos = q_offset + qi * bq + jnp.arange(bq)
        m0 = jnp.full((B, KV, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            lambda c, kj: kv_body(c, kj, qb, qpos),
            (m0, l0, a0),
            jnp.arange(nk_for_qi),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B,KV,rep,bq,hd)

    if skip_masked_blocks and causal and q_offset == 0 and Sq == Skv:
        # optimized: q-block i only visits kv blocks [0 .. i*bq//bk]
        outs = []
        for qi in range(nq):
            hi = min(nk, (qi + 1) * bq // bk + (1 if ((qi + 1) * bq) % bk else 0))
            outs.append(q_body(qi, max(hi, 1)))
        out = jnp.concatenate(outs, axis=3)
    else:
        def scan_q(_, qi):
            return None, q_body(qi, nk)

        _, out_blocks = jax.lax.scan(scan_q, None, jnp.arange(nq))
        # (nq, B, KV, rep, bq, hd) -> (B, KV, rep, Sq, hd)
        out = jnp.moveaxis(out_blocks, 0, 3).reshape(B, KV, rep, Sq, hd)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out[:, :Sq0].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,  # (B, S, KV, hd)
    pos: jnp.ndarray,  # scalar int32: current position (attend to <= pos)
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    scale = 1.0 / (hd ** 0.5)
    qr = q.reshape(B, KV, rep, hd)
    s = jnp.einsum(
        "bgrh,bsgh->bgrs", qr.astype(jnp.float32),
        k_cache.astype(jnp.float32),
    ) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgh->bgrh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_apply(x: jnp.ndarray, p: dict, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        gate = x @ p["w_gate"]
        up = x @ p["w_in"]
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        return h @ p["w_out"]
    if kind == "gelu":
        h = jax.nn.gelu((x @ p["w_in"]).astype(jnp.float32)).astype(x.dtype)
        return h @ p["w_out"]
    raise ValueError(kind)


def mlp_init(rng, d: int, f: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / f) ** 0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (f, d)) * s_out).astype(dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * s_in).astype(dtype)
    return p


# --------------------------------------------------------------------------
# attention parameter block
# --------------------------------------------------------------------------


def attn_init(rng, d: int, n_heads: int, n_kv: int, head_dim: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = (1.0 / d) ** 0.5
    return {
        "wq": (jax.random.normal(k1, (d, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, n_kv * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, n_kv * head_dim)) * s).astype(dtype),
        "wo": (
            jax.random.normal(k4, (n_heads * head_dim, d)) * s
        ).astype(dtype),
    }


def attn_qkv(x: jnp.ndarray, p: dict, n_heads: int, n_kv: int, head_dim: int):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv, head_dim)
    return q, k, v
