"""The paper's own experimental configuration (Section 5): defaults for
the DMMC pipeline — Wikipedia-like (transversal, GloVe-25d) and
Songs-like (partition, sparse-5000d) workloads."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DMMCConfig:
    name: str
    n: int
    dim: int
    matroid: str  # partition | transversal
    num_categories: int
    gamma: int
    rank: int
    metric: str = "cosine"


WIKIPEDIA = DMMCConfig(
    name="wikipedia-sim", n=5_886_692, dim=25, matroid="transversal",
    num_categories=100, gamma=3, rank=100,
)
SONGS = DMMCConfig(
    name="songs-sim", n=237_698, dim=5000, matroid="partition",
    num_categories=16, gamma=1, rank=89,
)
