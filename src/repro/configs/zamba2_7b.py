"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81 Mamba2 layers, d_model=3584, shared attn block (32H MHA, d_ff=14336)
applied after every 6th mamba layer (13 applications of ONE weight set),
ssm_state=64, vocab=32000. [arXiv:2411.15242; unverified]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, subquadratic=True, rope_theta=10000.0,
)
