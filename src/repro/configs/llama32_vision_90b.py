"""llama-3.2-vision-90b [vlm]: text backbone with cross-attn image layers.

100L total = 80 self-attn + 20 cross-attn (every 5th), d_model=8192,
64H GQA kv=8, d_ff=28672, vocab=128256. Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings (B, 1024, d).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv=8, d_ff=28672,
    vocab=128256, cross_attn_every=5, n_img_tokens=1024,
    rope_theta=500000.0,
)
