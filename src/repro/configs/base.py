"""Architecture + shape configuration schema.

One ``ArchConfig`` per assigned architecture (src/repro/configs/<id>.py) with
the exact published dimensions, plus ``reduced()`` for the CPU smoke tests.
The four assignment shapes are fixed here; ``long_500k`` only applies to
sub-quadratic (SSM/hybrid) architectures — DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp: str = "swiglu"  # swiglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # every Nth layer is MoE (1 = all)
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    d_conv: int = 4
    shared_attn_every: int = 0  # hybrid: shared attn block after every N mamba
    # VLM
    cross_attn_every: int = 0  # 0 = no cross attention
    n_img_tokens: int = 0
    # misc
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    subquadratic: bool = False  # eligible for long_500k
    dtype: str = "bfloat16"
    ssd_chunk: int = 256
    q_block: int = 512
    kv_block: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows padded to a multiple of 64 so the vocab dim
        shards evenly on the model axis (49155 -> 49216, 50280 -> 50304).
        Padding rows are ordinary never-targeted classes (standard practice;
        DESIGN.md §8)."""
        return -(-self.vocab // 64) * 64

    def reduced(self) -> "ArchConfig":
        """Same family/topology, laptop-sized — used by the smoke tests."""
        scale = {
            "n_layers": min(self.n_layers, 4),
            "d_model": 64,
            "n_heads": 4,
            "n_kv": max(1, min(self.n_kv, 2)) if self.n_kv else 0,
            "d_ff": 128,
            "vocab": 128,
            "head_dim": 16,
            "ssd_chunk": 16,
            "q_block": 16,
            "kv_block": 16,
        }
        if self.family in ("ssm", "hybrid"):
            scale.update(ssm_state=8, ssm_head_dim=16)
            if self.family == "hybrid":
                scale.update(n_layers=5, shared_attn_every=2)
        if self.n_experts:
            # dropless capacity in the reduced configs so the decode path is
            # bit-consistent with the full forward (capacity drops are a
            # known train/serve divergence of capacity-based MoE routing)
            scale.update(n_experts=4, top_k=min(self.top_k, 2),
                         capacity_factor=4.0)
        if self.cross_attn_every:
            scale.update(n_layers=4, cross_attn_every=2, n_img_tokens=8)
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic context handling (DESIGN.md §7)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True
