"""Config registry: one module per assigned architecture + the paper's own
experimental config. ``get_config(arch_id)`` resolves --arch flags."""
from . import (
    command_r_35b,
    granite3_8b,
    llama32_vision_90b,
    llama4_maverick_400b,
    mamba2_27b,
    musicgen_medium,
    phi3_mini_38b,
    phi35_moe_42b,
    smollm_135m,
    zamba2_7b,
)
from .base import SHAPES, ArchConfig, ShapeConfig, shape_applicable

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        zamba2_7b, llama32_vision_90b, granite3_8b, smollm_135m,
        phi3_mini_38b, command_r_35b, musicgen_medium, phi35_moe_42b,
        llama4_maverick_400b, mamba2_27b,
    )
}
# short aliases for --arch
ALIASES = {
    "zamba2-7b": "zamba2-7b",
    "llama-3.2-vision-90b": "llama-3.2-vision-90b",
    "granite-3-8b": "granite-3-8b",
    "smollm-135m": "smollm-135m",
    "phi3-mini-3.8b": "phi3-mini-3.8b",
    "command-r-35b": "command-r-35b",
    "musicgen-medium": "musicgen-medium",
    "phi3.5-moe-42b-a6.6b": "phi3.5-moe-42b-a6.6b",
    "llama4-maverick-400b-a17b": "llama4-maverick-400b-a17b",
    "mamba2-2.7b": "mamba2-2.7b",
}


def get_config(name: str) -> ArchConfig:
    return REGISTRY[ALIASES.get(name, name)]


ARCH_IDS = sorted(REGISTRY)

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "REGISTRY", "get_config", "ARCH_IDS",
]
