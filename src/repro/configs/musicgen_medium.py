"""musicgen-medium [audio]: decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (kv=24 -> MHA) d_ff=6144 vocab=2048, GELU MLP.
The EnCodec frontend is a STUB: the backbone consumes the flattened
audio-token stream; input_specs() provides token ids over the 2048-entry
codebook. [arXiv:2306.05284; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=6144,
    vocab=2048, mlp="gelu", rope_theta=10000.0,
)
