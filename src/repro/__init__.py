"""repro: coreset-based diversity maximization under matroid constraints
(Ceccarello, Pietracaprina, Pucci — 2020) as a multi-pod JAX framework."""
__version__ = "1.0.0"
