"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: use the Pallas TPU kernels when running on TPU; otherwise
fall back to the jnp oracles in ``ref.py`` (identical semantics — the kernel
tests assert allclose between the two across shape/dtype sweeps, running the
Pallas path in interpret mode on CPU).

``force`` lets tests/benchmarks pin a path: "pallas" | "ref" | "interpret".
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash as _flash
from . import gmm_step as _gmm_step
from . import pdist as _pdist
from . import ref as _ref
from . import ssd as _ssd

_FORCE = os.environ.get("REPRO_KERNEL_BACKEND", "")  # "", "pallas", "ref", "interpret"


def _mode(force: Optional[str]) -> str:
    f = force or _FORCE
    if f:
        return f
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pairwise_sqdist(x, y, *, force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.pairwise_sqdist(x, y)
    return _pdist.pairwise_sqdist(x, y, interpret=(m == "interpret"))


def pairwise_dist(x, y, *, force: Optional[str] = None):
    return jnp.sqrt(pairwise_sqdist(x, y, force=force))


_F32_MAX = jnp.float32(jnp.finfo(jnp.float32).max)


def block_center_dists(block, centers, cvalid, *, force: Optional[str] = None):
    """Fused block-of-points x center-buffer distances for the blocked scan.

    (B, d), (T, d), (T,) -> ((B, T) Euclidean distances with invalid centers
    masked to float32 max, scalar error margin).

    The ref path reproduces ``core.streaming._dists_to_centers`` bit for bit
    (broadcast diff / square / sum / sqrt, so the blocked scan's precheck is
    *exactly* the per-point arithmetic) and reports margin 0. The Pallas path
    routes through the matmul-form pdist kernel, whose cancellation error is
    bounded by the returned margin — callers must treat any comparison that
    lands within the margin as undecided and fall back to the exact path.
    """
    m = _mode(force)
    if m == "ref":
        diff = centers[None, :, :] - block[:, None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        d = jnp.sqrt(jnp.maximum(d2, 0.0))
        margin = jnp.float32(0.0)
    else:
        d2 = _pdist.pairwise_sqdist(
            block, centers, interpret=(m == "interpret")
        )
        d = jnp.sqrt(d2)
        # matmul-form ||x||^2+||y||^2-2x.y loses ~eps * (||x||^2+||y||^2)
        # to cancellation; bound it by the largest operand norms in play.
        scale = jnp.max(jnp.sum(block * block, axis=-1)) + jnp.max(
            jnp.where(cvalid, jnp.sum(centers * centers, axis=-1), 0.0)
        )
        margin = jnp.sqrt(jnp.float32(1e-5) * jnp.maximum(scale, 1e-12))
    return jnp.where(cvalid[None, :], d, _F32_MAX), margin


def gmm_update(x, z, min_dist, valid, *, force: Optional[str] = None):
    """Fused GMM step: (new_min, far_idx, far_val). See kernels/gmm_step.py."""
    m = _mode(force)
    if m == "ref":
        return _ref.gmm_update(x, z, min_dist, valid)
    return _gmm_step.gmm_update(
        x, z, min_dist, valid, interpret=(m == "interpret")
    )


def ssd_intra_chunk(xbar, loga, B, C, *, force: Optional[str] = None):
    """Batched SSD intra-chunk. xbar: (g, q, p), loga: (g, q), B/C: (g, q, n).

    Returns (y_intra (g,q,p), state (g,n,p), decay_from_start (g,q),
    total_decay (g,)).
    """
    m = _mode(force)
    if m == "ref":
        y, s, dfs, td = jax.vmap(_ref.ssd_intra_chunk)(xbar, loga, B, C)
        return y, s, dfs, td
    y, s = _ssd.ssd_intra_chunk_batched(
        xbar, loga, B, C, interpret=(m == "interpret")
    )
    cum = jnp.cumsum(loga.astype(jnp.float32), axis=-1)
    return y, s, jnp.exp(cum), jnp.exp(cum[:, -1])


def flash_attention_fwd(q, k, v, *, causal=True, q_block=512, kv_block=1024,
                        force: Optional[str] = None):
    """Fused flash-attention forward. q/k/v: (BH, S, hd), heads flattened."""
    m = _mode(force)
    if m == "ref":
        return _ref.flash_attention_fwd(q, k, v, causal=causal)
    return _flash.flash_attention_fwd(
        q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
        interpret=(m == "interpret"),
    )
