"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: use the Pallas TPU kernels when running on TPU; otherwise
fall back to the jnp oracles in ``ref.py`` (identical semantics — the kernel
tests assert allclose between the two across shape/dtype sweeps, running the
Pallas path in interpret mode on CPU).

``force`` lets tests/benchmarks pin a path: "pallas" | "ref" | "interpret".
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash as _flash
from . import gmm_step as _gmm_step
from . import pdist as _pdist
from . import ref as _ref
from . import ssd as _ssd

_FORCE = os.environ.get("REPRO_KERNEL_BACKEND", "")  # "", "pallas", "ref", "interpret"


def _mode(force: Optional[str]) -> str:
    f = force or _FORCE
    if f:
        return f
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pairwise_sqdist(x, y, *, force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.pairwise_sqdist(x, y)
    return _pdist.pairwise_sqdist(x, y, interpret=(m == "interpret"))


def pairwise_dist(x, y, *, force: Optional[str] = None):
    return jnp.sqrt(pairwise_sqdist(x, y, force=force))


def gmm_update(x, z, min_dist, valid, *, force: Optional[str] = None):
    """Fused GMM step: (new_min, far_idx, far_val). See kernels/gmm_step.py."""
    m = _mode(force)
    if m == "ref":
        return _ref.gmm_update(x, z, min_dist, valid)
    return _gmm_step.gmm_update(
        x, z, min_dist, valid, interpret=(m == "interpret")
    )


def ssd_intra_chunk(xbar, loga, B, C, *, force: Optional[str] = None):
    """Batched SSD intra-chunk. xbar: (g, q, p), loga: (g, q), B/C: (g, q, n).

    Returns (y_intra (g,q,p), state (g,n,p), decay_from_start (g,q),
    total_decay (g,)).
    """
    m = _mode(force)
    if m == "ref":
        y, s, dfs, td = jax.vmap(_ref.ssd_intra_chunk)(xbar, loga, B, C)
        return y, s, dfs, td
    y, s = _ssd.ssd_intra_chunk_batched(
        xbar, loga, B, C, interpret=(m == "interpret")
    )
    cum = jnp.cumsum(loga.astype(jnp.float32), axis=-1)
    return y, s, jnp.exp(cum), jnp.exp(cum[:, -1])


def flash_attention_fwd(q, k, v, *, causal=True, q_block=512, kv_block=1024,
                        force: Optional[str] = None):
    """Fused flash-attention forward. q/k/v: (BH, S, hd), heads flattened."""
    m = _mode(force)
    if m == "ref":
        return _ref.flash_attention_fwd(q, k, v, causal=causal)
    return _flash.flash_attention_fwd(
        q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
        interpret=(m == "interpret"),
    )
