"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: use the Pallas TPU kernels when running on TPU; otherwise
fall back to the jnp oracles in ``ref.py`` (identical semantics — the kernel
tests assert allclose between the two across shape/dtype sweeps, running the
Pallas path in interpret mode on CPU).

``force`` lets tests/benchmarks pin a path: "pallas" | "ref" | "interpret".
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash as _flash
from . import gmm_step as _gmm_step
from . import pdist as _pdist
from . import precheck as _precheck
from . import ref as _ref
from . import ssd as _ssd

_FORCE = os.environ.get("REPRO_KERNEL_BACKEND", "")  # "", "pallas", "ref", "interpret"


def _mode(force: Optional[str]) -> str:
    f = force or _FORCE
    if f == "matmul":
        # only center_precheck has a distinct matmul-form path (it handles
        # the knob before reaching here); for every other op the jnp
        # reference IS the matmul-free/CPU path
        return "ref"
    if f:
        return f
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pairwise_sqdist(x, y, *, force: Optional[str] = None):
    m = _mode(force)
    with jax.named_scope("kernels/pairwise_sqdist"):
        if m == "ref":
            return _ref.pairwise_sqdist(x, y)
        return _pdist.pairwise_sqdist(x, y, interpret=(m == "interpret"))


def pairwise_dist(x, y, *, force: Optional[str] = None):
    return jnp.sqrt(pairwise_sqdist(x, y, force=force))


def _pdist_e2(block, centers, cvalid, *, per_row: bool = False):
    """Squared-space error bound of the matmul-form ||x||^2+||y||^2-2x.y
    distances: cancellation loses ~eps * (||x||^2+||y||^2); bound it by the
    operand norms in play — per block-row when ``per_row`` (each point's
    own norm against the largest center norm: tighter, so fewer borderline
    points hit the exact fallback), the block-global max otherwise."""
    xnorm = jnp.sum(block * block, axis=-1)
    if not per_row:
        xnorm = jnp.max(xnorm)
    scale = xnorm + jnp.max(
        jnp.where(cvalid, jnp.sum(centers * centers, axis=-1), 0.0)
    )
    return jnp.float32(1e-5) * jnp.maximum(scale, 1e-12)


def center_precheck(block, centers, cvalid, *, force: Optional[str] = None):
    """Fused blocked-scan precheck: distance-to-centers + top-3 nearest
    classification in one op.

    (B, d), (T, d), (T,) -> (dmin (B,), z (B,) int32, second (B,),
    z2 (B,) int32, third (B,), error margin — (B,) per-row, or scalar 0 on
    the exact path). ``dmin``/``second``/``third`` are Euclidean distances
    to the nearest/second/third *valid* centers (float32 max when masked),
    ``z``/``z2`` the two nearest indices with ``jnp.argmin`` tie-breaking.
    The caller exact-refines the two candidate centers (a (B, 2, d) gather
    is cheap; the (B, T, d) pass is not) and uses ``third`` + margin to
    decide whether the candidate pair certainly contains the true nearest.

    Four paths: ``ref`` (exact broadcast arithmetic, margin 0 — the bit
    oracle), ``matmul`` (jnp matmul-form, the non-TPU default: the blocked
    scan's hot loop shouldn't materialize a (B, T, d) diff tensor per
    iteration), and ``pallas``/``interpret`` (the fused Pallas kernel,
    panel matmul + in-register top-3 reduction so the (B, T) matrix never
    leaves VMEM). All matmul-form paths report the cancellation margin;
    the scan replays anything within it through the exact per-point step,
    so every path yields bit-identical scan states.
    """
    f = force or _FORCE
    m = f if f else ("pallas" if jax.default_backend() == "tpu" else "matmul")
    if m == "ref":
        with jax.named_scope("kernels/center_precheck"):
            dmin, z, second, z2, third = _ref.center_precheck(
                block, centers, cvalid
            )
            return dmin, z, second, z2, third, jnp.float32(0.0)
    with jax.named_scope("kernels/center_precheck"):
        if m == "matmul":
            dmin, z, second, z2, third = _ref.center_precheck_matmul(
                block, centers, cvalid
            )
        else:
            dmin, z, second, z2, third = _precheck.center_precheck_stats(
                block, centers, cvalid, interpret=(m == "interpret")
            )
    # distance-space error bound from the squared-space cancellation bound
    # e2: |sqrt(a) - sqrt(b)| = |a - b| / (sqrt(a) + sqrt(b)), and every
    # center the tie test compares sits at d_mm >= dmin — so e2 / dmin
    # bounds the error, falling back to sqrt(e2) (the d ~ 0 worst case)
    # when dmin is tiny. ~10-30x tighter than sqrt(e2) alone at real
    # cluster distances, which is what keeps margin-fallback replays rare.
    e2 = _pdist_e2(block, centers, cvalid, per_row=True)
    margin = e2 / jnp.maximum(dmin, jnp.sqrt(e2))
    return dmin, z, second, z2, third, margin


def gmm_update(x, z, min_dist, valid, *, force: Optional[str] = None):
    """Fused GMM step: (new_min, far_idx, far_val). See kernels/gmm_step.py."""
    m = _mode(force)
    if m == "ref":
        return _ref.gmm_update(x, z, min_dist, valid)
    return _gmm_step.gmm_update(
        x, z, min_dist, valid, interpret=(m == "interpret")
    )


def ssd_intra_chunk(xbar, loga, B, C, *, force: Optional[str] = None):
    """Batched SSD intra-chunk. xbar: (g, q, p), loga: (g, q), B/C: (g, q, n).

    Returns (y_intra (g,q,p), state (g,n,p), decay_from_start (g,q),
    total_decay (g,)).
    """
    m = _mode(force)
    if m == "ref":
        y, s, dfs, td = jax.vmap(_ref.ssd_intra_chunk)(xbar, loga, B, C)
        return y, s, dfs, td
    y, s = _ssd.ssd_intra_chunk_batched(
        xbar, loga, B, C, interpret=(m == "interpret")
    )
    cum = jnp.cumsum(loga.astype(jnp.float32), axis=-1)
    return y, s, jnp.exp(cum), jnp.exp(cum[:, -1])


def flash_attention_fwd(q, k, v, *, causal=True, q_block=512, kv_block=1024,
                        force: Optional[str] = None):
    """Fused flash-attention forward. q/k/v: (BH, S, hd), heads flattened."""
    m = _mode(force)
    if m == "ref":
        return _ref.flash_attention_fwd(q, k, v, causal=causal)
    return _flash.flash_attention_fwd(
        q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
        interpret=(m == "interpret"),
    )
