"""Fused GMM farthest-point step as a Pallas kernel (TPU).

One GMM iteration reads the point matrix once: for each (bn, d) VMEM panel it
computes the distance of each row to the new center z, folds it into the
running min-distance vector, and emits the per-block max/argmax of the
updated min-distances (the candidate next center). The tiny (gn,) block
reductions are finished on the host side of the op (ops.gmm_update).

Without fusion this is three HBM passes over (n,)-vectors plus one over
(n, d); fused it is a single pass over (n, d) — the GMM loop is memory-bound
at large n, so this is the paper's O(n tau) distance-oracle loop at roofline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, z_ref, md_ref, v_ref, nm_ref, bv_ref, bi_ref):
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    z = z_ref[...].astype(jnp.float32)  # (1, d)
    md = md_ref[...]  # (bn, 1) f32
    valid = v_ref[...] != 0  # (bn, 1)
    diff = x - z
    d2 = jnp.sum(diff * diff, axis=1, keepdims=True)  # (bn, 1)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    nm = jnp.minimum(md, dist)
    nm_ref[...] = nm
    masked = jnp.where(valid, nm, -1.0)  # (bn, 1)
    bn = masked.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    best = jnp.max(masked)
    # first index attaining the max (deterministic tie-break)
    at = jnp.where(masked == best, iota, bn)
    arg = jnp.min(at)
    bv_ref[0, 0] = best
    bi_ref[0, 0] = arg.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gmm_update(
    x: jnp.ndarray,  # (n, d)
    z: jnp.ndarray,  # (d,)
    min_dist: jnp.ndarray,  # (n,) f32
    valid: jnp.ndarray,  # (n,) bool
    *,
    block_n: int = 1024,
    interpret: bool = False,
):
    """Returns (new_min (n,) f32, far_idx int32, far_val f32)."""
    n, d = x.shape
    bn = min(block_n, max(8, n))
    pn = -n % bn
    xp = jnp.pad(x, ((0, pn), (0, 0)))
    mdp = jnp.pad(min_dist.astype(jnp.float32), (0, pn))[:, None]
    vp = jnp.pad(valid.astype(jnp.int32), (0, pn))[:, None]
    gn = xp.shape[0] // bn
    nm, bv, bi = pl.pallas_call(
        _gmm_kernel,
        grid=(gn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((gn, 1), jnp.float32),
            jax.ShapeDtypeStruct((gn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(xp, z[None, :], mdp, vp)
    new_min = nm[:n, 0]
    blk = jnp.argmax(bv[:, 0])
    far_val = bv[blk, 0]
    far_idx = (blk * bn + bi[blk, 0]).astype(jnp.int32)
    return new_min, far_idx, far_val
