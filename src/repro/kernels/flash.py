"""Flash-attention forward Pallas kernel (TPU).

One (batch*head, q-block) cell keeps an (bq, hd) f32 accumulator plus
(bq,) running max/denominator in VMEM scratch while the sequential third
grid axis streams kv blocks through VMEM. This is the fused form of
models/attention.py's forward: on TPU it collapses the ~8 HLO elementwise
passes per block (mask/max/sub/exp/mul/add/...) into the matmul pipeline —
the dominant contributor to the memory roofline term of the dense
train/prefill cells (EXPERIMENTS §Roofline calibration note 4).

Layout: q/k/v pre-flattened to (BH, S, hd) with heads already expanded
(GQA rep applied by the caller, matching models/common.attn path).
VMEM per step: bq*hd + 2*bk*hd + bq*bk + scratch ≈ (512+2*1024)*128*4
+ 512*1024*4 ≈ 3.4 MiB at the default blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                      bq, bk, nk, causal, skv_real, scale):
    kj = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < skv_real
    if causal:
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_sc[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_block", "kv_block", "interpret"),
)
def flash_attention_fwd(
    q: jnp.ndarray,  # (BH, Sq, hd) heads pre-expanded/flattened
    k: jnp.ndarray,  # (BH, Skv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq0, hd = q.shape
    skv0 = k.shape[1]
    bq = min(q_block, sq0)
    bk = min(kv_block, skv0)
    pq = -sq0 % bq
    pk = -skv0 % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    sq, skv = sq0 + pq, skv0 + pk
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (hd ** 0.5)

    out = pl.pallas_call(
        functools.partial(
            _flash_fwd_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
            skv_real=skv0, scale=scale,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq0]


# ---------------------------------------------------------------------------
# backward kernels: dq (grid over q blocks) and dk/dv (grid over kv blocks),
# both recomputing probability blocks from (q, k, lse) — O(S*hd) residency.
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                         dq_ref, dq_sc, *, bq, bk, nk, causal, skv_real,
                         scale):
    kj = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        dq_sc[...] = jnp.zeros_like(dq_sc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, 0].astype(jnp.float32)  # (bq,)
    dsum = dsum_ref[0][:, 0].astype(jnp.float32)  # (bq,)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < skv_real
    if causal:
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)
    ds = p * (dp - dsum[:, None]) * scale
    dq_sc[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(kj == nk - 1)
    def _finalize():
        dq_ref[0] = dq_sc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                          dk_ref, dv_ref, dk_sc, dv_sc, *, bq, bk, nq,
                          causal, skv_real, scale):
    qi = pl.program_id(2)
    kj = pl.program_id(1)

    @pl.when(qi == 0)
    def _init():
        dk_sc[...] = jnp.zeros_like(dk_sc)
        dv_sc[...] = jnp.zeros_like(dv_sc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0][:, 0].astype(jnp.float32)
    dsum = dsum_ref[0][:, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < skv_real
    if causal:
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])  # (bq, bk)
    dv_sc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bk, hd)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - dsum[:, None]) * scale
    dk_sc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bk, hd)

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0] = dk_sc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_sc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_block", "kv_block", "interpret"),
)
def flash_attention_bwd(
    q, k, v, o, lse, do,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
    interpret: bool = False,
):
    """Returns (dq, dk, dv). q/k/v/o/do: (BH, S, hd); lse: (BH, Sq)."""
    bh, sq0, hd = q.shape
    skv0 = k.shape[1]
    bq = min(q_block, sq0)
    bk = min(kv_block, skv0)
    pq = -sq0 % bq
    pk = -skv0 % bk
    dsum = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    )  # (BH, Sq)
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
        do = jnp.pad(do, ((0, 0), (0, pq), (0, 0)))
        lse = jnp.pad(lse, ((0, 0), (0, pq)), constant_values=1.0)
        dsum = jnp.pad(dsum, ((0, 0), (0, pq)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    sq, skv = sq0 + pq, skv0 + pk
    nq, nk = sq // bq, skv // bk
    scale = 1.0 / (hd ** 0.5)
    lse2 = lse[..., None]  # (BH, Sq, 1) — TPU-friendly 2D blocks
    dsum2 = dsum[..., None]

    dq = pl.pallas_call(
        functools.partial(
            _flash_bwd_dq_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
            skv_real=skv0, scale=scale,
        ),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, do, lse2, dsum2)

    dk, dv = pl.pallas_call(
        functools.partial(
            _flash_bwd_dkv_kernel, bq=bq, bk=bk, nq=nq, causal=causal,
            skv_real=skv0, scale=scale,
        ),
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, j, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, skv, hd), k.dtype),
            jax.ShapeDtypeStruct((bh, skv, hd), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, hd), jnp.float32),
            pltpu.VMEM((bk, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, do, lse2, dsum2)
    return dq[:, :sq0], dk[:, :skv0], dv[:, :skv0]
