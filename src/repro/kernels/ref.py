"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert against, and also the
dispatch target of ``ops`` on non-TPU backends (XLA:CPU fuses them well
enough for the CPU test/bench environment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# pdist
# --------------------------------------------------------------------------


def pairwise_sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(n, d), (m, d) -> (n, m) squared Euclidean distances, f32 accumulate."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xn = jnp.sum(x * x, axis=-1)
    yn = jnp.sum(y * y, axis=-1)
    d2 = xn[:, None] + yn[None, :] - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


# --------------------------------------------------------------------------
# gmm_step: fused distance-to-center + running-min + global argmax
# --------------------------------------------------------------------------


def gmm_update(
    x: jnp.ndarray,  # (n, d)
    z: jnp.ndarray,  # (d,)
    min_dist: jnp.ndarray,  # (n,)
    valid: jnp.ndarray,  # (n,) bool
):
    """Returns (new_min (n,), far_idx int32, far_val f32).

    new_min[i] = min(min_dist[i], d(x_i, z)); far = argmax over valid points
    of new_min (the next GMM center and the current clustering radius).
    """
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    diff = x - z[None, :]
    d = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    new_min = jnp.minimum(min_dist, d)
    masked = jnp.where(valid, new_min, -1.0)
    far_idx = jnp.argmax(masked).astype(jnp.int32)
    far_val = masked[far_idx]
    return new_min, far_idx, far_val


# --------------------------------------------------------------------------
# ssd: Mamba2 intra-chunk state-space-duality block
# --------------------------------------------------------------------------


def ssd_intra_chunk(
    xbar: jnp.ndarray,  # (q, p)   dt-scaled inputs for one (chunk, head)
    loga: jnp.ndarray,  # (q,)     log decay per step (= dt * A, A < 0)
    B: jnp.ndarray,  # (q, n)
    C: jnp.ndarray,  # (q, n)
):
    """Returns (y_intra (q, p), state (n, p), decay_from_start (q,),
    total_decay scalar).

    y_intra[t] = sum_{s<=t} exp(cum[t]-cum[s]) (C_t . B_s) xbar[s]
    state      = sum_s exp(cum[q-1]-cum[s]) B_s (x) xbar[s]   (n, p)
    """
    xbar = xbar.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    loga = loga.astype(jnp.float32)
    q = xbar.shape[0]
    cum = jnp.cumsum(loga)
    # L[t, s] = exp(cum[t] - cum[s]) for s <= t else 0
    diff = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    G = C @ B.T  # (q, q)
    y_intra = (G * L) @ xbar  # (q, p)
    decay_to_end = jnp.exp(cum[-1] - cum)  # (q,)
    state = (B * decay_to_end[:, None]).T @ xbar  # (n, p)
    decay_from_start = jnp.exp(cum)  # (q,) prod_{r<=t} a_r
    return y_intra, state, decay_from_start, jnp.exp(cum[-1])


def ssd_reference_scan(
    xbar: jnp.ndarray,  # (l, p)
    loga: jnp.ndarray,  # (l,)
    B: jnp.ndarray,  # (l, n)
    C: jnp.ndarray,  # (l, n)
    s0: jnp.ndarray | None = None,  # (n, p)
):
    """Step-by-step recurrent oracle: the ground truth for SSD.

    s_t = a_t s_{t-1} + B_t (x) xbar_t ; y_t = C_t @ s_t
    """
    l, p = xbar.shape
    n = B.shape[1]
    if s0 is None:
        s0 = jnp.zeros((n, p), jnp.float32)

    def step(s, inp):
        xb, la, b, c = inp
        s = jnp.exp(la) * s + b[:, None] * xb[None, :]
        y = c @ s
        return s, y

    s_fin, ys = jax.lax.scan(
        step,
        s0.astype(jnp.float32),
        (
            xbar.astype(jnp.float32),
            loga.astype(jnp.float32),
            B.astype(jnp.float32),
            C.astype(jnp.float32),
        ),
    )
    return ys, s_fin


# --------------------------------------------------------------------------
# flash forward (dense oracle)
# --------------------------------------------------------------------------


def flash_attention_fwd(q, k, v, causal=True):
    """(BH, Sq, hd) x (BH, Skv, hd) dense-softmax oracle."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q, k) / (hd ** 0.5)
    if causal:
        m = jnp.arange(q.shape[1])[:, None] >= jnp.arange(k.shape[1])[None]
        s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v)
