"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert against, and also the
dispatch target of ``ops`` on non-TPU backends (XLA:CPU fuses them well
enough for the CPU test/bench environment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# pdist
# --------------------------------------------------------------------------


def pairwise_sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(n, d), (m, d) -> (n, m) squared Euclidean distances, f32 accumulate."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xn = jnp.sum(x * x, axis=-1)
    yn = jnp.sum(y * y, axis=-1)
    d2 = xn[:, None] + yn[None, :] - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


_F32_MAX = jnp.float32(jnp.finfo(jnp.float32).max)


def _nearest_stats(
    d: jnp.ndarray,  # (B, T) masked distances
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(dmin, z, second, z2, third) row reduction shared by both precheck
    oracles: the three smallest distances and the indices of the two
    smallest (first-index tie-breaking, like ``jnp.argmin``).

    min-over-iota instead of argmin + one_hot re-masking: same results
    (first column attaining the row min == argmin's tie rule), ~40% fewer
    passes over the (B, T) tile — this reduction runs on every block of the
    ingest hot path, and the Pallas kernel uses the identical formulation.
    """
    tcap = d.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    dmin = jnp.min(d, axis=1, keepdims=True)
    z = jnp.min(
        jnp.where(d == dmin, cols, jnp.int32(tcap)), axis=1, keepdims=True
    )
    d_noz = jnp.where(cols == z, _F32_MAX, d)
    second = jnp.min(d_noz, axis=1, keepdims=True)
    z2 = jnp.min(
        jnp.where(d_noz == second, cols, jnp.int32(tcap)), axis=1,
        keepdims=True,
    )
    third = jnp.min(jnp.where(cols == z2, _F32_MAX, d_noz), axis=1)
    return dmin[:, 0], z[:, 0], second[:, 0], z2[:, 0], third


def center_precheck(
    block: jnp.ndarray,  # (B, d)
    centers: jnp.ndarray,  # (T, d)
    cvalid: jnp.ndarray,  # (T,) bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(dmin, z, second, z2, third) nearest-center classification for the
    streaming blocked scan — exact oracle.

    Reproduces ``core.streaming._dists_to_centers`` bit for bit per point
    (broadcast diff / square / sum / sqrt, invalid centers at float32 max),
    then the exact min/argmin/one-hot-excluded-second glue the scan
    historically ran on the full distance matrix — so the blocked scan's
    precheck is *exactly* the per-point arithmetic on this path (margin 0).
    """
    diff = centers[None, :, :] - block[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    return _nearest_stats(jnp.where(cvalid[None, :], d, _F32_MAX))


def center_precheck_matmul(
    block: jnp.ndarray,  # (B, d)
    centers: jnp.ndarray,  # (T, d)
    cvalid: jnp.ndarray,  # (T,) bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Matmul-form precheck: ||x||^2 + ||c||^2 - 2 x.c through the BLAS
    panel instead of a materialized (B, T, d) broadcast-diff tensor — ~2-4x
    faster on CPU and the arithmetic twin of the Pallas kernel. Subject to
    the same cancellation error, so callers must pair it with the pdist
    margin (any comparison within the margin falls back to the exact
    per-point path; the blocked scan stays bit-identical by construction).
    """
    block = block.astype(jnp.float32)
    centers = centers.astype(jnp.float32)
    xn = jnp.sum(block * block, axis=1)
    cn = jnp.sum(centers * centers, axis=1)
    d2 = xn[:, None] + cn[None, :] - 2.0 * (block @ centers.T)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    return _nearest_stats(jnp.where(cvalid[None, :], d, _F32_MAX))


# --------------------------------------------------------------------------
# gmm_step: fused distance-to-center + running-min + global argmax
# --------------------------------------------------------------------------


def gmm_update(
    x: jnp.ndarray,  # (n, d)
    z: jnp.ndarray,  # (d,)
    min_dist: jnp.ndarray,  # (n,)
    valid: jnp.ndarray,  # (n,) bool
):
    """Returns (new_min (n,), far_idx int32, far_val f32).

    new_min[i] = min(min_dist[i], d(x_i, z)); far = argmax over valid points
    of new_min (the next GMM center and the current clustering radius).
    """
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    diff = x - z[None, :]
    d = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    new_min = jnp.minimum(min_dist, d)
    masked = jnp.where(valid, new_min, -1.0)
    far_idx = jnp.argmax(masked).astype(jnp.int32)
    far_val = masked[far_idx]
    return new_min, far_idx, far_val


# --------------------------------------------------------------------------
# ssd: Mamba2 intra-chunk state-space-duality block
# --------------------------------------------------------------------------


def ssd_intra_chunk(
    xbar: jnp.ndarray,  # (q, p)   dt-scaled inputs for one (chunk, head)
    loga: jnp.ndarray,  # (q,)     log decay per step (= dt * A, A < 0)
    B: jnp.ndarray,  # (q, n)
    C: jnp.ndarray,  # (q, n)
):
    """Returns (y_intra (q, p), state (n, p), decay_from_start (q,),
    total_decay scalar).

    y_intra[t] = sum_{s<=t} exp(cum[t]-cum[s]) (C_t . B_s) xbar[s]
    state      = sum_s exp(cum[q-1]-cum[s]) B_s (x) xbar[s]   (n, p)
    """
    xbar = xbar.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    loga = loga.astype(jnp.float32)
    q = xbar.shape[0]
    cum = jnp.cumsum(loga)
    # L[t, s] = exp(cum[t] - cum[s]) for s <= t else 0
    diff = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    G = C @ B.T  # (q, q)
    y_intra = (G * L) @ xbar  # (q, p)
    decay_to_end = jnp.exp(cum[-1] - cum)  # (q,)
    state = (B * decay_to_end[:, None]).T @ xbar  # (n, p)
    decay_from_start = jnp.exp(cum)  # (q,) prod_{r<=t} a_r
    return y_intra, state, decay_from_start, jnp.exp(cum[-1])


def ssd_reference_scan(
    xbar: jnp.ndarray,  # (l, p)
    loga: jnp.ndarray,  # (l,)
    B: jnp.ndarray,  # (l, n)
    C: jnp.ndarray,  # (l, n)
    s0: jnp.ndarray | None = None,  # (n, p)
):
    """Step-by-step recurrent oracle: the ground truth for SSD.

    s_t = a_t s_{t-1} + B_t (x) xbar_t ; y_t = C_t @ s_t
    """
    l, p = xbar.shape
    n = B.shape[1]
    if s0 is None:
        s0 = jnp.zeros((n, p), jnp.float32)

    def step(s, inp):
        xb, la, b, c = inp
        s = jnp.exp(la) * s + b[:, None] * xb[None, :]
        y = c @ s
        return s, y

    s_fin, ys = jax.lax.scan(
        step,
        s0.astype(jnp.float32),
        (
            xbar.astype(jnp.float32),
            loga.astype(jnp.float32),
            B.astype(jnp.float32),
            C.astype(jnp.float32),
        ),
    )
    return ys, s_fin


# --------------------------------------------------------------------------
# flash forward (dense oracle)
# --------------------------------------------------------------------------


def flash_attention_fwd(q, k, v, causal=True):
    """(BH, Sq, hd) x (BH, Skv, hd) dense-softmax oracle."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q, k) / (hd ** 0.5)
    if causal:
        m = jnp.arange(q.shape[1])[:, None] >= jnp.arange(k.shape[1])[None]
        s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v)
