"""Fused blocked-scan precheck Pallas kernel (TPU).

The streaming blocked scan classifies every point of a block against the
current center buffer: nearest-center distance, nearest-center index, and
second-nearest distance (for the near-tie fallback margin). Historically
this was ``pdist``'s (B, T) distance matrix followed by host-side jnp glue
(min / argmin / one-hot-masked second min); this kernel fuses the whole
classification into one pass so the (B, T) matrix never round-trips
through HBM.

Same panel-matmul structure as ``pdist.py``: grid (gB, gd), LHS point
panels (bB, bd) and the full (padded) center buffer (T_pad, bd) staged
through VMEM, a (bB, T_pad) f32 squared-distance accumulator revisited
across the sequential d axis. On the last d step the kernel reduces the
accumulator in-register: masked sqrt, row min, first-index argmin (iota +
min over matching columns — ``jnp.argmin``'s tie rule), and the min with
the argmin column excluded. Output is a (B, 128) stats tile (cols 0..2 =
dmin, second, z; the 128-lane width is the natural TPU tile — slicing a
(B, 3) result would pad to the same tile anyway).

The center buffer is small (tau+1 rows), so one T_pad-wide block per step
is the right shape: the reduction needs the full row, and T_pad=128 keeps
VMEM per step at bB*bd + T_pad*bd + bB*T_pad floats (< 1 MiB at defaults).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

# python literal (not a jnp scalar): pallas kernels must not close over
# traced array constants
_F32_MAX = float(jnp.finfo(jnp.float32).max)


def _precheck_kernel(x_ref, c_ref, m_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bB, bd)
    c = c_ref[...].astype(jnp.float32)  # (T_pad, bd)
    dot = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bB, T_pad)
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bB, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, T_pad)
    acc_ref[...] += xn + cn - 2.0 * dot

    @pl.when(k == nk - 1)
    def _reduce():
        d2 = jnp.maximum(acc_ref[...], 0.0)  # (bB, T_pad)
        d = jnp.sqrt(d2)
        valid = m_ref[0:1, :] > 0.0  # (1, T_pad); padded cols invalid
        d = jnp.where(valid, d, _F32_MAX)
        tpad = d.shape[1]
        dmin = jnp.min(d, axis=1, keepdims=True)  # (bB, 1)
        cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        z = jnp.min(
            jnp.where(d == dmin, cols, jnp.int32(tpad)), axis=1,
            keepdims=True,
        )  # first col attaining the min == jnp.argmin's tie rule
        d_noz = jnp.where(cols == z, _F32_MAX, d)
        second = jnp.min(d_noz, axis=1, keepdims=True)
        z2 = jnp.min(
            jnp.where(d_noz == second, cols, jnp.int32(tpad)), axis=1,
            keepdims=True,
        )
        third = jnp.min(
            jnp.where(cols == z2, _F32_MAX, d_noz), axis=1, keepdims=True
        )
        oc = jax.lax.broadcasted_iota(jnp.int32, o_ref.shape, 1)
        o_ref[...] = (
            jnp.where(oc == 0, dmin, 0.0)
            + jnp.where(oc == 1, second, 0.0)
            + jnp.where(oc == 2, z.astype(jnp.float32), 0.0)
            + jnp.where(oc == 3, z2.astype(jnp.float32), 0.0)
            + jnp.where(oc == 4, third, 0.0)
        )


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_d", "interpret")
)
def center_precheck_stats(
    block: jnp.ndarray,  # (B, d) points
    centers: jnp.ndarray,  # (T, d) center buffer
    cvalid: jnp.ndarray,  # (T,) bool
    *,
    block_b: int = 128,
    block_d: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(dmin, z, second, z2, third) nearest-center classification: the
    three smallest center distances per point and the indices of the two
    smallest, invalid centers masked to float32 max."""
    B, d = block.shape
    T, d2 = centers.shape
    assert d == d2, (block.shape, centers.shape)
    bB = min(block_b, max(8, B))
    bd = min(block_d, d)
    pB = -B % bB
    pT = -T % 128
    pd = -d % bd
    xp = jnp.pad(block, ((0, pB), (0, pd)))
    cp = jnp.pad(centers, ((0, pT), (0, pd)))
    tpad = cp.shape[0]
    # validity mask as an (8, T_pad) f32 plane: sublane-8 keeps the block
    # a whole min f32 tile; the kernel reads row 0
    mask = jnp.broadcast_to(
        jnp.pad(cvalid.astype(jnp.float32), (0, pT))[None, :], (8, tpad)
    )
    gB, gd = xp.shape[0] // bB, xp.shape[1] // bd
    out = pl.pallas_call(
        functools.partial(_precheck_kernel, nk=gd),
        grid=(gB, gd),
        in_specs=[
            pl.BlockSpec((bB, bd), lambda i, k: (i, k)),
            pl.BlockSpec((tpad, bd), lambda i, k: (0, k)),
            pl.BlockSpec((8, tpad), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bB, 128), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], 128), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bB, tpad), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, cp, mask)
    stats = out[:B]
    return (
        stats[:, 0],
        stats[:, 2].astype(jnp.int32),
        stats[:, 1],
        stats[:, 3].astype(jnp.int32),
        stats[:, 4],
    )
