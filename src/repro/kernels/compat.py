"""Version compatibility shims for the Pallas TPU API.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and the
installed version may carry either name). Every kernel in this package goes
through this shim instead of touching ``pltpu`` directly, so an upgrade of
the toolchain is a one-line change here rather than a sweep of the kernels.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
