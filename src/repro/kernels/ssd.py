"""Mamba2 SSD intra-chunk Pallas kernel (TPU).

Computes, for one (batch*chunk, head) grid cell with chunk length q, head dim
p, state dim n:

    y_intra = (C B^T (*) L) @ xbar          (q, p)   -- MXU matmuls
    state   = B^T diag(exp(cum[-1]-cum)) xbar  (n, p)

where L[t, s] = exp(cum[t] - cum[s]) for s <= t (the within-chunk decay),
cum = cumsum(loga). The inter-chunk recurrence (a length-(l/q) scan over
(n, p) states) is tiny and is done by the caller in plain JAX.

TPU adaptation: the Mamba2 paper phrases SSD so the inner work is matmuls —
exactly what the MXU wants. Block choice (q, n, p) = (128|256, 64|128, 64)
keeps all operands VMEM-resident: q*n*2 + q*p + q*q + n*p floats ~< 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(xbar_ref, loga_ref, b_ref, c_ref, y_ref, s_ref):
    xbar = xbar_ref[0].astype(jnp.float32)  # (q, p)
    loga = loga_ref[0].astype(jnp.float32)  # (q, 1) -> (q,)
    B = b_ref[0].astype(jnp.float32)  # (q, n)
    C = c_ref[0].astype(jnp.float32)  # (q, n)
    q = xbar.shape[0]
    cum = jnp.cumsum(loga[:, 0])  # (q,)
    diff = cum[:, None] - cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(row >= col, jnp.exp(diff), 0.0)
    G = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, q)
    y = jax.lax.dot_general(
        G * L, xbar, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, p)
    y_ref[0] = y
    decay_to_end = jnp.exp(cum[-1] - cum)  # (q,)
    Bw = B * decay_to_end[:, None]  # (q, n)
    state = jax.lax.dot_general(
        Bw, xbar, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (n, p)
    s_ref[0] = state


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_batched(
    xbar: jnp.ndarray,  # (g, q, p)  g = batch*chunks*heads flattened
    loga: jnp.ndarray,  # (g, q)
    B: jnp.ndarray,  # (g, q, n)
    C: jnp.ndarray,  # (g, q, n)
    *,
    interpret: bool = False,
):
    """Returns (y_intra (g, q, p), state (g, n, p))."""
    g, q, p = xbar.shape
    n = B.shape[-1]
    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, p), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, q, p), jnp.float32),
            jax.ShapeDtypeStruct((g, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xbar, loga[..., None], B, C)
    return y, s
