"""Blocked pairwise squared-distance Pallas kernel (TPU).

Computes D2[i, j] = ||x_i||^2 + ||y_j||^2 - 2 x_i . y_j with a 3-D grid
(gn, gm, gd): LHS/RHS panels of shape (bn, bd) / (bm, bd) are staged through
VMEM and a (bn, bm) f32 accumulator tile is revisited across the d-grid axis
(dimension_semantics: the d axis is 'arbitrary', i.e. sequential, so the
accumulation is well-defined).

Design notes (TPU):
* the dominant op is the (bn, bd) @ (bd, bm) panel matmul -> MXU;
  block sizes default to 256/256/512, all multiples of the 128 MXU tile;
* VMEM per step = bn*bd + bm*bd + bn*bm floats ~= (256*512*2 + 256*256)*4B
  ~= 1.3 MiB, comfortably under the ~16 MiB/core budget, leaving room for
  double-buffered prefetch of the next panels;
* norms are accumulated per d-tile alongside the dot product so the kernel
  makes exactly one pass over the operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _pdist_kernel(x_ref, y_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # (bn, bd)
    y = y_ref[...].astype(jnp.float32)  # (bm, bd)
    dot = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bn, bm)
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (bn, 1)
    yn = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, bm)
    o_ref[...] += xn + yn - 2.0 * dot


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_m", "block_d", "interpret")
)
def pairwise_sqdist(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    block_n: int = 256,
    block_m: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """(n, d), (m, d) -> (n, m) squared distances. Pads to block multiples."""
    n, d = x.shape
    m, d2 = y.shape
    assert d == d2, (x.shape, y.shape)
    bn = min(block_n, max(8, n))
    bm = min(block_m, max(8, m))
    bd = min(block_d, d)
    pn = -n % bn
    pm = -m % bm
    pd = -d % bd
    xp = jnp.pad(x, ((0, pn), (0, pd)))
    yp = jnp.pad(y, ((0, pm), (0, pd)))
    gn, gm, gd = xp.shape[0] // bn, yp.shape[0] // bm, xp.shape[1] // bd
    out = pl.pallas_call(
        _pdist_kernel,
        grid=(gn, gm, gd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, yp)
    return jnp.maximum(out[:n, :m], 0.0)
