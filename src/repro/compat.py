"""JAX version compatibility shims shared across the repo.

The installed JAX may predate two API moves used by the distributed paths:

* ``jax.shard_map`` (with ``check_vma``) vs the older
  ``jax.experimental.shard_map.shard_map`` (with ``check_rep``);
* ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)``
  (see ``launch.mesh.make_mesh`` for the mesh-side shim).

Pallas-specific shims live in ``kernels.compat``.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across the experimental->core promotion."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` (newer JAX) or the psum(1) equivalent."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)
