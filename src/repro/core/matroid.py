"""Matroid representations and oracles.

Two faces, one semantics:

* **Host oracles** (numpy): exact independence / rank / extend queries used by
  the final-stage solvers (local search, exhaustive search) which the paper
  runs on the *small* coreset. Transversal independence is decided exactly
  with Kuhn's augmenting-path maximum bipartite matching.

* **Vectorized jit-side helpers**: static-shape, mask-based routines used
  inside the (sharded, jit'd) coreset constructions, where every shape must
  be known at trace time. Partition-matroid extraction is exact (Thm 1);
  transversal extraction uses the provably-sufficient "min(k, |A ∩ C|)
  delegates per category present in the cluster" rule (a superset of the
  paper's Thm-2 set — still a (1-eps)-coreset, see DESIGN.md §8.4).

Array conventions
-----------------
``cats``: int32[n, gamma] — category ids per point, right-padded with -1.
          Partition/uniform matroids use gamma == 1.
``caps``: int32[h] — per-category budget (partition matroid only; a
          transversal matroid implicitly has cap 1 *per matching*, not per
          category membership).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Static spec (hashable; safe as a jit static argument)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MatroidSpec:
    kind: str  # 'uniform' | 'partition' | 'transversal' | 'general'
    num_categories: int = 0  # h
    gamma: int = 1  # max categories per point

    def __post_init__(self):
        if self.kind not in ("uniform", "partition", "transversal", "general"):
            raise ValueError(f"unknown matroid kind: {self.kind}")


# --------------------------------------------------------------------------
# Host-side exact oracles (numpy) — used on coreset-sized inputs
# --------------------------------------------------------------------------


class Matroid:
    """Abstract host-side matroid over ground set {0..n-1}."""

    spec: MatroidSpec

    def is_independent(self, idxs: Sequence[int]) -> bool:
        raise NotImplementedError

    def can_extend(self, idxs: Sequence[int], x: int) -> bool:
        """Whether idxs + [x] is independent (idxs assumed independent)."""
        return self.is_independent(list(idxs) + [x])

    def rank_of(self, idxs: Sequence[int]) -> int:
        """Size of a largest independent subset of idxs (matroid greedy)."""
        cur: list[int] = []
        for x in idxs:
            if self.can_extend(cur, x):
                cur.append(x)
        return len(cur)

    def greedy_independent(self, idxs: Sequence[int], k: int) -> list[int]:
        """A largest independent subset of idxs of size <= k (exact for all
        matroids by the greedy property, provided can_extend is exact)."""
        cur: list[int] = []
        for x in idxs:
            if len(cur) >= k:
                break
            if self.can_extend(cur, x):
                cur.append(x)
        return cur

    # subclasses may override with something faster


class UniformMatroid(Matroid):
    def __init__(self, n: int, rank: int):
        self.n = n
        self.rank = rank
        self.spec = MatroidSpec("uniform")

    def is_independent(self, idxs):
        return len(set(idxs)) == len(idxs) and len(idxs) <= self.rank


class PartitionMatroid(Matroid):
    def __init__(self, cats: np.ndarray, caps: np.ndarray):
        cats = np.asarray(cats, np.int32)
        if cats.ndim == 2:
            # extra columns may only carry -1 padding: a partition matroid
            # assigns each element exactly one class — multi-label ground
            # sets are transversal-matroid territory, and truncating the
            # extra labels would silently change the constraint
            if cats.shape[1] > 1 and np.any(cats[:, 1:] >= 0):
                raise ValueError(
                    "partition matroid got multi-label categories "
                    "(a point carries >1 label); use a transversal spec"
                )
            cats = cats[:, 0]
        self.cats = cats
        self.caps = np.asarray(caps, np.int64)
        self.spec = MatroidSpec("partition", num_categories=len(self.caps), gamma=1)

    @property
    def rank(self) -> int:
        counts = np.bincount(self.cats, minlength=len(self.caps))
        return int(np.minimum(counts, self.caps).sum())

    def is_independent(self, idxs):
        idxs = list(idxs)
        if len(set(idxs)) != len(idxs):
            return False
        counts = np.bincount(self.cats[idxs], minlength=len(self.caps))
        return bool(np.all(counts <= self.caps))

    def can_extend(self, idxs, x):
        if x in idxs:
            return False
        c = self.cats[x]
        return int(np.sum(self.cats[list(idxs)] == c)) < int(self.caps[c])


def _kuhn_try(adj: list[list[int]], u: int, match_cat: np.ndarray,
              seen: np.ndarray) -> bool:
    """Augmenting path from point u (iterative DFS, Kuhn's algorithm)."""
    stack = [(u, iter(adj[u]))]
    path: list[tuple[int, int]] = []  # (point, cat) tentative assignments
    while stack:
        node, it = stack[-1]
        advanced = False
        for c in it:
            if seen[c]:
                continue
            seen[c] = True
            w = match_cat[c]
            if w < 0:
                # free category: commit the whole path
                match_cat[c] = node
                for (pu, pc) in reversed(path):
                    match_cat[pc] = pu
                return True
            path.append((node, c))
            stack.append((w, iter(adj[w])))
            advanced = True
            break
        if not advanced:
            stack.pop()
            if path and stack:
                path.pop()
    return False


class TransversalMatroid(Matroid):
    """Transversal matroid from multi-label categories (exact via matching)."""

    def __init__(self, cats: np.ndarray, num_categories: int):
        cats = np.asarray(cats, np.int32)
        if cats.ndim == 1:
            cats = cats[:, None]
        self.cats = cats  # (n, gamma), -1 padded
        self.h = int(num_categories)
        self.spec = MatroidSpec(
            "transversal", num_categories=self.h, gamma=cats.shape[1]
        )

    def _adj(self, idxs) -> list[list[int]]:
        return [[int(c) for c in self.cats[i] if c >= 0] for i in idxs]

    def max_matching(self, idxs: Sequence[int]) -> int:
        adj = self._adj(idxs)
        match_cat = np.full(self.h, -1, np.int64)
        size = 0
        for u in range(len(adj)):
            seen = np.zeros(self.h, bool)
            if _kuhn_try(adj, u, match_cat, seen):
                size += 1
        return size

    def is_independent(self, idxs):
        idxs = list(idxs)
        if len(set(idxs)) != len(idxs):
            return False
        return self.max_matching(idxs) == len(idxs)

    def can_extend(self, idxs, x):
        if x in idxs:
            return False
        return self.is_independent(list(idxs) + [x])

    @property
    def rank(self) -> int:
        return self.max_matching(range(self.cats.shape[0]))

    def greedy_independent(self, idxs, k):
        """Largest <=k independent subset — incremental Kuhn (exact)."""
        idxs = list(idxs)
        adj_all = self._adj(idxs)
        match_cat = np.full(self.h, -1, np.int64)
        chosen: list[int] = []
        adj: list[list[int]] = []
        for local, x in enumerate(idxs):
            if len(chosen) >= k:
                break
            adj.append(adj_all[local])
            seen = np.zeros(self.h, bool)
            if _kuhn_try(adj, len(adj) - 1, match_cat, seen):
                chosen.append(x)
            else:
                # rejected point is always the last entry, so indices stored
                # in match_cat (positions of *accepted* points) stay aligned
                adj.pop()
        return chosen


class GeneralMatroid(Matroid):
    """Wraps a user oracle is_independent(list[int]) -> bool."""

    def __init__(self, n: int, oracle: Callable[[Sequence[int]], bool]):
        self.n = n
        self.oracle = oracle
        self.spec = MatroidSpec("general")

    def is_independent(self, idxs):
        idxs = list(idxs)
        if len(set(idxs)) != len(idxs):
            return False
        return bool(self.oracle(idxs))


# --------------------------------------------------------------------------
# Vectorized jit-side helpers (static shapes, masks)
# --------------------------------------------------------------------------


def rank_in_group(group_ids: jnp.ndarray, valid: jnp.ndarray,
                  num_groups: int) -> jnp.ndarray:
    """Stream-order rank of every element within its group.

    group_ids: int32[m] in [0, num_groups); valid: bool[m].
    Returns int32[m]; invalid entries get a huge rank. Stable in index order,
    which is what the paper's "first come" extraction semantics need.
    """
    m = group_ids.shape[0]
    key = jnp.where(valid, group_ids, num_groups)  # park invalid in last group
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    idx = jnp.arange(m, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_key[1:] != sorted_key[:-1]]
    )
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    ranks_sorted = idx - seg_start
    ranks = jnp.zeros((m,), jnp.int32).at[order].set(ranks_sorted)
    return jnp.where(valid, ranks, jnp.int32(2**30))


def partition_extract_mask(
    assign: jnp.ndarray,  # int32[n] cluster id per point
    cats: jnp.ndarray,  # int32[n, 1]
    caps: jnp.ndarray,  # int32[h]
    valid: jnp.ndarray,  # bool[n]
    k: int,
    tau: int,
    num_categories: int,
) -> jnp.ndarray:
    """Exact Thm-1 EXTRACT for partition matroids, across all clusters at once.

    Selected set per cluster = a largest independent subset of size <= k:
    first-k-per-(cluster,category) clipped per category by caps, then first-k
    overall within the cluster.
    """
    c = cats[:, 0]
    # rank within (cluster, category)
    gc = assign * num_categories + c
    r_cc = rank_in_group(gc, valid, tau * num_categories)
    stage1 = (r_cc < jnp.minimum(caps[c], k)) & valid
    # rank within cluster among stage-1 survivors
    r_cl = rank_in_group(assign, stage1, tau)
    return stage1 & (r_cl < k)


def transversal_extract_mask(
    assign: jnp.ndarray,  # int32[n]
    cats: jnp.ndarray,  # int32[n, gamma], -1 padded
    valid: jnp.ndarray,  # bool[n]
    k: int,
    tau: int,
    num_categories: int,
) -> jnp.ndarray:
    """Jit-friendly transversal EXTRACT: keep the first min(k, |A ∩ C_i|)
    points of every category A present in cluster C_i (a superset of the
    Thm-2 coreset; matching-free, hence shardable). A point is kept iff it is
    within the first k of *any* of its categories in its cluster.
    """
    n, gamma = cats.shape
    # per (point, category-slot) group ids
    g = assign[:, None] * num_categories + jnp.maximum(cats, 0)
    slot_valid = (cats >= 0) & valid[:, None]
    r = rank_in_group(g.reshape(-1), slot_valid.reshape(-1),
                      tau * num_categories).reshape(n, gamma)
    keep = jnp.any((r < k) & slot_valid, axis=1)
    return keep & valid


def partition_counts_ok(sel_cats: jnp.ndarray, sel_valid: jnp.ndarray,
                        caps: jnp.ndarray, num_categories: int) -> jnp.ndarray:
    """Check a (small) selected set respects partition caps. sel_cats: (m,1)."""
    c = jnp.where(sel_valid, sel_cats[:, 0], num_categories)
    counts = jnp.zeros((num_categories + 1,), jnp.int32).at[c].add(1)
    return jnp.all(counts[:num_categories] <= caps)


# --------------------------------------------------------------------------
# Builders
# --------------------------------------------------------------------------


def make_host_matroid(spec: MatroidSpec, cats: Optional[np.ndarray],
                      caps: Optional[np.ndarray], n: int,
                      k: int, oracle=None) -> Matroid:
    if spec.kind == "uniform":
        return UniformMatroid(n, k)
    if spec.kind == "partition":
        return PartitionMatroid(np.asarray(cats), np.asarray(caps))
    if spec.kind == "transversal":
        return TransversalMatroid(np.asarray(cats), spec.num_categories)
    if spec.kind == "general":
        assert oracle is not None, "general matroid needs a host oracle"
        return GeneralMatroid(n, oracle)
    raise ValueError(spec.kind)
