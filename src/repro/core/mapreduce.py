"""MapReduce coreset construction (paper §4.2) as SPMD shard_map.

The paper's one-round MR scheme: partition S into ell shards, run SeqCoreset
on each shard (local delta_i, local GMM), union the local coresets. The
composability property (§3, [21]) makes the union a (1-eps)-coreset for S.

TPU mapping (DESIGN.md §3.3):
* a "reducer" is a mesh position along the data-parallel axes
  (``pod`` x ``data``); the map phase is the data pipeline's sharding;
* the union is one ``all_gather`` of the fixed-capacity coreset buffers;
* the optional second round (re-coreset of the union, making the final size
  independent of ell — paper §4.2 last paragraph) runs replicated on every
  device (identical inputs -> identical outputs, no extra communication).

Fault-tolerance note: the union of ANY subset of shard-coresets is a valid
coreset for the points those shards hold, so a straggler/failed shard
degrades coverage gracefully instead of poisoning the result (the driver can
mask out a shard by zeroing its ``valid`` lanes).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import axis_size, shard_map
from .coreset import Coreset, compress, default_capacity, extraction_mask, seq_coreset
from .matroid import MatroidSpec


def _flat_axis_index(axis_names: Sequence[str]) -> jnp.ndarray:
    """Linear shard index over (possibly multiple) mesh axes, C-order."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * axis_size(name) + jax.lax.axis_index(name)
    return idx


def local_coreset_and_gather(
    pts: jnp.ndarray,  # (n_local, d)
    cats: jnp.ndarray,  # (n_local, gamma)
    valid: jnp.ndarray,  # (n_local,)
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau_local: int,
    axis_names: Sequence[str],
    *,
    eps: float = 0.0,
    use_radius_target: bool = False,
    cap_local: Optional[int] = None,
) -> tuple[Coreset, jnp.ndarray]:
    """Runs inside shard_map: SeqCoreset on the local shard, then all_gather.

    Returns the union coreset (same on every shard) and the max overflow.
    """
    n_local = pts.shape[0]
    offset = _flat_axis_index(axis_names) * n_local
    cs, _res, ovf = seq_coreset(
        pts, cats, valid, spec, caps, k, tau_local,
        eps=eps, use_radius_target=use_radius_target,
        cap=cap_local, base_index=offset,
    )
    gathered = Coreset(
        *(
            jax.lax.all_gather(leaf, axis_names, tiled=True)
            for leaf in cs
        )
    )
    ovf = jax.lax.pmax(ovf, axis_names)
    return gathered, ovf


def mapreduce_coreset(
    mesh: Mesh,
    points: jnp.ndarray,  # (n, d) global, n divisible by #shards
    cats: jnp.ndarray,  # (n, gamma)
    valid: jnp.ndarray,  # (n,)
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau_local: int,
    *,
    data_axes: Sequence[str] = ("data",),
    eps: float = 0.0,
    use_radius_target: bool = False,
    round2_tau: Optional[int] = None,
) -> tuple[Coreset, jnp.ndarray]:
    """One (optionally two) MR round(s). Returns (coreset, overflow) with the
    coreset replicated across the mesh.

    round2_tau: if given, apply the sequential construction once more to the
    gathered union (paper: makes |T| independent of ell at the cost of an
    extra (1-eps) factor).
    """
    data_axes = tuple(data_axes)
    caps_arg = caps if caps is not None else jnp.zeros((1,), jnp.int32)

    in_spec = P(data_axes)
    pspec = P(data_axes, None)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(pspec, pspec, in_spec, P()),
        out_specs=(
            Coreset(P(), P(), P(), P()),
            P(),
        ),
        check_vma=False,
    )
    def run(pts, cts, vld, caps_in):
        cs, ovf = local_coreset_and_gather(
            pts, cts, vld, spec,
            caps_in if caps is not None else None,
            k, tau_local, data_axes,
            eps=eps, use_radius_target=use_radius_target,
        )
        if round2_tau is not None:
            cap2 = default_capacity(spec, k, round2_tau)
            cs2, _res2, ovf2 = seq_coreset(
                cs.points, cs.cats, cs.valid, spec,
                caps_in if caps is not None else None,
                k, round2_tau, cap=cap2,
                base_index=None,
            )
            # src_idx of round-2 points must chain through round-1's mapping
            safe = jnp.maximum(cs2.src_idx, 0)
            chained = jnp.where(cs2.valid, cs.src_idx[safe], -1)
            cs = cs2._replace(src_idx=chained)
            ovf = jnp.maximum(ovf, ovf2)
        return cs, ovf

    return run(points, cats, valid, caps_arg)
