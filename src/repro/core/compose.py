"""Coreset composition (paper §3: composability under union).

The paper's Lemma backing both the MapReduce construction (§4.2) and the
sharded serving layer: if S_1, ..., S_m partition S and T_i is an
(eps, k)-coreset of S_i, then U_i T_i is an (eps, k)-coreset of S. *Any*
partition of the stream qualifies — the row-granular round-robin deal of
the ``vmap``/``shard_map`` drives (``ingest_batch_sharded`` /
``ingest_batch_sharded_mapped``) and the batch-granular deal of the
serving layer's ``pipeline`` placement alike. Shards build coresets
independently and are combined after the fact:

``union_coresets``       plain buffer concatenation — the exact union, no
                         quality loss, size grows with the shard count;
``snapshot_shards``      the union of a *stacked* per-shard ``StreamState``'s
                         coresets (vmapped snapshot + flatten), preserving
                         shard-major row order;
``merge_stream_states``  re-filter the union back to a single <= tau-center
                         ``StreamState`` by re-ingesting every shard's
                         delegates (with their global ``src_idx`` kept)
                         through the tau-controlled scan — a coreset of a
                         coreset, i.e. still a coreset of S with the eps
                         compounding per §3. Accepts a stacked state (the
                         vmap/shard_map drives) or a list of per-shard
                         states (the pipeline placement).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .coreset import Coreset, concat_coresets
from .matroid import MatroidSpec
from .streaming import (
    StreamState,
    ingest_batch,
    init_stream_state,
    snapshot_coreset,
)


def union_coresets(coresets: Sequence[Coreset]) -> Coreset:
    """Union of coresets of a partition = coreset of the whole (§3)."""
    return concat_coresets(list(coresets))


def unstack_shards(sts: StreamState) -> list[StreamState]:
    """Split a stacked per-shard state (leading shard axis) into a list."""
    num_shards = sts.cvalid.shape[0]
    return [
        jax.tree_util.tree_map(lambda x, s=s: x[s], sts)
        for s in range(num_shards)
    ]


def snapshot_shards(sts: StreamState) -> Coreset:
    """Union coreset of a stacked per-shard ``StreamState``.

    Rows are shard-major (shard 0's buffer order, then shard 1's, ...): the
    same order as ``union_coresets([snapshot_coreset(s) for s in shards])``.
    """
    cs = jax.vmap(snapshot_coreset)(sts)
    flat = lambda x: x.reshape((-1,) + x.shape[2:])
    return Coreset(
        points=flat(cs.points),
        cats=flat(cs.cats),
        valid=flat(cs.valid),
        src_idx=flat(cs.src_idx),
    )


def snapshot_at_epoch(
    states: Union[StreamState, Sequence[StreamState]],
) -> Coreset:
    """Union coreset of whatever state collection an ingestion drive owns —
    the epoch-materialization entry point of the serving runtime.

    Accepts every placement's state layout and dispatches to the matching
    §3 composition: a single ``StreamState`` (unsharded), a stacked state
    with a leading shard axis (the ``vmap``/``shard_map`` drives), or a
    list of per-shard states (the ``pipeline`` placement). Row order is
    shard-major in every case, identical to
    ``union_coresets([snapshot_coreset(s) for s in shards])``, so epochs
    materialized under different drives of the same deal are comparable
    row for row.
    """
    if isinstance(states, StreamState):
        if states.cvalid.ndim == 2:
            return snapshot_shards(states)
        return snapshot_coreset(states)
    return union_coresets([snapshot_coreset(s) for s in states])


def compact_coreset(cs: Coreset) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side (points, cats, src_idx) of the valid rows, buffer order."""
    valid = np.asarray(cs.valid)
    return (
        np.asarray(cs.points)[valid],
        np.asarray(cs.cats)[valid],
        np.asarray(cs.src_idx)[valid].astype(np.int64),
    )


def merge_stream_states(
    states: Union[StreamState, Sequence[StreamState]],
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    variant: str = "radius",
    eps: float = 0.5,
    c_const: int = 32,
    slot_cap: Optional[int] = None,
    block_size: int = 1,  # one small one-shot pass: per-point compiles faster
) -> StreamState:
    """Merge per-shard stream states into one <= tau-center state.

    The union of the shards' delegate sets (a coreset of the whole stream,
    §3) is itself streamed through the tau-controlled scan, which re-filters
    it back to tau centers; delegates keep their *global* ``src_idx``, so
    the merged coreset still names original stream rows. ``states`` is a
    list of per-shard states or a stacked state with a leading shard axis.
    """
    if isinstance(states, StreamState):
        states = (
            unstack_shards(states) if states.cvalid.ndim == 2 else [states]
        )
    pts, cats, srcs = [], [], []
    for st in states:
        p, c, s = compact_coreset(snapshot_coreset(st))
        pts.append(p)
        cats.append(c)
        srcs.append(s)
    P = np.concatenate(pts)
    C = np.concatenate(cats)
    S = np.concatenate(srcs)
    d = P.shape[1]
    gamma = C.shape[1]
    if slot_cap is None:
        slot_cap = states[0].dv.shape[1]
    st = init_stream_state(d, gamma, spec, k, tau, slot_cap=slot_cap)
    return ingest_batch(
        st,
        jnp.asarray(P, jnp.float32),
        jnp.asarray(C, jnp.int32),
        jnp.ones((P.shape[0],), bool),
        spec,
        caps,
        k,
        tau,
        src=jnp.asarray(S, jnp.int32),
        variant=variant,
        eps=eps,
        c_const=c_const,
        block_size=block_size,
    )
