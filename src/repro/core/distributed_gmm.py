"""Distributed (global) GMM farthest-first traversal — beyond-paper.

The paper's MR construction (§4.2) runs GMM independently per shard and
unions the per-shard coresets; correct by composability, but the union is a
tau_total = ell * tau_local clustering whose radius can be up to ~2x worse
than a GLOBAL tau-clustering of S (each shard re-discovers the same global
structure). This module runs ONE Gonzalez traversal over the sharded
dataset inside shard_map:

  per iteration: every shard folds the new center into its local min-dist
  vector (the same fused kernels/ops.gmm_update pass), then a global
  argmax is reached with one pmax + one masked pmax (O(1) scalars on the
  wire per iteration — the collective cost is tau * O(1), negligible next
  to the O(n*tau/ell) local distance work).

The result is byte-identical to single-machine GMM on the concatenated
data (tests/test_distributed_gmm.py), so Thm-5 coreset guarantees apply
with the GLOBAL tau rather than the per-shard sum — strictly smaller
coresets at equal radius (measured in benchmarks/fig3 commentary).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels import ops
from ..compat import axis_size, shard_map
from .coreset import Coreset, compress, default_capacity, extraction_mask
from .matroid import MatroidSpec


def _global_gmm_shard(pts, valid, tau: int, axes: Sequence[str]):
    """Runs inside shard_map. pts: (n_local, d). Returns
    (assign (n_local,), min_dist (n_local,), centers (tau, d), num, radius).
    """
    n_local = pts.shape[0]
    axes = tuple(axes)

    shard_idx = jnp.int32(0)
    for name in axes:
        shard_idx = shard_idx * axis_size(name) + jax.lax.axis_index(
            name
        )

    def pick_global(md):
        """Global argmax of masked min-dist: returns (value, center point).

        Two-round owner election so exact-value ties resolve to exactly ONE
        shard (elementwise pmax of two different points would mix
        coordinates)."""
        local_best = jnp.max(jnp.where(valid, md, -1.0))
        gbest = jax.lax.pmax(local_best, axes)
        contends = local_best >= gbest
        owner_tag = jnp.where(contends, -shard_idx.astype(jnp.float32),
                              -jnp.inf)
        best_owner = jax.lax.pmax(owner_tag, axes)
        is_owner = contends & (owner_tag >= best_owner)
        li = jnp.argmax(jnp.where(valid, md, -1.0))
        cand = jnp.where(is_owner, pts[li], -jnp.inf)
        center = jax.lax.pmax(cand, axes)
        return gbest, center

    # anchor: globally-first valid point (shard with lowest linear index
    # that has any valid point wins)
    has = jnp.any(valid)
    tag = jnp.where(has, -shard_idx.astype(jnp.float32), -jnp.inf)
    best_tag = jax.lax.pmax(tag, axes)
    anchor_owner = (tag >= best_tag) & has
    a_local = jnp.argmax(valid)
    anchor = jax.lax.pmax(
        jnp.where(anchor_owner, pts[a_local], -jnp.inf), axes
    )

    md0, _, _ = ops.gmm_update(
        pts, anchor, jnp.full((n_local,), jnp.inf, jnp.float32), valid
    )
    delta, z2 = pick_global(md0)

    centers0 = jnp.zeros((tau, pts.shape[1]), pts.dtype).at[0].set(anchor)
    assign0 = jnp.zeros((n_local,), jnp.int32)

    def body(t, state):
        centers, assign, md, nxt = state
        centers = centers.at[t].set(nxt)
        new_md, _, _ = ops.gmm_update(pts, nxt, md, valid)
        assign = jnp.where(new_md < md, t, assign)
        _, nxt2 = pick_global(new_md)
        return centers, assign, new_md, nxt2

    centers, assign, md, _ = jax.lax.fori_loop(
        1, tau, body, (centers0, assign0, md0, z2)
    )
    radius = jax.lax.pmax(jnp.max(jnp.where(valid, md, 0.0)), axes)
    return assign, md, centers, jnp.float32(delta), radius


def distributed_coreset(
    mesh: Mesh,
    points: jnp.ndarray,  # (n, d) global, n divisible by #shards
    cats: jnp.ndarray,
    valid: jnp.ndarray,
    spec: MatroidSpec,
    caps,
    k: int,
    tau: int,
    *,
    data_axes: Sequence[str] = ("data",),
):
    """Global-GMM coreset: one traversal over all shards, then the same
    EXTRACT masks as seq_coreset evaluated shard-locally, gathered.

    Returns (coreset replicated, radius, delta).
    """
    data_axes = tuple(data_axes)
    caps_arg = caps if caps is not None else jnp.zeros((1,), jnp.int32)
    cap = default_capacity(spec, k, tau)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(data_axes, None), P(data_axes, None), P(data_axes), P()),
        out_specs=(Coreset(P(), P(), P(), P()), P(), P()),
        check_vma=False,
    )
    def run(pts, cts, vld, caps_in):
        n_local = pts.shape[0]
        assign, _md, _centers, delta, radius = _global_gmm_shard(
            pts, vld, tau, data_axes
        )
        mask = extraction_mask(
            spec, assign, cts,
            caps_in if caps is not None else None, vld, k, tau,
        )
        idx = jnp.int32(0)
        for name in data_axes:
            idx = idx * axis_size(name) + jax.lax.axis_index(name)
        cs = compress(pts, cts, mask, cap, base_index=idx * n_local)
        gathered = Coreset(
            *(jax.lax.all_gather(leaf, data_axes, tiled=True) for leaf in cs)
        )
        return gathered, radius, delta

    return run(points, cats, valid, caps_arg)
