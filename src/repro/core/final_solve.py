"""Final-stage DMMC solver over a *precomputed* coreset distance matrix.

The paper's split (§4.4): the expensive combinatorial solver only ever sees
the coreset, so the distance matrix over the coreset is a small, reusable
object. This module is the single implementation shared by the offline
driver (``solve.solve_dmmc``) and the online serving layer
(``serve.diversity``), which caches the matrix across queries:

    D = coreset_distance_matrix(coreset_points)     # Pallas pdist on TPU
    X, val = final_solve(D, matroid, k, variant)    # host solver, reads D only

Keeping both callers on the same distance computation and the same solver
makes the service's answers *exactly* equal to ``solve_dmmc`` on the same
coreset (the parity tests in tests/test_service.py assert this).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np


from ..kernels import ops as kernel_ops
from .diversity import Variant
from .matroid import Matroid
from .solvers import SolveContext, SolveSpec, resolve_engine, select_engine


def coreset_distance_matrix(
    points: np.ndarray, *, force: Optional[str] = None
) -> np.ndarray:
    """(m, d) -> (m, m) Euclidean distances via the tiled pdist kernel.

    Dispatches through ``kernels.ops`` (Pallas on TPU, jnp reference off-TPU)
    so offline and serving paths produce the same float32 matrix.
    """
    pts = jnp.asarray(points, jnp.float32)
    d2 = kernel_ops.pairwise_sqdist(pts, pts, force=force)
    return np.asarray(jnp.sqrt(jnp.maximum(d2, 0.0)))


class SubsetMatroidView(Matroid):
    """View of a host matroid restricted to ``sub`` with local indexing.

    Local index i stands for global element sub[i]; solvers run on local
    indices (rows of the coreset distance matrix), oracle queries are
    translated to the global ground set.
    """

    def __init__(self, matroid: Matroid, sub: np.ndarray):
        self.matroid = matroid
        self.sub = np.asarray(sub, np.int64)
        self.spec = matroid.spec

    def can_extend(self, idxs, x):
        return self.matroid.can_extend(
            [int(self.sub[i]) for i in idxs], int(self.sub[x])
        )

    def is_independent(self, idxs):
        return self.matroid.is_independent([int(self.sub[i]) for i in idxs])


def final_solve(
    D: np.ndarray,
    matroid: Matroid,
    k: int,
    variant: Variant,
    *,
    idxs: Optional[Sequence[int]] = None,
    gamma: float = 0.0,
    engine: str = "host",
    cats: Optional[np.ndarray] = None,
    caps: Optional[np.ndarray] = None,
) -> tuple[list[int], float]:
    """Best independent k-subset of ``idxs`` under ``variant``, reading only D.

    Dispatches through the ``core.solvers`` registry. The default
    ``engine="host"`` is the paper's dispatch (sum -> AMT local search,
    footnote 5; others -> exhaustive search, exact on the coreset) and
    stays the offline driver's default: a one-shot solve would pay a jit
    compile per novel coreset size for no amortization. ``engine="auto"``
    picks the fastest registered engine with the host-parity guarantee
    (pass ``cats``/``caps`` so the jit engines are eligible); any
    registered engine name forces that engine. Returns (selected local
    indices, canonical float64 diversity value).
    """
    ctx = SolveContext(
        D=np.asarray(D),
        spec=matroid.spec,
        cats=None if cats is None else np.asarray(cats, np.int32),
        caps=None if caps is None else np.asarray(caps, np.int32),
        matroid_fn=lambda _spec: matroid,
    )
    # idxs passes through as an explicit candidate order: host solvers'
    # tie-breaks are visit-order dependent, so the sequence (duplicates
    # included) reaches them unchanged; jit engines refuse non-ascending
    # orders via eligible()
    spec = SolveSpec(
        k=k, variant=variant, gamma=gamma,
        idxs=None if idxs is None else tuple(int(i) for i in idxs),
    )
    if engine == "auto":
        eng = select_engine(ctx, spec)
    else:
        eng = resolve_engine(engine, ctx, spec)
    sol = eng.solve_one(ctx, spec)
    return [int(i) for i in sol.local_indices], float(sol.value)
