"""Diversity objectives of Table 1 and their combinatorics.

Host (numpy) versions are the solver-facing oracles (exact for small k, with
clearly-flagged heuristics for NP-hard evaluations beyond exact thresholds);
jnp versions exist for the objectives that are cheap to evaluate inside jit
(sum / star / tree), which is what the data-selection integration uses.

f(k) bookkeeping (number of distances in the objective) and the Lemma-1
average-farness lower bounds are also here, used by the property tests.
"""
from __future__ import annotations

import itertools
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

Variant = Literal["sum", "star", "tree", "cycle", "bipartition"]
VARIANTS: tuple[Variant, ...] = ("sum", "star", "tree", "cycle", "bipartition")

EXACT_CYCLE_MAX_K = 12  # Held-Karp 2^k * k^2
EXACT_BIPARTITION_MAX_K = 16  # C(16, 8) = 12870 subsets


def f_of_k(variant: Variant, k: int) -> int:
    """Number of pairwise distances contributing to div (paper §3)."""
    if variant == "sum":
        return k * (k - 1) // 2
    if variant in ("star", "tree"):
        return k - 1
    if variant == "cycle":
        return k
    if variant == "bipartition":
        return (k // 2) * ((k + 1) // 2)
    raise ValueError(variant)


def farness_lower_bound(delta: float, k: int, variant: Variant) -> float:
    """Lemma 1: rho_{S,k} >= c(variant) * Delta_S."""
    if variant == "sum":
        return delta / (2 * k)
    if variant == "star":
        return delta / (4 * (k - 1))
    if variant == "tree":
        return delta / (2 * (k - 1))
    if variant == "cycle":
        return delta / k
    if variant == "bipartition":
        return delta / (2 * (k + 1))
    raise ValueError(variant)


# --------------------------------------------------------------------------
# jnp objectives (jit-able) on a distance matrix D: (k, k)
# --------------------------------------------------------------------------


def sum_div(D: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(D) / 2.0


def star_div(D: jnp.ndarray) -> jnp.ndarray:
    return jnp.min(jnp.sum(D, axis=1))


def tree_div(D: jnp.ndarray) -> jnp.ndarray:
    """MST weight via Prim's algorithm, O(k^2)."""
    k = D.shape[0]
    big = jnp.asarray(jnp.inf, D.dtype)

    def step(state, _):
        in_tree, best = state
        # best: cheapest edge from tree to each vertex outside it
        masked = jnp.where(in_tree, big, best)
        j = jnp.argmin(masked)
        w = masked[j]
        in_tree = in_tree.at[j].set(True)
        best = jnp.minimum(best, D[j])
        return (in_tree, best), w

    in_tree0 = jnp.zeros((k,), bool).at[0].set(True)
    _, ws = jax.lax.scan(step, (in_tree0, D[0]), None, length=k - 1)
    return jnp.sum(ws)


_JNP_OBJECTIVES = {"sum": sum_div, "star": star_div, "tree": tree_div}


def jnp_diversity(D: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    if variant not in _JNP_OBJECTIVES:
        raise ValueError(
            f"{variant} is NP-hard to evaluate; use host diversity() instead"
        )
    return _JNP_OBJECTIVES[variant](D)


# --------------------------------------------------------------------------
# Host objectives (exact small-k; flagged heuristics beyond)
# --------------------------------------------------------------------------


def _tsp_held_karp(D: np.ndarray) -> float:
    k = D.shape[0]
    if k == 1:
        return 0.0
    if k == 2:
        return float(2.0 * D[0, 1])
    full = 1 << (k - 1)  # subsets of {1..k-1}; city 0 is the anchor
    dp = np.full((full, k - 1), np.inf)
    for j in range(k - 1):
        dp[1 << j, j] = D[0, j + 1]
    for mask in range(1, full):
        for j in range(k - 1):
            cur = dp[mask, j]
            if not np.isfinite(cur) or not (mask >> j) & 1:
                continue
            rest = ~mask & (full - 1)
            m = rest
            while m:
                nxt = (m & -m).bit_length() - 1
                nm = mask | (1 << nxt)
                val = cur + D[j + 1, nxt + 1]
                if val < dp[nm, nxt]:
                    dp[nm, nxt] = val
                m &= m - 1
    best = np.inf
    for j in range(k - 1):
        best = min(best, dp[full - 1, j] + D[j + 1, 0])
    return float(best)


def _tsp_heuristic(D: np.ndarray) -> float:
    """Nearest-neighbour + 2-opt. Flagged approximate (used only for k > 12)."""
    k = D.shape[0]
    tour = [0]
    unvisited = set(range(1, k))
    while unvisited:
        last = tour[-1]
        nxt = min(unvisited, key=lambda j: D[last, j])
        tour.append(nxt)
        unvisited.remove(nxt)
    improved = True
    while improved:
        improved = False
        for i in range(1, k - 1):
            for j in range(i + 1, k):
                a, b = tour[i - 1], tour[i]
                c, d = tour[j], tour[(j + 1) % k]
                if D[a, c] + D[b, d] < D[a, b] + D[c, d] - 1e-12:
                    tour[i : j + 1] = tour[i : j + 1][::-1]
                    improved = True
    return float(sum(D[tour[i], tour[(i + 1) % k]] for i in range(k)))


def _bipartition_exact(D: np.ndarray) -> float:
    k = D.shape[0]
    half = k // 2
    idx = list(range(k))
    best = np.inf
    # fix element 0 in Q's complement to halve the enumeration when k even
    for q in itertools.combinations(idx[1:] if k % 2 == 0 else idx, half):
        q = list(q)
        mask = np.zeros(k, bool)
        mask[q] = True
        cut = float(D[mask][:, ~mask].sum())
        best = min(best, cut)
    return best


def _bipartition_heuristic(D: np.ndarray) -> float:
    """Greedy + single-swap descent (Kernighan-Lin style), flagged approx."""
    k = D.shape[0]
    half = k // 2
    rng = np.random.default_rng(0)
    best = np.inf
    for _ in range(8):
        mask = np.zeros(k, bool)
        mask[rng.choice(k, half, replace=False)] = True
        improved = True
        while improved:
            improved = False
            cut = float(D[mask][:, ~mask].sum())
            for i in np.flatnonzero(mask):
                for j in np.flatnonzero(~mask):
                    m2 = mask.copy()
                    m2[i], m2[j] = False, True
                    c2 = float(D[m2][:, ~m2].sum())
                    if c2 < cut - 1e-12:
                        mask, cut, improved = m2, c2, True
        best = min(best, cut)
    return best


def diversity(D: np.ndarray, variant: Variant) -> float:
    """Host-side objective value for point set with distance matrix D."""
    D = np.asarray(D, np.float64)
    k = D.shape[0]
    if k <= 1:
        return 0.0
    if variant == "sum":
        return float(np.sum(D) / 2.0)
    if variant == "star":
        return float(np.min(np.sum(D, axis=1)))
    if variant == "tree":
        # Prim
        in_tree = np.zeros(k, bool)
        in_tree[0] = True
        best = D[0].copy()
        total = 0.0
        for _ in range(k - 1):
            best_m = np.where(in_tree, np.inf, best)
            j = int(np.argmin(best_m))
            total += best_m[j]
            in_tree[j] = True
            best = np.minimum(best, D[j])
        return float(total)
    if variant == "cycle":
        if k <= EXACT_CYCLE_MAX_K:
            return _tsp_held_karp(D)
        return _tsp_heuristic(D)
    if variant == "bipartition":
        if k <= EXACT_BIPARTITION_MAX_K:
            return _bipartition_exact(D)
        return _bipartition_heuristic(D)
    raise ValueError(variant)


def diversity_of_points(points: np.ndarray, variant: Variant) -> float:
    from .geometry import pairwise_matrix

    D = np.asarray(pairwise_matrix(jnp.asarray(points)))
    return diversity(D, variant)
