"""Moved to ``core.solvers.exhaustive`` (the solver-engine package);
this shim keeps the historical import path working."""
from .solvers.exhaustive import exhaustive_best

__all__ = ["exhaustive_best"]
