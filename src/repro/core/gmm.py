"""Gonzalez farthest-first traversal (GMM) — the paper's clustering engine.

Two stopping rules, both from the paper:

* **radius-target** (Alg. 1): iterate until the clustering radius drops to
  ``eps * delta / (16 k)`` where ``delta = d(z1, z2) in [Delta/2, Delta]`` —
  this is what makes the construction oblivious to the doubling dimension;
* **fixed tau** (the experiments' knob): run exactly ``tau`` iterations.

The inner loop is one fused pass over the point matrix per added center
(``kernels.ops.gmm_update``): distance-to-new-center, running min, and the
arg-max that selects the next center, all in one HBM read. Total work is
O(n tau) distances — Thm 5.

Everything is static-shape and jit-able, so the MapReduce construction can
run it *inside* shard_map on each shard.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import geometry


class GMMResult(NamedTuple):
    centers: jnp.ndarray  # int32[tau_max] point indices, -1 padded
    num_centers: jnp.ndarray  # int32 scalar
    assign: jnp.ndarray  # int32[n] cluster id (position in `centers`)
    min_dist: jnp.ndarray  # f32[n] distance to own center
    radius: jnp.ndarray  # f32 scalar (over valid points)
    delta: jnp.ndarray  # f32 scalar, d(z1, z2) in [Delta/2, Delta]


@functools.partial(
    jax.jit, static_argnames=("tau_max", "k", "use_radius_target")
)
def gmm(
    points: jnp.ndarray,  # (n, d), already metric-normalized
    valid: jnp.ndarray,  # (n,) bool
    tau_max: int,
    *,
    k: int = 1,
    eps: float = 0.0,
    use_radius_target: bool = False,
) -> GMMResult:
    """Farthest-first traversal with masked (padded) inputs.

    With ``use_radius_target``: stop at radius <= eps * delta / (16 k)
    (Alg. 1 line: ``while r(C, Z) > eps*delta/(16k)``), capped at tau_max.
    Otherwise: run to exactly min(tau_max, #valid) centers.
    """
    n = points.shape[0]
    has_any = jnp.any(valid)
    anchor = jnp.argmax(valid).astype(jnp.int32)  # first valid point (z1)

    nm0, far0, delta = ops.gmm_update(
        points,
        points[anchor],
        jnp.full((n,), jnp.inf, jnp.float32),
        valid,
    )
    # state: (t, centers, assign, min_dist, next_idx, radius)
    centers0 = jnp.full((tau_max,), -1, jnp.int32).at[0].set(anchor)
    assign0 = jnp.zeros((n,), jnp.int32)
    target = (
        jnp.asarray(eps, jnp.float32) * delta / (16.0 * k)
        if use_radius_target
        else jnp.asarray(-1.0, jnp.float32)
    )
    n_valid = jnp.sum(valid.astype(jnp.int32))

    def cond(state):
        t, _, _, _, _, radius = state
        return (t < jnp.minimum(tau_max, n_valid)) & (radius > target)

    def body(state):
        t, centers, assign, min_dist, nxt, _ = state
        centers = centers.at[t].set(nxt)
        new_min, far_idx, far_val = ops.gmm_update(
            points, points[nxt], min_dist, valid
        )
        assign = jnp.where(new_min < min_dist, t, assign)
        return (t + 1, centers, assign, new_min, far_idx, far_val)

    t, centers, assign, min_dist, _, radius = jax.lax.while_loop(
        cond, body, (jnp.int32(1), centers0, assign0, nm0, far0, delta)
    )
    radius = jnp.where(has_any, jnp.maximum(radius, 0.0), 0.0)
    return GMMResult(
        centers=centers,
        num_centers=jnp.where(has_any, t, 0).astype(jnp.int32),
        assign=assign,
        min_dist=min_dist,
        radius=radius,
        delta=jnp.where(has_any, delta, 0.0),
    )


def gmm_fixed(points, valid, tau: int) -> GMMResult:
    """Experiments' knob: exactly tau clusters (Section 5 parameterization)."""
    return gmm(points, valid, tau_max=tau)


def gmm_radius(points, valid, k: int, eps: float, tau_max: int) -> GMMResult:
    """Alg. 1 stopping rule: radius <= eps*delta/(16k), capped at tau_max."""
    return gmm(
        points, valid, tau_max=tau_max, k=k, eps=eps, use_radius_target=True
    )
