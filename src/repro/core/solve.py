"""End-to-end DMMC driver: coreset construction + final-stage solver.

This is the public API tying the paper together (§4.4):

    solution = solve_dmmc(points, k, spec, ..., setting="mapreduce")

1. build a (1-eps)-coreset with the chosen setting
   (sequential Alg. 1 / streaming Alg. 2 / MapReduce shard_map);
2. run the final solver on the coreset only:
   - sum       -> AMT local search (gamma=0), the paper's choice;
   - others    -> exhaustive search (exact on the coreset).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry
from .coreset import seq_coreset, seq_coreset_host
from .diversity import Variant, diversity
from .final_solve import SubsetMatroidView, coreset_distance_matrix, final_solve
from .mapreduce import mapreduce_coreset
from .matroid import MatroidSpec, make_host_matroid
from .streaming import stream_coreset


@dataclasses.dataclass
class DMMCSolution:
    indices: np.ndarray  # selected point indices into S
    diversity: float
    coreset_indices: np.ndarray
    coreset_size: int
    timings: dict
    info: dict


def _final_solve(
    points: np.ndarray,
    cats: Optional[np.ndarray],
    spec: MatroidSpec,
    caps: Optional[np.ndarray],
    k: int,
    coreset_idx: np.ndarray,
    variant: Variant,
    oracle=None,
    gamma: float = 0.0,
    engine: str = "host",
) -> tuple[list[int], float]:
    matroid = make_host_matroid(
        spec,
        None if cats is None else np.asarray(cats),
        caps,
        points.shape[0],
        k,
        oracle,
    )
    sub = np.asarray(coreset_idx, np.int64)
    # distance matrix over coreset only (never over S)
    pts = np.asarray(
        geometry.normalize_for_metric(jnp.asarray(points[sub]), "euclidean")
    )
    Dsub = coreset_distance_matrix(pts)
    view = SubsetMatroidView(matroid, sub)
    # cats/caps restricted to the coreset rows make the jit engines
    # eligible when the caller asks for engine="auto"/"jit_*"
    X, val = final_solve(
        Dsub, view, k, variant, gamma=gamma, engine=engine,
        cats=None if cats is None else np.asarray(cats)[sub], caps=caps,
    )
    return [int(sub[i]) for i in X], val


def solve_dmmc(
    points: np.ndarray,
    k: int,
    spec: MatroidSpec,
    *,
    cats: Optional[np.ndarray] = None,
    caps: Optional[np.ndarray] = None,
    variant: Variant = "sum",
    eps: Optional[float] = None,
    tau: Optional[int] = None,
    setting: str = "sequential",  # sequential | streaming | mapreduce
    metric: geometry.Metric = "euclidean",
    mesh=None,
    data_axes: Sequence[str] = ("data",),
    round2_tau: Optional[int] = None,
    oracle=None,
    gamma: float = 0.0,
    engine: str = "host",
) -> DMMCSolution:
    """Solve a DMMC instance end to end. Exactly one of eps/tau.

    ``engine`` names a ``core.solvers`` registry engine for the final
    stage ("host" = the paper's dispatch, the offline default — a one-shot
    solve cannot amortize a jit compile; "auto" = fastest host-parity
    engine; or any registered engine name).
    """
    assert (eps is None) != (tau is None)
    n, d = points.shape
    t0 = time.perf_counter()

    cats_arr = (
        np.zeros((n, 1), np.int32)
        if cats is None
        else np.asarray(cats, np.int32).reshape(n, -1)
    )
    pts_norm = geometry.normalize_for_metric(
        jnp.asarray(points, jnp.float32), metric
    )

    if setting == "sequential":
        idx, info = seq_coreset_host(
            np.asarray(pts_norm),
            cats_arr,
            spec,
            caps,
            k,
            eps=eps,
            tau=tau,
            metric="euclidean",  # already normalized
            oracle=oracle,
        )
    elif setting == "streaming":
        assert tau is not None, "streaming is parameterized by tau (§5.2)"
        caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
        cs, _st = stream_coreset(
            pts_norm, jnp.asarray(cats_arr), jnp.ones((n,), bool),
            spec, caps_j, k, tau,
        )
        idx = np.asarray(cs.src_idx)[np.asarray(cs.valid)]
        info = dict(tau=tau, size=int(idx.size))
    elif setting == "mapreduce":
        assert mesh is not None and tau is not None
        caps_j = None if caps is None else jnp.asarray(caps, jnp.int32)
        shards = int(np.prod([mesh.shape[a] for a in data_axes]))
        pad = -n % shards
        pts_p = jnp.pad(pts_norm, ((0, pad), (0, 0)))
        cats_p = jnp.pad(jnp.asarray(cats_arr), ((0, pad), (0, 0)))
        val_p = jnp.pad(jnp.ones((n,), bool), (0, pad))
        tau_local = max(1, tau // shards)
        cs, ovf = mapreduce_coreset(
            mesh, pts_p, cats_p, val_p, spec, caps_j, k, tau_local,
            data_axes=data_axes, round2_tau=round2_tau,
        )
        valid = np.asarray(cs.valid)
        idx = np.unique(np.asarray(cs.src_idx)[valid])
        idx = idx[(idx >= 0) & (idx < n)]  # drop padding artifacts
        info = dict(tau=tau, shards=shards, size=int(idx.size),
                    overflow=int(ovf))
    else:
        raise ValueError(setting)

    t1 = time.perf_counter()
    sol_idx, val = _final_solve(
        np.asarray(pts_norm), cats_arr, spec, caps, k,
        np.asarray(idx), variant, oracle, gamma, engine,
    )
    t2 = time.perf_counter()

    return DMMCSolution(
        indices=np.asarray(sol_idx, np.int64),
        diversity=val,
        coreset_indices=np.asarray(idx, np.int64),
        coreset_size=int(np.asarray(idx).size),
        timings=dict(coreset_s=t1 - t0, solver_s=t2 - t1, total_s=t2 - t0),
        info=info,
    )
