"""Core library: the paper's contribution (coreset-based DMMC) in JAX.

Public API:
    MatroidSpec, make_host_matroid          -- matroid representations
    gmm, gmm_fixed, gmm_radius              -- Gonzalez clustering (Alg. 1 engine)
    seq_coreset, seq_coreset_host           -- sequential construction (Alg. 1)
    stream_coreset, stream_coreset_host     -- streaming construction (Alg. 2)
    mapreduce_coreset                       -- shard_map MR construction (4.2)
    local_search_sum, exhaustive_best       -- final-stage solvers (4.4)
    SolverEngine, register_engine, ...      -- pluggable solver-engine registry
                                               (core.solvers; jit batch engines
                                               + host reference engines)
    solve_dmmc                              -- end-to-end driver
    diversity, jnp_diversity, VARIANTS      -- Table-1 objectives
"""
from .diversity import VARIANTS, Variant, diversity, f_of_k, farness_lower_bound, jnp_diversity
from .exhaustive import exhaustive_best
from .gmm import GMMResult, gmm, gmm_fixed, gmm_radius
from .coreset import Coreset, concat_coresets, seq_coreset, seq_coreset_host
from .local_search import greedy_init, local_search_sum
from .mapreduce import mapreduce_coreset
from .matroid import (
    GeneralMatroid,
    Matroid,
    MatroidSpec,
    PartitionMatroid,
    TransversalMatroid,
    UniformMatroid,
    make_host_matroid,
)
from .compose import (
    merge_stream_states,
    snapshot_shards,
    union_coresets,
    unstack_shards,
)
from .distributed_gmm import distributed_coreset
from .final_solve import coreset_distance_matrix, final_solve
from .solvers import (
    SolveContext,
    SolveSpec,
    SolverEngine,
    coverage_matrix,
    get_engine,
    register_engine,
    registered_engines,
    select_engine,
    selection_value,
)
from .solve import DMMCSolution, solve_dmmc
from .streaming import (
    StreamState,
    ingest_batch,
    ingest_batch_sharded,
    init_sharded_states,
    init_stream_state,
    snapshot_coreset,
    stream_coreset,
    stream_coreset_host,
)

__all__ = [
    "VARIANTS", "Variant", "diversity", "f_of_k", "farness_lower_bound",
    "jnp_diversity", "exhaustive_best", "GMMResult", "gmm", "gmm_fixed",
    "gmm_radius", "Coreset", "concat_coresets", "seq_coreset",
    "seq_coreset_host", "greedy_init", "local_search_sum",
    "mapreduce_coreset", "GeneralMatroid", "Matroid", "MatroidSpec",
    "PartitionMatroid", "TransversalMatroid", "UniformMatroid",
    "make_host_matroid", "DMMCSolution", "solve_dmmc", "stream_coreset",
    "distributed_coreset",
    "stream_coreset_host",
    "StreamState", "init_stream_state", "ingest_batch", "snapshot_coreset",
    "ingest_batch_sharded", "init_sharded_states",
    "merge_stream_states", "snapshot_shards", "union_coresets",
    "unstack_shards",
    "coreset_distance_matrix", "final_solve",
    "SolveContext", "SolveSpec", "SolverEngine", "coverage_matrix",
    "get_engine", "register_engine", "registered_engines", "select_engine",
    "selection_value",
]
