"""Coreset constructions (paper §3.1 + Alg. 1 "SeqCoreset").

Two implementations with one semantics:

* ``seq_coreset`` — fully jit-able, static shapes, mask-based. Exact Thm-1
  extraction for partition/uniform matroids; for transversal matroids it uses
  the matching-free "min(k, |A ∩ C_i|) delegates of every category present"
  rule (superset of Thm 2's set → still a (1-eps)-coreset; DESIGN.md §8.4).
  This is the routine that runs *inside* shard_map on every shard.

* ``seq_coreset_host`` — the paper's Algorithm 1 verbatim (numpy EXTRACT with
  exact Kuhn matching for transversal U_i + category top-up, and the general-
  matroid fallback T_i = C_i). Used by the sequential setting and by the
  correctness tests.

Coresets are fixed-capacity padded buffers so that the MapReduce union is a
plain ``all_gather`` (DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry
from .gmm import GMMResult, gmm
from .matroid import (
    Matroid,
    MatroidSpec,
    make_host_matroid,
    partition_extract_mask,
    rank_in_group,
    transversal_extract_mask,
)


class Coreset(NamedTuple):
    points: jnp.ndarray  # f32[cap, d]
    cats: jnp.ndarray  # int32[cap, gamma]
    valid: jnp.ndarray  # bool[cap]
    src_idx: jnp.ndarray  # int32[cap] index into the original dataset (-1 pad)

    @property
    def capacity(self) -> int:
        return self.points.shape[0]

    def size(self) -> jnp.ndarray:
        return jnp.sum(self.valid.astype(jnp.int32))


def default_capacity(spec: MatroidSpec, k: int, tau: int) -> int:
    """Static buffer capacity per construction (Thms 1/2 size bounds)."""
    if spec.kind in ("uniform", "partition"):
        return k * tau  # exact upper bound (Thm 1)
    if spec.kind == "transversal":
        # the matching-free jit rule keeps min(k, count) points of EVERY
        # category present in a cluster -> per-cluster bound is k * h (the
        # paper's Thm-2 set with exact matching is the tighter gamma*k^2;
        # the host construction achieves it). Cap the buffer accordingly.
        per_cluster = k * max(
            min(spec.num_categories, 4 * max(spec.gamma, 1) * k * k), 1
        )
        return min(per_cluster, k * max(spec.num_categories, 1)) * tau
    # general matroids can degenerate to whole clusters; host path only.
    raise ValueError(f"no static capacity for matroid kind {spec.kind!r}")


def extraction_mask(
    spec: MatroidSpec,
    assign: jnp.ndarray,
    cats: jnp.ndarray,
    caps: Optional[jnp.ndarray],
    valid: jnp.ndarray,
    k: int,
    tau: int,
) -> jnp.ndarray:
    """Per-point keep mask implementing EXTRACT for each matroid type."""
    if spec.kind == "uniform":
        # unconstrained diversity coreset of [4, 10, 21]: k points per cluster
        r = rank_in_group(assign, valid, tau)
        return valid & (r < k)
    if spec.kind == "partition":
        return partition_extract_mask(
            assign, cats, caps, valid, k, tau, spec.num_categories
        )
    if spec.kind == "transversal":
        return transversal_extract_mask(
            assign, cats, valid, k, tau, spec.num_categories
        )
    raise ValueError(f"jit EXTRACT not defined for {spec.kind!r}")


def compress(
    points: jnp.ndarray,
    cats: jnp.ndarray,
    mask: jnp.ndarray,
    cap: int,
    base_index: Optional[jnp.ndarray] = None,
) -> Coreset:
    """Pack masked rows into a fixed-capacity Coreset buffer (jit-safe)."""
    (idx,) = jnp.nonzero(mask, size=cap, fill_value=-1)
    valid = idx >= 0
    safe = jnp.maximum(idx, 0)
    src = idx if base_index is None else jnp.where(valid, base_index + idx, -1)
    return Coreset(
        points=jnp.where(valid[:, None], points[safe], 0.0),
        cats=jnp.where(valid[:, None], cats[safe], -1),
        valid=valid,
        src_idx=src.astype(jnp.int32),
    )


@functools.partial(
    jax.jit,
    static_argnames=("spec", "k", "tau", "eps", "use_radius_target", "cap"),
)
def seq_coreset(
    points: jnp.ndarray,  # (n, d) metric-normalized
    cats: jnp.ndarray,  # (n, gamma)
    valid: jnp.ndarray,  # (n,)
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],  # (h,) or None
    k: int,
    tau: int,
    *,
    eps: float = 0.0,
    use_radius_target: bool = False,
    cap: Optional[int] = None,
    base_index: Optional[jnp.ndarray] = None,
) -> tuple[Coreset, GMMResult, jnp.ndarray]:
    """Jit-able SeqCoreset. Returns (coreset, gmm_result, overflow_count).

    overflow_count > 0 means the static capacity was too small for the
    selection (never happens for partition/uniform with default capacity).
    """
    res = gmm(
        points, valid, tau_max=tau, k=k, eps=eps,
        use_radius_target=use_radius_target,
    )
    mask = extraction_mask(spec, res.assign, cats, caps, valid, k, tau)
    cap_ = cap if cap is not None else default_capacity(spec, k, tau)
    cs = compress(points, cats, mask, cap_, base_index)
    overflow = jnp.maximum(
        jnp.sum(mask.astype(jnp.int32)) - jnp.asarray(cap_, jnp.int32), 0
    )
    return cs, res, overflow


def concat_coresets(coresets: list[Coreset]) -> Coreset:
    """Union of coresets (composability): plain concatenation of buffers."""
    return Coreset(
        points=jnp.concatenate([c.points for c in coresets]),
        cats=jnp.concatenate([c.cats for c in coresets]),
        valid=jnp.concatenate([c.valid for c in coresets]),
        src_idx=jnp.concatenate([c.src_idx for c in coresets]),
    )


# --------------------------------------------------------------------------
# Host-side paper-exact Algorithm 1 (sequential setting; tests' ground truth)
# --------------------------------------------------------------------------


def seq_coreset_host(
    points: np.ndarray,
    cats: Optional[np.ndarray],
    spec: MatroidSpec,
    caps: Optional[np.ndarray],
    k: int,
    *,
    eps: Optional[float] = None,
    tau: Optional[int] = None,
    tau_max: int = 4096,
    metric: geometry.Metric = "euclidean",
    oracle=None,
) -> tuple[np.ndarray, dict]:
    """Algorithm 1 verbatim. Returns (selected indices into S, info dict).

    Exactly one of eps / tau must be given (radius-target vs fixed-tau mode).
    """
    assert (eps is None) != (tau is None), "give exactly one of eps / tau"
    n = points.shape[0]
    pts = geometry.normalize_for_metric(jnp.asarray(points, jnp.float32), metric)
    valid = jnp.ones((n,), bool)
    if eps is not None:
        res = gmm(pts, valid, tau_max=min(tau_max, n), k=k, eps=eps,
                  use_radius_target=True)
    else:
        res = gmm(pts, valid, tau_max=min(tau, n))
    assign = np.asarray(res.assign)
    num_centers = int(res.num_centers)

    if cats is None:
        cats_np = np.zeros((n, 1), np.int32)
    else:
        cats_np = np.asarray(cats, np.int32)
        if cats_np.ndim == 1:
            cats_np = cats_np[:, None]
    matroid: Matroid = make_host_matroid(spec, cats_np, caps, n, k, oracle)

    selected: list[int] = []
    for c in range(num_centers):
        members = np.flatnonzero(assign == c)
        u = matroid.greedy_independent(members.tolist(), k)  # largest <= k
        if spec.kind in ("uniform", "partition") or len(u) == k:
            t_i = list(u)
        elif spec.kind == "transversal":
            # top-up: min(k, |A ∩ C_i|) points of every category A of U_i
            t_i = list(u)
            chosen = set(u)
            a_prime = {
                int(a) for x in u for a in cats_np[x] if a >= 0
            }
            counts = {a: 0 for a in a_prime}
            for x in t_i:
                for a in cats_np[x]:
                    if int(a) in counts:
                        counts[int(a)] += 1
            for x in members:
                x = int(x)
                if x in chosen:
                    continue
                want = [
                    int(a) for a in cats_np[x]
                    if int(a) in counts and counts[int(a)] < k
                ]
                if want:
                    t_i.append(x)
                    chosen.add(x)
                    for a in cats_np[x]:
                        if int(a) in counts:
                            counts[int(a)] += 1
        else:  # general matroid: keep whole cluster when |U_i| < k (Thm 3)
            t_i = members.tolist()
        selected.extend(int(x) for x in t_i)

    info = dict(
        tau=num_centers,
        radius=float(res.radius),
        delta=float(res.delta),
        size=len(selected),
    )
    return np.asarray(sorted(set(selected)), np.int64), info
