"""Moved to ``core.solvers.local_search`` (the solver-engine package);
this shim keeps the historical import path working."""
from .solvers.local_search import greedy_init, local_search_sum

__all__ = ["greedy_init", "local_search_sum"]
