"""Streaming coreset construction (paper Alg. 2 "StreamCoreset" + the
tau-controlled doubling variant of §5.2), as a single jit'd lax.scan.

The scan is exposed as a resumable *ingestion API* — the substrate of the
online serving layer (serve/diversity):

    st = init_stream_state(d, gamma, spec, k, tau)
    st = ingest_batch(st, batch, cats, valid, spec, caps, k, tau,
                      base_index=offset)     # any number of times
    coreset = snapshot_coreset(st)

``stream_coreset`` (the one-shot entry point) is now a thin wrapper over
these three; batched ingestion is bit-identical to a single pass because the
scan branches only on ``st.n_seen``.

The scan is *blocked*: each step consumes ``block_size`` points. One
vectorized distance pass (``kernels.ops.block_center_dists``) plus a
matroid-specific precheck classifies every point in the block as a no-op
(within threshold of an existing center AND its HANDLE would not add a
delegate) or as active; runs of no-ops are consumed with O(1) masked
updates and only active points — center opens, delegate adds, restructures,
the first two stream points, and anything within the distance kernel's
error margin of a decision boundary — replay the exact per-point step.
``block_size=1`` recovers the original per-point scan; both produce
bit-identical states (asserted by the equivalence/property tests).

``ingest_batch_sharded`` vmaps the same scan over a leading shard axis: per
§3 composability (and the MapReduce formulation of arXiv:1605.05590),
shards build coresets independently and compose by union — see
``core/compose.py`` for the union/merge half.

State (all static shapes; TCAP centers, SLOT delegate slots per center):
  R          scalar estimate (diameter for Alg. 2; radius for the variant)
  x1         first stream point (Alg. 2's anchor for the diameter estimate)
  centers    f32[TCAP, d], cvalid bool[TCAP]
  del_*      delegate buffers per center: points f32[TCAP, SLOT, d],
             cats int32[TCAP, SLOT, gamma], valid bool[TCAP, SLOT],
             src int32[TCAP, SLOT]

Per point: nearest center; if farther than the new-center threshold, open a
center (the point is its own first delegate — Alg. 2); else HANDLE(x, z).
HANDLE is matroid-specific and matches Alg. 2 case-by-case:
  partition    add iff |D_z| < k and cat-count < cap (D_z stays independent)
  uniform      add iff |D_z| < k
  transversal  add iff some category of x has < k delegates; then try the
               shrink step with a *greedy* matching witness (a greedy size-k
               matching proves an independent size-k subset exists; sound,
               possibly later than the paper's exact check — DESIGN.md §8)
Restructuring merges dropped centers' delegates into their nearest survivor
via the same HANDLE (Alg. 2's merge loop).

General matroids need a host oracle => use ``stream_coreset_host`` (plain
python loop; streaming is single-machine in the paper anyway).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .coreset import Coreset
from .matroid import MatroidSpec

_BIG = jnp.float32(jnp.finfo(jnp.float32).max)


class StreamState(NamedTuple):
    R: jnp.ndarray
    x1: jnp.ndarray  # (d,)
    n_seen: jnp.ndarray  # int32, number of (valid) points consumed
    centers: jnp.ndarray  # (TCAP, d)
    cvalid: jnp.ndarray  # (TCAP,)
    dp: jnp.ndarray  # (TCAP, SLOT, d)
    dc: jnp.ndarray  # (TCAP, SLOT, gamma)
    dv: jnp.ndarray  # (TCAP, SLOT)
    ds: jnp.ndarray  # (TCAP, SLOT)
    overflow: jnp.ndarray  # int32: forced-discard count (transversal cap)


def _dists_to_centers(x, centers, cvalid):
    diff = centers - x[None, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    return jnp.where(cvalid, d, _BIG)


def _handle(spec: MatroidSpec, k: int, caps, st: StreamState, z, x, xc, xsrc):
    """Alg. 2 HANDLE(x, z, D_z). Returns updated state (+overflow count)."""
    slots_v = st.dv[z]  # (SLOT,)
    cnt = jnp.sum(slots_v.astype(jnp.int32))
    slot_cap = slots_v.shape[0]
    free_slot = jnp.argmin(slots_v)  # first False (all True -> 0, guarded)
    has_room = ~jnp.all(slots_v)

    if spec.kind == "uniform":
        add = cnt < k
        forced = jnp.int32(0)
    elif spec.kind == "partition":
        c = xc[0]
        same = slots_v & (st.dc[z, :, 0] == c)
        add = (cnt < k) & (jnp.sum(same.astype(jnp.int32)) < caps[c])
        forced = jnp.int32(0)
    elif spec.kind == "transversal":
        # count of delegates holding each category of x
        match = (st.dc[z][:, :, None] == xc[None, None, :]) & (
            xc[None, None, :] >= 0
        )  # (SLOT, gamma, gamma_x)
        holds = jnp.any(match, axis=1) & slots_v[:, None]  # (SLOT, gamma_x)
        cnts = jnp.sum(holds.astype(jnp.int32), axis=0)  # (gamma_x,)
        short = (cnts < k) & (xc >= 0)
        want = jnp.any(short)
        add = want & has_room
        forced = (want & ~has_room).astype(jnp.int32)
    else:  # pragma: no cover
        raise ValueError(f"jit HANDLE not defined for {spec.kind!r}")

    add = add & has_room

    def do_add(st: StreamState) -> StreamState:
        return st._replace(
            dp=st.dp.at[z, free_slot].set(x),
            dc=st.dc.at[z, free_slot].set(xc),
            dv=st.dv.at[z, free_slot].set(True),
            ds=st.ds.at[z, free_slot].set(xsrc),
        )

    st = jax.lax.cond(add, do_add, lambda s: s, st)
    st = st._replace(overflow=st.overflow + forced)

    if spec.kind == "transversal":
        st = jax.lax.cond(add, lambda s: _shrink(spec, k, s, z), lambda s: s, st)
    return st


def _shrink(spec: MatroidSpec, k: int, st: StreamState, z):
    """Greedy-matching shrink: if a greedy matching of D_z covers k slots,
    keep exactly those slots (a witnessed independent set of size k). The
    matching loop itself lives in ``solvers.matching`` (shared with the
    batched transversal solver's machinery) and is bit-identical to the
    historical inline version."""
    from .solvers.matching import greedy_matching_slots

    slots_v = st.dv[z]
    _used, matched = greedy_matching_slots(
        st.dc[z], slots_v, spec.num_categories
    )
    size = jnp.sum(matched.astype(jnp.int32))

    def do_shrink(st: StreamState) -> StreamState:
        return st._replace(dv=st.dv.at[z].set(matched & slots_v))

    return jax.lax.cond(size >= k, do_shrink, lambda s: s, st)


def _merge_delegates(spec, k, caps, st: StreamState, dead_mask):
    """Alg. 2 restructure merge: delegates of dropped centers are HANDLE'd
    into their nearest surviving center.

    The tcap*slot fori_loop runs only when some center actually died — a
    filter pass that keeps every center (all-False ``dead_mask``) is a no-op
    and must not pay the merge loop on the scan's steady-state steps."""
    tcap, slot_n = st.dv.shape

    def per_slot(i, st):
        ci, si = i // slot_n, i % slot_n
        is_live_del = dead_mask[ci] & st.dv[ci, si]

        def do(st: StreamState) -> StreamState:
            x = st.dp[ci, si]
            d = _dists_to_centers(x, st.centers, st.cvalid)
            z = jnp.argmin(d)
            return _handle(spec, k, caps, st, z, x, st.dc[ci, si], st.ds[ci, si])

        return jax.lax.cond(is_live_del, do, lambda s: s, st)

    def run_merge(st: StreamState) -> StreamState:
        st = jax.lax.fori_loop(0, tcap * slot_n, per_slot, st)
        # clear dropped centers' own buffers
        return st._replace(dv=st.dv & ~dead_mask[:, None])

    return jax.lax.cond(jnp.any(dead_mask), run_merge, lambda s: s, st)


def _filter_centers(st: StreamState, thr):
    """Greedy maximal subset of centers with pairwise distance > thr."""
    c = st.centers
    d2 = jnp.sum((c[:, None, :] - c[None, :, :]) ** 2, axis=-1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    tcap = c.shape[0]

    def body(i, keep):
        near_kept = jnp.any(keep & st.cvalid & (d[i] <= thr) &
                            (jnp.arange(tcap) < i))
        ki = st.cvalid[i] & ~near_kept
        return keep.at[i].set(ki)

    keep = jax.lax.fori_loop(0, tcap, body, jnp.zeros((tcap,), bool))
    return keep


def default_slot_cap(spec: MatroidSpec, k: int) -> int:
    """Static per-center delegate capacity (Alg. 2 size bounds)."""
    if spec.kind in ("uniform", "partition"):
        return k
    return max(spec.gamma, 1) * k * k


def init_stream_state(
    d: int,
    gamma: int,
    spec: MatroidSpec,
    k: int,
    tau: int,
    *,
    slot_cap: Optional[int] = None,
) -> StreamState:
    """Empty resumable scan state (the ingestion API's starting point).

    The returned state is a pure pytree of static-shape buffers: feed it to
    ``ingest_batch`` any number of times, snapshot with ``snapshot_coreset``.
    """
    tcap = tau + 1
    if slot_cap is None:
        slot_cap = default_slot_cap(spec, k)
    return StreamState(
        R=jnp.float32(0.0),
        x1=jnp.zeros((d,), jnp.float32),
        n_seen=jnp.int32(0),
        centers=jnp.zeros((tcap, d), jnp.float32),
        cvalid=jnp.zeros((tcap,), bool),
        dp=jnp.zeros((tcap, slot_cap, d), jnp.float32),
        dc=jnp.full((tcap, slot_cap, gamma), -1, jnp.int32),
        dv=jnp.zeros((tcap, slot_cap), bool),
        ds=jnp.full((tcap, slot_cap), -1, jnp.int32),
        overflow=jnp.int32(0),
    )


def snapshot_coreset(st: StreamState) -> Coreset:
    """Assemble the current coreset from the delegate buffers (jit-safe)."""
    tcap, slot_cap, d = st.dp.shape
    gamma = st.dc.shape[2]
    flat_valid = st.dv.reshape(-1) & jnp.repeat(st.cvalid, slot_cap)
    return Coreset(
        points=st.dp.reshape(-1, d),
        cats=st.dc.reshape(-1, gamma),
        valid=flat_valid,
        src_idx=jnp.where(flat_valid, st.ds.reshape(-1), -1),
    )


def _make_step(spec: MatroidSpec, k: int, tau: int, caps_arr, variant: str,
               eps: float, c_const: int):
    """Build the per-point Alg.-2 scan step (the bit-exact reference
    semantics both the per-point and the blocked scans are defined by)."""

    def open_center(st: StreamState, x, xc, xsrc) -> StreamState:
        slot = jnp.argmin(st.cvalid)
        return st._replace(
            centers=st.centers.at[slot].set(x),
            cvalid=st.cvalid.at[slot].set(True),
            dp=st.dp.at[slot, 0].set(x),
            dc=st.dc.at[slot, 0].set(xc),
            dv=st.dv.at[slot, 0].set(True),
            ds=st.ds.at[slot, 0].set(xsrc),
        )

    def restructure_radius(st: StreamState) -> StreamState:
        """tau-variant: while #centers > tau: R *= 2; filter; merge."""

        def cond(st):
            return jnp.sum(st.cvalid.astype(jnp.int32)) > tau

        def body(st):
            R = st.R * 2.0
            st = st._replace(R=R)
            keep = _filter_centers(st, R)
            dead = st.cvalid & ~keep
            st = st._replace(cvalid=keep)
            return _merge_delegates(spec, k, caps_arr, st, dead)

        return jax.lax.while_loop(cond, body, st)

    def restructure_diameter(st: StreamState) -> StreamState:
        """Alg. 2: after R update, filter at eps*R/(ck) and merge."""
        thr = jnp.float32(eps) * st.R / (c_const * k)
        keep = _filter_centers(st, thr)
        dead = st.cvalid & ~keep
        st = st._replace(cvalid=keep)
        return _merge_delegates(spec, k, caps_arr, st, dead)

    def step(st: StreamState, inp):
        x, xc, xsrc, v = inp
        t = st.n_seen

        def skip(st):
            return st

        def first(st: StreamState) -> StreamState:
            st = open_center(st, x, xc, xsrc)
            return st._replace(x1=x, n_seen=t + 1)

        def second(st: StreamState) -> StreamState:
            r0 = jnp.sqrt(
                jnp.maximum(jnp.sum((x - st.x1) ** 2), 0.0)
            )
            st = open_center(st, x, xc, xsrc)
            R = r0 if variant == "diameter" else r0 / 2.0
            return st._replace(R=jnp.maximum(R, 1e-30), n_seen=t + 1)

        def general(st: StreamState) -> StreamState:
            dists = _dists_to_centers(x, st.centers, st.cvalid)
            z = jnp.argmin(dists)
            dmin = dists[z]
            if variant == "diameter":
                thr_new = 2.0 * eps * st.R / (c_const * k)
            else:
                thr_new = 2.0 * st.R

            def as_new(st):
                return open_center(st, x, xc, xsrc)

            def as_handle(st):
                return _handle(spec, k, caps_arr, st, z, x, xc, xsrc)

            st = jax.lax.cond(dmin > thr_new, as_new, as_handle, st)

            if variant == "diameter":
                d1 = jnp.sqrt(jnp.maximum(jnp.sum((x - st.x1) ** 2), 0.0))

                def upd(st):
                    st = st._replace(R=d1)
                    return restructure_diameter(st)

                st = jax.lax.cond(d1 > 2.0 * st.R, upd, lambda s: s, st)
            else:
                st = jax.lax.cond(
                    jnp.sum(st.cvalid.astype(jnp.int32)) > tau,
                    restructure_radius,
                    lambda s: s,
                    st,
                )
            return st._replace(n_seen=t + 1)

        branch = jnp.where(t == 0, 0, jnp.where(t == 1, 1, 2))
        st = jax.lax.cond(
            v,
            lambda st: jax.lax.switch(branch, [first, second, general], st),
            skip,
            st,
        )
        return st, None

    return step


def _block_precheck(spec: MatroidSpec, k: int, caps_arr, variant: str,
                    eps: float, c_const: int, st: StreamState,
                    xb, xcb, vb):
    """Vectorized would-this-point-change-state test for a block of points,
    evaluated against the *current* state.

    Returns (active bool[B], forced int32[B]). A point is active iff the
    per-point step would do anything beyond incrementing ``n_seen`` (and, for
    transversal, ``overflow``): open a center, add a delegate (incl. the
    shrink that follows), trigger the diameter-variant R update, or fall
    within the distance kernel's error margin of any of those decision
    boundaries. Inactive valid points are exact no-ops whose only effect is
    ``n_seen += 1`` and ``overflow += forced`` — the invariant the blocked
    scan's bulk-skip relies on (state-unchanged induction along the block).
    """
    from ..kernels import ops as _ops

    dists, margin = _ops.block_center_dists(xb, st.centers, st.cvalid)
    tcap = st.centers.shape[0]
    dmin = jnp.min(dists, axis=1)
    z = jnp.argmin(dists, axis=1)
    # near-tie in the nearest-center choice => the precheck's z may disagree
    # with the exact path's; send those to the sequential fallback.
    second = jnp.min(
        jnp.where(jax.nn.one_hot(z, tcap, dtype=bool), _BIG, dists), axis=1
    )
    tie = (second - dmin) <= 2.0 * margin

    if variant == "diameter":
        thr_new = 2.0 * eps * st.R / (c_const * k)
    else:
        thr_new = 2.0 * st.R
    opens = dmin > thr_new - margin

    dvz = st.dv[z]  # (B, SLOT)
    cnt = jnp.sum(dvz.astype(jnp.int32), axis=1)
    has_room = ~jnp.all(dvz, axis=1)
    if spec.kind == "uniform":
        add = cnt < k
        forced = jnp.zeros(xb.shape[0], jnp.int32)
    elif spec.kind == "partition":
        c = xcb[:, 0]
        same = dvz & (st.dc[z][:, :, 0] == c[:, None])
        add = (cnt < k) & (
            jnp.sum(same.astype(jnp.int32), axis=1) < caps_arr[c]
        )
        forced = jnp.zeros(xb.shape[0], jnp.int32)
    elif spec.kind == "transversal":
        dcz = st.dc[z]  # (B, SLOT, gamma)
        match = (dcz[:, :, :, None] == xcb[:, None, None, :]) & (
            xcb[:, None, None, :] >= 0
        )  # (B, SLOT, gamma, gamma_x)
        holds = jnp.any(match, axis=2) & dvz[:, :, None]  # (B, SLOT, gamma_x)
        cnts = jnp.sum(holds.astype(jnp.int32), axis=1)  # (B, gamma_x)
        short = (cnts < k) & (xcb >= 0)
        want = jnp.any(short, axis=1)
        add = want & has_room
        forced = (want & ~has_room).astype(jnp.int32)
    else:  # pragma: no cover
        raise ValueError(f"blocked scan not defined for {spec.kind!r}")
    add = add & has_room

    active = opens | add | tie
    if variant == "diameter":
        d1 = jnp.sqrt(
            jnp.maximum(jnp.sum((xb - st.x1[None, :]) ** 2, axis=-1), 0.0)
        )
        active = active | (d1 > 2.0 * st.R - margin)
    return active & vb, forced


def _blocked_scan(step, spec: MatroidSpec, k: int, caps_arr, variant: str,
                  eps: float, c_const: int, st0: StreamState,
                  points, cats, src, valid, block_size: int) -> StreamState:
    """Scan B points per step: one vectorized distance/precheck pass decides
    which points could change state; runs of no-op points are consumed in
    O(1) masked updates and only the (rare, in steady state) active points
    replay the exact per-point step — bit-identical to the per-point scan."""
    n, d = points.shape
    B = block_size
    pad = -n % B
    if pad:
        points = jnp.concatenate([points, jnp.zeros((pad, d), points.dtype)])
        cats = jnp.concatenate(
            [cats, jnp.full((pad, cats.shape[1]), -1, cats.dtype)]
        )
        src = jnp.concatenate([src, jnp.full((pad,), -1, jnp.int32)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    nb = points.shape[0] // B
    Pb = points.reshape(nb, B, d)
    Cb = cats.reshape(nb, B, -1)
    Sb = src.reshape(nb, B)
    Vb = valid.reshape(nb, B)
    idx = jnp.arange(B, dtype=jnp.int32)

    def block_step(st: StreamState, inp):
        xb, xcb, srcb, vb = inp

        def cond(carry):
            return carry[1] < B

        def body(carry):
            st, i = carry
            active, forced = _block_precheck(
                spec, k, caps_arr, variant, eps, c_const, st, xb, xcb, vb
            )
            rem = idx >= i
            # the first two (valid) stream points take special branches
            vrem = vb & rem
            excl = jnp.cumsum(vrem.astype(jnp.int32)) - vrem.astype(jnp.int32)
            active = active | (vrem & (st.n_seen + excl < 2))
            act = active & rem
            f = jnp.where(jnp.any(act), jnp.argmax(act), B).astype(jnp.int32)
            skip = vrem & (idx < f)
            st = st._replace(
                n_seen=st.n_seen + jnp.sum(skip.astype(jnp.int32)),
                overflow=st.overflow + jnp.sum(jnp.where(skip, forced, 0)),
            )
            fs = jnp.minimum(f, B - 1)  # clamped gather; guarded by f < B

            def do_point(st: StreamState) -> StreamState:
                return step(st, (xb[fs], xcb[fs], srcb[fs], vb[fs]))[0]

            st = jax.lax.cond(f < B, do_point, lambda s: s, st)
            return st, f + 1

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        return st, None

    st, _ = jax.lax.scan(block_step, st0, (Pb, Cb, Sb, Vb))
    return st


def _ingest_core(st0: StreamState, points, cats, valid, src,
                 spec: MatroidSpec, caps_arr, k: int, tau: int,
                 variant: str, eps: float, c_const: int,
                 block_size: int) -> StreamState:
    step = _make_step(spec, k, tau, caps_arr, variant, eps, c_const)
    valid = valid.astype(bool)
    if block_size <= 1:
        st, _ = jax.lax.scan(step, st0, (points, cats, src, valid))
        return st
    return _blocked_scan(
        step, spec, k, caps_arr, variant, eps, c_const,
        st0, points, cats, src, valid, block_size,
    )


@functools.partial(
    jax.jit,
    static_argnames=("spec", "k", "tau", "variant", "c_const", "block_size"),
)
def ingest_batch(
    st0: StreamState,
    points: jnp.ndarray,  # (n, d) metric-normalized stream order
    cats: jnp.ndarray,  # (n, gamma)
    valid: jnp.ndarray,  # (n,)
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    base_index: jnp.ndarray = 0,  # global stream offset of points[0]
    variant: str = "radius",  # "radius" (§5.2 tau-controlled) | "diameter" (Alg. 2)
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 128,
    src: Optional[jnp.ndarray] = None,  # explicit global indices (overrides
                                        # base_index + arange; compose path)
) -> StreamState:
    """Resume the jit'd Alg.-2 scan over one batch of the stream.

    ``st0`` is ``init_stream_state(...)`` or the state returned by a previous
    ``ingest_batch`` call; ``base_index`` offsets the delegates' ``src_idx``
    so they stay global across batches. The scan branches on ``st.n_seen``,
    so resuming mid-stream is exact: the concatenation of batches yields
    bit-identical state to a single one-shot pass.

    ``block_size`` > 1 selects the blocked scan (B points per step; the
    vectorized precheck bulk-skips no-op points and replays only state-
    changing ones through the per-point step) — bit-identical to
    ``block_size=1`` by construction; the equivalence tests parameterize
    over both.
    """
    n, _ = points.shape
    caps_arr = caps if caps is not None else jnp.zeros((1,), jnp.int32)
    if src is None:
        src = jnp.asarray(base_index, jnp.int32) + jnp.arange(
            n, dtype=jnp.int32
        )
    else:
        src = jnp.asarray(src, jnp.int32)
    return _ingest_core(
        st0, points, cats, valid, src, spec, caps_arr, k, tau,
        variant, eps, c_const, block_size,
    )


def init_sharded_states(
    num_shards: int,
    d: int,
    gamma: int,
    spec: MatroidSpec,
    k: int,
    tau: int,
    *,
    slot_cap: Optional[int] = None,
) -> StreamState:
    """Stacked pytree of ``num_shards`` empty stream states (leading shard
    axis on every leaf) — the carry for ``ingest_batch_sharded``."""
    st = init_stream_state(d, gamma, spec, k, tau, slot_cap=slot_cap)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (num_shards,) + x.shape), st
    )


@functools.partial(
    jax.jit,
    static_argnames=("spec", "k", "tau", "variant", "c_const", "block_size"),
)
def ingest_batch_sharded(
    sts: StreamState,  # stacked: every leaf has leading shard axis S
    points: jnp.ndarray,  # (S, m, d)
    cats: jnp.ndarray,  # (S, m, gamma)
    valid: jnp.ndarray,  # (S, m)
    src: jnp.ndarray,  # (S, m) global stream indices
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    variant: str = "radius",
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 128,
) -> StreamState:
    """vmapped blocked ingestion: every shard runs its own independent
    Alg.-2 scan (paper §3 / the MapReduce formulation: coresets of a
    partition compose by union). Per-shard results are bit-identical to
    running ``ingest_batch`` on that shard's sub-stream alone."""
    caps_arr = caps if caps is not None else jnp.zeros((1,), jnp.int32)

    def one(st, p, c, v, s):
        return _ingest_core(
            st, p, c, v, s, spec, caps_arr, k, tau,
            variant, eps, c_const, block_size,
        )

    return jax.vmap(one)(sts, points, cats, valid.astype(bool), src)


def stream_coreset(
    points: jnp.ndarray,  # (n, d) metric-normalized stream order
    cats: jnp.ndarray,  # (n, gamma)
    valid: jnp.ndarray,  # (n,)
    spec: MatroidSpec,
    caps: Optional[jnp.ndarray],
    k: int,
    tau: int,
    *,
    slot_cap: Optional[int] = None,
    variant: str = "radius",  # "radius" (§5.2 tau-controlled) | "diameter" (Alg. 2)
    eps: float = 0.5,
    c_const: int = 32,
    block_size: int = 1,
) -> tuple[Coreset, StreamState]:
    """One-pass streaming coreset: init + single ingest_batch + snapshot.

    Defaults to the per-point scan: a one-shot offline pass pays the blocked
    graph's larger compile without amortizing it over repeated calls (the
    serving layer, which does amortize, opts into ``block_size=128``).
    """
    n, d = points.shape
    gamma = cats.shape[1]
    st0 = init_stream_state(d, gamma, spec, k, tau, slot_cap=slot_cap)
    st = ingest_batch(
        st0, points, cats, valid, spec, caps, k, tau,
        variant=variant, eps=eps, c_const=c_const, block_size=block_size,
    )
    return snapshot_coreset(st), st


def stream_coreset_host(
    points: np.ndarray,
    cats: Optional[np.ndarray],
    matroid,
    k: int,
    tau: int,
) -> np.ndarray:
    """Host-loop streaming for general matroids (oracle-based HANDLE).

    HANDLE 'other' case of Alg. 2: always add; if D_z gains an independent
    subset of size k, shrink to it. Returns selected indices.
    """
    n, d = points.shape
    R = None
    centers: list[int] = []
    delegates: dict[int, list[int]] = {}

    def dist(i, j):
        return float(np.linalg.norm(points[i] - points[j]))

    for i in range(n):
        if len(centers) < 2:
            centers.append(i)
            delegates[i] = [i]
            if len(centers) == 2:
                R = dist(centers[0], centers[1]) / 2.0 or 1e-30
            continue
        dmin, z = min((dist(i, c), c) for c in centers)
        if dmin > 2.0 * R:
            centers.append(i)
            delegates[i] = [i]
        else:
            dz = delegates[z]
            sub = matroid.greedy_independent(dz, k)
            if len(sub) < k:
                dz.append(i)
                sub2 = matroid.greedy_independent(dz, k)
                if len(sub2) == k:
                    delegates[z] = sub2
        while len(centers) > tau:
            R *= 2.0
            kept: list[int] = []
            for c in centers:
                if all(dist(c, c2) > R for c2 in kept):
                    kept.append(c)
            dropped = [c for c in centers if c not in kept]
            centers = kept
            for c in dropped:
                for x in delegates.pop(c):
                    dmin, z = min((dist(x, c2), c2) for c2 in centers)
                    dz = delegates[z]
                    sub = matroid.greedy_independent(dz, k)
                    if len(sub) < k:
                        dz.append(x)
                        sub2 = matroid.greedy_independent(dz, k)
                        if len(sub2) == k:
                            delegates[z] = sub2
    out = sorted({x for dz in delegates.values() for x in dz})
    return np.asarray(out, np.int64)
